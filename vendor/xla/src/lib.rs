//! A build-time stub of the `xla` (xla-rs) PJRT bindings, vendored so the
//! workspace compiles in containers without the XLA shared libraries or
//! registry access.
//!
//! [`Literal`] is implemented for real (host-side buffers with shape
//! checking), because the engine's input staging and its unit tests
//! exercise it. Everything that would call into PJRT proper —
//! [`HloModuleProto::from_text_file`], [`PjRtClient::compile`],
//! [`PjRtLoadedExecutable::execute`] — returns an "XLA runtime
//! unavailable" error, which the coordinator and benches already treat as
//! "no accelerator backend" and fall back to the native engine. Swapping
//! this stub for the real bindings is a one-line change in the root
//! `Cargo.toml`.

use std::any::Any;
use std::fmt;

/// Error type mirroring xla-rs's; only ever constructed with a message.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable(what: &str) -> Error {
    Error(format!("{what}: XLA runtime unavailable (stub build; native engine only)"))
}

/// A host-side tensor: flat values plus a shape.
#[derive(Clone, Debug)]
pub struct Literal {
    values: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// A rank-1 f32 literal.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal { values: values.to_vec(), dims: vec![values.len() as i64] }
    }

    /// A rank-0 f32 literal.
    pub fn scalar(value: f32) -> Literal {
        Literal { values: vec![value], dims: vec![] }
    }

    /// Reshape, validating the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let expected: i64 = dims.iter().product();
        if expected != self.values.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.values.len()
            )));
        }
        Ok(Literal { values: self.values.clone(), dims: dims.to_vec() })
    }

    /// Elements as a `Vec<T>`; the stub only holds f32.
    pub fn to_vec<T: Clone + 'static>(&self) -> Result<Vec<T>, Error> {
        let any: &dyn Any = &self.values;
        match any.downcast_ref::<Vec<T>>() {
            Some(v) => Ok(v.clone()),
            None => Err(Error("to_vec: stub literals are f32-only".to_string())),
        }
    }

    /// Destructure a tuple literal; the stub never produces tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("to_tuple"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module; the stub cannot parse HLO text.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle returned by `execute`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle; the stub never produces one that runs.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute"))
    }
}

/// PJRT client. Construction succeeds (so diagnostics can report the
/// platform); compilation fails with a clear message.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (XLA unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shapes() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert!(l.reshape(&[4]).is_err());
        assert_eq!(l.to_vec::<f32>().unwrap().len(), 6);
        assert!(l.to_vec::<i64>().is_err());
        assert_eq!(Literal::scalar(2.5).to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(!client.platform_name().is_empty());
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        assert!(client.compile(&comp).is_err());
    }
}
