//! A minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the workspace builds in offline containers (no registry access).
//!
//! Implements exactly the surface signax uses:
//!
//! - [`Error`]: an opaque error holding a rendered message (the source
//!   chain is flattened into the message at conversion time).
//! - [`Result`]: `Result<T, Error>` with a defaulted error type.
//! - [`anyhow!`], [`bail!`], [`ensure!`]: the formatting macros.
//! - `impl<E: std::error::Error> From<E> for Error` so `?` converts
//!   standard errors, mirroring upstream anyhow's blanket conversion
//!   (which is also why `Error` itself does not implement
//!   `std::error::Error` — the two impls would overlap).

use std::fmt;

/// An error message, with any source chain pre-rendered into it.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (inline captures work,
/// since the format tokens keep the caller's span).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs_two(x: usize) -> Result<usize> {
        ensure!(x >= 2, "got {x}, need at least 2");
        Ok(x)
    }

    fn io_convert() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn macros_and_conversions() {
        assert!(needs_two(3).is_ok());
        let e = needs_two(1).unwrap_err();
        assert_eq!(e.to_string(), "got 1, need at least 2");
        let e = io_convert().unwrap_err();
        assert!(!e.to_string().is_empty());
        let e: Error = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
        assert_eq!(format!("{e:?}"), "plain 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert!(bails().is_err());
    }
}
