//! A miniature property-based testing framework (proptest is unavailable
//! offline).
//!
//! Usage:
//! ```no_run
//! use signax::substrate::propcheck::{property, Gen};
//! property("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     g.label(format!("a={a} b={b}"));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case runs with a fresh deterministic generator; on failure the case
//! index, seed and the last `label` are reported so the exact case can be
//! replayed by seeding `Gen::replay`.

use crate::substrate::rng::Rng;

/// Case-local generator handed to properties.
pub struct Gen {
    rng: Rng,
    pub case: usize,
    pub seed: u64,
    label: String,
}

impl Gen {
    /// Recreate the generator for a reported failing case.
    pub fn replay(seed: u64, case: usize) -> Gen {
        Gen { rng: Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)), case, seed, label: String::new() }
    }

    /// Attach a human-readable description of the drawn case, shown on
    /// failure.
    pub fn label(&mut self, s: String) {
        self.label = s;
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.in_range(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        self.rng.normal_vec(n, scale)
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` on `cases` deterministic pseudo-random cases. Panics (failing
/// the enclosing test) with replay info if any case panics.
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    property_seeded(name, 0x5167_4E41_5458_0001, cases, prop)
}

/// Like [`property`] but with an explicit base seed (for replaying).
pub fn property_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    seed: u64,
    cases: usize,
    prop: F,
) {
    for case in 0..cases {
        let mut g = Gen::replay(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case}/{cases} (seed={seed:#x})\n  case: {}\n  cause: {msg}\n  replay with Gen::replay({seed:#x}, {case})",
                if g.label.is_empty() { "<unlabelled>" } else { &g.label },
            );
        }
    }
}

/// Assert two f32 slices are close: `|a-b| <= atol + rtol * |b|` elementwise.
/// Reports the worst offending index on failure.
#[track_caller]
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    let mut worst = (0usize, 0.0f32, 0.0f32);
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        let err = (x - y).abs();
        if err > tol && err - tol > worst.1 {
            worst = (i, err - tol, err);
        }
        assert!(
            x.is_finite() && y.is_finite(),
            "non-finite at index {i}: a={x} b={y}"
        );
    }
    if worst.2 > 0.0 {
        let i = worst.0;
        panic!(
            "arrays differ at index {i}: a={} b={} (abs err {}, rtol={rtol}, atol={atol})",
            a[i], b[i], worst.2
        );
    }
}

/// Relative L2 error between two vectors (0 for identical).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0f64;
    let mut den = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += (x as f64 - y as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("xor involutive", 64, |g| {
            let a = g.usize_in(0, 1 << 20);
            let b = g.usize_in(0, 1 << 20);
            assert_eq!(a ^ b ^ b, a);
        });
    }

    #[test]
    fn failing_property_reports() {
        let r = std::panic::catch_unwind(|| {
            property("always fails", 3, |g| {
                g.label("doomed".into());
                assert!(false, "nope");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("should have failed"),
        };
        assert!(msg.contains("case 0/3"), "{msg}");
        assert!(msg.contains("doomed"), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut g1 = Gen::replay(99, 5);
        let mut g2 = Gen::replay(99, 5);
        for _ in 0..16 {
            assert_eq!(g1.usize_in(0, 1000), g2.usize_in(0, 1000));
        }
        // Different cases draw differently.
        let mut g3 = Gen::replay(99, 6);
        let same = (0..16)
            .filter(|_| Gen::replay(99, 5).usize_in(0, usize::MAX - 1) == g3.usize_in(0, usize::MAX - 1))
            .count();
        assert!(same < 16);
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-4, 1e-6);
        let r = std::panic::catch_unwind(|| assert_close(&[1.0], &[1.2], 1e-3, 1e-3));
        assert!(r.is_err());
    }

    #[test]
    fn rel_l2_zero_for_identical() {
        let v = [1.0f32, -2.0, 3.0];
        assert_eq!(rel_l2(&v, &v), 0.0);
        assert!(rel_l2(&[1.0], &[2.0]) > 0.1);
    }
}
