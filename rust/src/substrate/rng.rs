//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` for seeding, `Xoshiro256PlusPlus` as the workhorse
//! generator, plus helpers for uniforms, normals (Box–Muller) and shuffles.
//! Every benchmark, test and example in the crate derives its data from
//! these generators so runs are reproducible bit-for-bit.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection-free for our purposes: modulo bias is negligible for
        // n << 2^64 and tests only need determinism, not perfection.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn in_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal variate via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with iid N(0, scale^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * scale;
        }
    }

    /// A fresh Vec of iid N(0, scale^2) f32 values.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, scale);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
            let x = r.in_range(3, 9);
            assert!((3..=9).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        // Overwhelmingly unlikely to be identity.
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent_and_deterministic() {
        let mut a = Rng::new(1234);
        let mut b = Rng::new(1234);
        let mut sa = a.split();
        let mut sb = b.split();
        for _ in 0..32 {
            assert_eq!(sa.next_u64(), sb.next_u64());
        }
    }
}
