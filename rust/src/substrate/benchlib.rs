//! Benchmark harness substrate.
//!
//! Reproduces the paper's measurement protocol (§6): "Every test case is
//! repeated 50 times and the fastest time taken", plus richer statistics
//! (median / mean / stddev) for our own §Perf iteration log. Criterion is
//! not available offline, so this is the measurement core used both by the
//! table harness (`signax tables`) and by `cargo bench`.

use std::time::{Duration, Instant};

/// Statistics over a set of timed runs.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub repeats: usize,
    pub min: Duration,
    pub max: Duration,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean_s = total.as_secs_f64() / n as f64;
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            repeats: n,
            min: samples[0],
            max: samples[n - 1],
            mean: Duration::from_secs_f64(mean_s),
            median: samples[n / 2],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }

    /// The paper's headline number: fastest observed time, in seconds.
    pub fn best_secs(&self) -> f64 {
        self.min.as_secs_f64()
    }
}

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup: usize,
    /// Timed repeats (paper uses 50).
    pub repeats: usize,
    /// Hard wall-clock budget; repeats stop early once exceeded (but at
    /// least `min_repeats` are always taken).
    pub budget: Duration,
    pub min_repeats: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: 2,
            repeats: 50,
            budget: Duration::from_secs(20),
            min_repeats: 3,
        }
    }
}

impl BenchConfig {
    /// Scaled-down protocol for CI / quick runs.
    pub fn quick() -> Self {
        BenchConfig { warmup: 1, repeats: 5, budget: Duration::from_secs(3), min_repeats: 2 }
    }
}

/// Time `f` under the given protocol. A `black_box`-style sink is the
/// caller's responsibility: have `f` return/accumulate something observable.
pub fn bench<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Stats {
    for _ in 0..cfg.warmup {
        f();
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.repeats);
    for i in 0..cfg.repeats {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if i + 1 >= cfg.min_repeats && started.elapsed() > cfg.budget {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human format: seconds with 3 significant figures, like the paper tables.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "-".to_string();
    }
    if s == 0.0 {
        return "0".to_string();
    }
    let digits = 3usize;
    let mag = s.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec, s)
}

/// A row of a benchmark table: one column label -> best-time (or None where
/// the implementation "does not support that operation", printed as a dash,
/// like esig in the paper).
#[derive(Clone, Debug)]
pub struct TableRow {
    pub label: String,
    pub cells: Vec<Option<f64>>,
}

/// A paper-style table: column headers + rows + derived ratio rows.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub col_name: String,
    pub cols: Vec<String>,
    pub rows: Vec<TableRow>,
}

impl Table {
    pub fn new(title: &str, col_name: &str, cols: Vec<String>) -> Table {
        Table { title: title.to_string(), col_name: col_name.to_string(), cols, rows: vec![] }
    }

    pub fn push_row(&mut self, label: &str, cells: Vec<Option<f64>>) {
        assert_eq!(cells.len(), self.cols.len());
        self.rows.push(TableRow { label: label.to_string(), cells });
    }

    /// Add "Ratio <target>" rows: baseline_time / target_time, mirroring the
    /// paper's "Ratio CPU / Ratio GPU" rows (how many times faster than the
    /// strongest competitor `baseline_label` each `target_label` is).
    pub fn push_ratio_rows(&mut self, baseline_label: &str, target_labels: &[&str]) {
        let base: Vec<Option<f64>> = self
            .rows
            .iter()
            .find(|r| r.label == baseline_label)
            .map(|r| r.cells.clone())
            .unwrap_or_else(|| vec![None; self.cols.len()]);
        let mut ratio_rows = vec![];
        for &t in target_labels {
            if let Some(tr) = self.rows.iter().find(|r| r.label == t) {
                let cells: Vec<Option<f64>> = base
                    .iter()
                    .zip(&tr.cells)
                    .map(|(b, v)| match (b, v) {
                        (Some(b), Some(v)) if *v > 0.0 => Some(b / v),
                        _ => None,
                    })
                    .collect();
                ratio_rows.push(TableRow { label: format!("Ratio {t}"), cells });
            }
        }
        self.rows.extend(ratio_rows);
    }

    /// Render in a paper-like fixed-width layout.
    pub fn render(&self) -> String {
        let mut width = self.col_name.len();
        for r in &self.rows {
            width = width.max(r.label.len());
        }
        let cell_w = 10usize;
        let mut s = String::new();
        s.push_str(&format!("## {}\n", self.title));
        s.push_str(&format!("{:<width$}", self.col_name, width = width + 2));
        for c in &self.cols {
            s.push_str(&format!("{c:>cell_w$}"));
        }
        s.push('\n');
        s.push_str(&"-".repeat(width + 2 + cell_w * self.cols.len()));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&format!("{:<width$}", r.label, width = width + 2));
            for c in &r.cells {
                match c {
                    Some(v) => s.push_str(&format!("{:>cell_w$}", fmt_secs(*v))),
                    None => s.push_str(&format!("{:>cell_w$}", "-")),
                }
            }
            s.push('\n');
        }
        s
    }

    /// CSV form for `results/`.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.col_name.to_string());
        for c in &self.cols {
            s.push(',');
            s.push_str(c);
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.label.replace(',', ";"));
            for c in &r.cells {
                s.push(',');
                if let Some(v) = c {
                    s.push_str(&format!("{v}"));
                }
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(10),
            Duration::from_millis(30),
            Duration::from_millis(20),
        ]);
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
        assert_eq!(s.median, Duration::from_millis(20));
        assert!((s.mean.as_secs_f64() - 0.020).abs() < 1e-9);
        assert_eq!(s.repeats, 3);
    }

    #[test]
    fn bench_counts_and_runs() {
        let mut calls = 0;
        let cfg = BenchConfig { warmup: 2, repeats: 5, budget: Duration::from_secs(60), min_repeats: 1 };
        let st = bench(&cfg, || {
            calls += 1;
        });
        assert_eq!(calls, 7); // 2 warmup + 5 timed
        assert_eq!(st.repeats, 5);
    }

    #[test]
    fn bench_budget_stops_early() {
        let cfg = BenchConfig {
            warmup: 0,
            repeats: 1000,
            budget: Duration::from_millis(30),
            min_repeats: 2,
        };
        let st = bench(&cfg, || std::thread::sleep(Duration::from_millis(10)));
        assert!(st.repeats >= 2 && st.repeats < 1000, "repeats={}", st.repeats);
    }

    #[test]
    fn fmt_secs_sigfigs() {
        assert_eq!(fmt_secs(20.9), "20.9");
        assert_eq!(fmt_secs(0.00327), "0.00327");
        assert_eq!(fmt_secs(0.16), "0.160");
        assert_eq!(fmt_secs(f64::NAN), "-");
    }

    #[test]
    fn table_render_and_ratio() {
        let mut t = Table::new("demo", "Channels", vec!["2".into(), "3".into()]);
        t.push_row("base", vec![Some(1.0), None]);
        t.push_row("fast", vec![Some(0.25), Some(0.5)]);
        t.push_ratio_rows("base", &["fast"]);
        let r = t.render();
        assert!(r.contains("Ratio fast"));
        let ratio_row = t.rows.iter().find(|r| r.label == "Ratio fast").unwrap();
        assert_eq!(ratio_row.cells[0], Some(4.0));
        assert_eq!(ratio_row.cells[1], None);
        let csv = t.to_csv();
        assert!(csv.starts_with("Channels,2,3\n"));
    }
}
