//! Infrastructure substrates built from scratch.
//!
//! The build environment is fully offline with only the `xla` and `anyhow`
//! crates vendored, so every piece of supporting infrastructure a project
//! like this would normally pull from crates.io is implemented here:
//!
//! - [`rng`] — deterministic pseudo-random number generation
//!   (SplitMix64 / xoshiro256++), normal variates, shuffles.
//! - [`pool`] — a scoped-thread fork/join helper plus a long-lived worker
//!   thread pool used by the coordinator.
//! - [`cli`] — a small declarative command-line argument parser.
//! - [`benchlib`] — a benchmark harness (warmup, repeats, min/median/mean,
//!   the paper's "repeat 50 times, take the fastest" protocol).
//! - [`propcheck`] — a miniature property-based testing framework.
//! - [`json`] — a JSON parser/serializer for golden-file interchange with
//!   the Python oracle and for results output.

pub mod benchlib;
pub mod cli;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;
