//! Minimal JSON parser and serializer.
//!
//! Used for golden-file interchange with the Python oracle
//! (`artifacts/golden/*.json`, written by `python/compile/aot.py`) and for
//! structured results output. Supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (sufficient for our numeric files).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Interpret an array of numbers as `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Shortest round-trip formatting via Rust's float Display
                    // is fine for interchange with Python.
                    let _ = write!(s, "{x}");
                } else {
                    s.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\r' => s.push_str("\\r"),
                        '\t' => s.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(s, "\\u{:04x}", c as u32);
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(xs) => {
                s.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    x.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow::anyhow!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => anyhow::bail!("expected , or ] got {other:?} at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected , or }} got {other:?} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, 3.0e-5];
        let j = Json::f32s(&xs);
        let back = Json::parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"[[1,[2,[3]]],{"x":{"y":[{}]}}]"#).unwrap();
        assert!(matches!(v, Json::Arr(_)));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn python_style_floats() {
        // Python json.dump writes e.g. 1e-07 and plain integers.
        let v = Json::parse("[1e-07, 42, -0.5]").unwrap();
        let xs = v.as_f32_vec().unwrap();
        assert!((xs[0] - 1e-7).abs() < 1e-12);
        assert_eq!(xs[1], 42.0);
    }
}
