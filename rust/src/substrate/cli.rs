//! A small declarative command-line parser (no external crates offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Parse comma-separated integers, e.g. `--channels 2,3,4`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer {p:?}"))
                })
                .collect(),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A subcommand with its option specs.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: vec![] }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    fn usage(&self, prog: &str) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} {} — {}", prog, self.name, self.about);
        let _ = writeln!(s, "\noptions:");
        for o in &self.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = o.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            let _ = writeln!(s, "  --{}{}\n      {}", o.name, kind, o.help);
        }
        s
    }

    /// Parse raw tokens (after the subcommand name).
    pub fn parse(&self, prog: &str, tokens: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                anyhow::bail!("{}", self.usage(prog));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.usage(prog)))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("--{key} is a flag and takes no value");
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?
                            .clone(),
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        for o in &self.opts {
            if !o.is_flag && args.get(o.name).is_none() {
                match o.default {
                    Some(d) => {
                        args.values.insert(o.name.to_string(), d.to_string());
                    }
                    None => anyhow::bail!("missing required option --{}\n{}", o.name, self.usage(prog)),
                }
            }
        }
        Ok(args)
    }
}

/// Top-level CLI: a set of subcommands.
pub struct Cli {
    pub prog: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n\nsubcommands:", self.prog, self.about);
        for c in &self.commands {
            let _ = writeln!(s, "  {:<14} {}", c.name, c.about);
        }
        let _ = writeln!(s, "\nrun `{} <subcommand> --help` for options", self.prog);
        s
    }

    /// Dispatch: returns (subcommand name, parsed args).
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<(&Command, Args)> {
        let Some(sub) = argv.first() else {
            anyhow::bail!("{}", self.usage());
        };
        if sub == "--help" || sub == "-h" || sub == "help" {
            anyhow::bail!("{}", self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == sub)
            .ok_or_else(|| anyhow::anyhow!("unknown subcommand {sub:?}\n{}", self.usage()))?;
        let args = cmd.parse(self.prog, &argv[1..])?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    fn demo_cmd() -> Command {
        Command::new("run", "demo")
            .opt("depth", "signature depth", "4")
            .req("channels", "path channels")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_values_flags_defaults() {
        let c = demo_cmd();
        let a = c.parse("prog", &toks("--channels 3 --verbose")).unwrap();
        assert_eq!(a.get_usize("depth", 0).unwrap(), 4);
        assert_eq!(a.get_usize("channels", 0).unwrap(), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let c = demo_cmd();
        let a = c.parse("prog", &toks("--channels=5 --depth=9")).unwrap();
        assert_eq!(a.get_usize("channels", 0).unwrap(), 5);
        assert_eq!(a.get_usize("depth", 0).unwrap(), 9);
    }

    #[test]
    fn missing_required_errors() {
        let c = demo_cmd();
        assert!(c.parse("prog", &toks("--depth 2")).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        let c = demo_cmd();
        assert!(c.parse("prog", &toks("--channels 1 --nope 3")).is_err());
    }

    #[test]
    fn bad_integer_errors() {
        let c = demo_cmd();
        let a = c.parse("prog", &toks("--channels x")).unwrap();
        assert!(a.get_usize("channels", 0).is_err());
    }

    #[test]
    fn usize_list() {
        let c = Command::new("t", "t").opt("channels", "", "2,3");
        let a = c.parse("prog", &toks("")).unwrap();
        assert_eq!(a.get_usize_list("channels", &[]).unwrap(), vec![2, 3]);
        let a = c.parse("prog", &toks("--channels 4,5,6")).unwrap();
        assert_eq!(a.get_usize_list("channels", &[]).unwrap(), vec![4, 5, 6]);
    }

    #[test]
    fn cli_dispatch() {
        let cli = Cli {
            prog: "signax",
            about: "test",
            commands: vec![demo_cmd(), Command::new("other", "x")],
        };
        let (cmd, args) = cli.parse(&toks("run --channels 2")).unwrap();
        assert_eq!(cmd.name, "run");
        assert_eq!(args.get_usize("channels", 0).unwrap(), 2);
        assert!(cli.parse(&toks("zzz")).is_err());
        assert!(cli.parse(&[]).is_err());
    }

    #[test]
    fn positional_collected() {
        let c = Command::new("t", "t");
        let a = c.parse("prog", &toks("alpha beta")).unwrap();
        assert_eq!(a.positional(), &["alpha".to_string(), "beta".to_string()]);
    }
}
