//! Threading substrates: scoped fork/join helpers and a long-lived worker
//! pool.
//!
//! The paper's CPU parallelism (§5.1) has two levels: naïve parallelism over
//! the batch dimension, and a chunked parallel reduction over the stream
//! dimension (since ⊠ is associative). Both are expressed with
//! [`parallel_chunks`] / [`parallel_map_indexed`]; the coordinator's worker
//! threads use [`WorkerPool`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Number of worker threads to use by default: the machine's parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `n` items into at most `threads` contiguous chunks of near-equal
/// size. Returns (start, end) pairs; never returns empty chunks.
pub fn chunk_ranges(n: usize, threads: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return vec![];
    }
    let t = threads.max(1).min(n);
    let base = n / t;
    let rem = n % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(chunk_index, start, end)` over near-equal chunks of `0..n` on up
/// to `threads` scoped threads. `f` only gets shared access, so use interior
/// mutability or per-chunk outputs; prefer [`parallel_map_indexed`] when
/// each chunk produces a value.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        if let Some(&(s, e)) = ranges.first() {
            f(0, s, e);
        }
        return;
    }
    std::thread::scope(|scope| {
        for (i, &(s, e)) in ranges.iter().enumerate() {
            let f = &f;
            scope.spawn(move || f(i, s, e));
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
/// Each item is processed exactly once; work is distributed dynamically via
/// an atomic counter so uneven item costs still balance.
pub fn parallel_map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let t = threads.max(1).min(n.max(1));
    if t <= 1 || n <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..t {
            let f = &f;
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so no two threads write the same slot,
                // and the scope guarantees the buffer outlives the threads.
                unsafe { slots_ptr.write(i, Some(v)) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("every slot filled")).collect()
}

/// Mutably split a flat buffer of `n_items` items, each `item_len` long,
/// into per-chunk sub-slices and process chunks in parallel.
/// `f(chunk_index, first_item, items_slice)`.
pub fn parallel_chunks_mut<T, F>(
    buf: &mut [T],
    item_len: usize,
    threads: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert!(item_len > 0 && buf.len() % item_len == 0);
    let n = buf.len() / item_len;
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        if let Some(&(s, e)) = ranges.first() {
            f(0, s, &mut buf[s * item_len..e * item_len]);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = buf;
        let mut consumed = 0usize;
        for (i, &(s, e)) in ranges.iter().enumerate() {
            let (head, tail) = rest.split_at_mut((e - s) * item_len);
            rest = tail;
            debug_assert_eq!(consumed, s * item_len);
            consumed += head.len();
            let f = &f;
            scope.spawn(move || f(i, s, head));
        }
    });
}

struct SendPtr<T>(*mut T);

// Manual Clone/Copy: the derive would add an unwanted `T: Copy` bound.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// SAFETY: caller must guarantee `i` is in bounds and no other thread
    /// accesses index `i` concurrently. Taking `&self` (a method, not field
    /// access) ensures closures capture the whole Send wrapper rather than
    /// the raw pointer field (edition-2021 disjoint capture).
    unsafe fn write(&self, i: usize, v: T) {
        unsafe { *self.0.add(i) = v };
    }
}
// SAFETY: only used with disjoint index writes inside a thread scope.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A long-lived worker pool for the coordinator's background work
/// (artifact compilation, batch execution). Jobs are closures; shutdown is
/// graceful on drop.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            let h = std::thread::Builder::new()
                .name(format!("signax-worker-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool rx lock");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Relaxed);
                        }
                        Err(_) => break, // sender dropped: shut down
                    }
                })
                .expect("spawn worker");
            handles.push(h);
        }
        Self { tx: Some(tx), handles, queued }
    }

    /// Submit a job for execution on some worker.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("worker pool alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 7, 16, 100, 101] {
            for t in [1usize, 2, 3, 8, 200] {
                let rs = chunk_ranges(n, t);
                let mut pos = 0;
                for &(s, e) in &rs {
                    assert_eq!(s, pos);
                    assert!(e > s, "no empty chunks");
                    pos = e;
                }
                assert_eq!(pos, n);
                assert!(rs.len() <= t.max(1));
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        let rs = chunk_ranges(10, 3);
        let sizes: Vec<usize> = rs.iter().map(|&(s, e)| e - s).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn parallel_chunks_visits_all() {
        let sum = AtomicU64::new(0);
        parallel_chunks(1000, 4, |_i, s, e| {
            let local: u64 = (s..e).map(|x| x as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn parallel_map_matches_serial() {
        let par = parallel_map_indexed(257, 8, |i| i * i);
        let ser: Vec<usize> = (0..257).map(|i| i * i).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        assert_eq!(parallel_map_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn parallel_chunks_mut_disjoint_writes() {
        let mut buf = vec![0u32; 12 * 5];
        parallel_chunks_mut(&mut buf, 5, 4, |_c, first, items| {
            for (k, item) in items.chunks_mut(5).enumerate() {
                for v in item.iter_mut() {
                    *v = (first + k) as u32;
                }
            }
        });
        for (i, item) in buf.chunks(5).enumerate() {
            assert!(item.iter().all(|&v| v == i as u32));
        }
    }

    #[test]
    fn worker_pool_runs_jobs_and_drains() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // Drop waits for queue drain via channel close + join.
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }
}
