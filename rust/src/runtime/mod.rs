//! The accelerator runtime: loads AOT-compiled HLO-text artifacts produced
//! by `python/compile/aot.py` and executes them on a PJRT client via the
//! `xla` crate. This is the reproduction's analogue of Signatory's GPU
//! backend (§5.2): the same HLO would run unchanged on a TPU PJRT plugin.
//!
//! - [`artifact`] — the artifact registry (parses `artifacts/MANIFEST.json`).
//! - [`engine`] — PJRT client wrapper with a compile cache and typed
//!   entry points for each artifact kind (sig / siggrad / logsig / train).

pub mod artifact;
pub mod engine;
pub mod handle;

pub use artifact::{ArtifactEntry, ArtifactKind, Registry};
pub use engine::Engine;
pub use handle::EngineHandle;

/// Default artifact directory, relative to the repo root.
pub fn default_artifact_dir() -> std::path::PathBuf {
    // Resolve relative to the executable's cwd; the CLI lets callers
    // override with --artifacts.
    std::path::PathBuf::from("artifacts")
}
