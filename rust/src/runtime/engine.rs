//! PJRT execution engine: compile cache + typed entry points.
//!
//! One `Engine` wraps one `PjRtClient` (CPU here; the same code path would
//! target a TPU plugin). Executables are compiled from HLO text on first
//! use and cached per artifact file.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::artifact::{ArtifactEntry, ArtifactKind, Registry};

/// A PJRT client plus a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> anyhow::Result<Engine> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(
        &self,
        reg: &Registry,
        entry: &ArtifactEntry,
    ) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(&entry.file) {
            return Ok(Arc::clone(e));
        }
        let path = reg.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        let exe = Arc::new(exe);
        self.compiled.lock().unwrap().insert(entry.file.clone(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cache_size(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }

    fn literal(values: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let expected: i64 = dims.iter().product();
        anyhow::ensure!(values.len() as i64 == expected, "literal shape mismatch");
        xla::Literal::vec1(values)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True, so outputs are a tuple.
        lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))
    }

    /// Run a `sig` or `logsig` artifact: `paths` is `(batch, L, d)` flat,
    /// returns `(batch, out_dim)` flat.
    pub fn run_forward(
        &self,
        reg: &Registry,
        entry: &ArtifactEntry,
        paths: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            matches!(entry.kind, ArtifactKind::Sig | ArtifactKind::LogSig),
            "run_forward expects a sig/logsig artifact"
        );
        let exe = self.executable(reg, entry)?;
        let x = Self::literal(
            paths,
            &[entry.batch as i64, entry.length as i64, entry.d as i64],
        )?;
        let outs = Self::run(&exe, &[x])?;
        anyhow::ensure!(outs.len() == 1, "expected 1 output, got {}", outs.len());
        let v = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(v.len() == entry.batch * entry.out_dim, "bad output size");
        Ok(v)
    }

    /// Run a `siggrad` artifact: `(paths, cotangent) -> grad_paths`.
    pub fn run_grad(
        &self,
        reg: &Registry,
        entry: &ArtifactEntry,
        paths: &[f32],
        cotangent: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(entry.kind == ArtifactKind::SigGrad, "run_grad expects siggrad");
        let exe = self.executable(reg, entry)?;
        let x = Self::literal(
            paths,
            &[entry.batch as i64, entry.length as i64, entry.d as i64],
        )?;
        let sig_len: usize = (1..=entry.depth).map(|k| entry.d.pow(k as u32)).sum();
        let g = Self::literal(cotangent, &[entry.batch as i64, sig_len as i64])?;
        let outs = Self::run(&exe, &[x, g])?;
        anyhow::ensure!(outs.len() == 1, "expected 1 output");
        Ok(outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?)
    }

    /// Run the train-step artifact once: consumes parameter buffers and the
    /// batch, returns the loss; `params` is updated in place.
    ///
    /// Parameter layout (matching `model.DeepSigParams`):
    /// `w1 (d, hidden), b1 (hidden), w2 (hidden, d_out), b2 (d_out),
    ///  w_out (sig_len), b_out ()`.
    pub fn run_train_step(
        &self,
        reg: &Registry,
        entry: &ArtifactEntry,
        params: &mut [Vec<f32>],
        x: &[f32],
        y: &[f32],
        lr: f32,
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(entry.kind == ArtifactKind::Train, "run_train_step expects train");
        anyhow::ensure!(params.len() == 6, "expected 6 parameter tensors");
        let exe = self.executable(reg, entry)?;
        let (d_in, h, d_out) = (entry.d, entry.hidden, entry.d_out);
        let sig_len: usize = (1..=entry.depth).map(|k| d_out.pow(k as u32)).sum();
        let shapes: [&[i64]; 6] = [
            &[d_in as i64, h as i64],
            &[h as i64],
            &[h as i64, d_out as i64],
            &[d_out as i64],
            &[sig_len as i64],
            &[],
        ];
        let mut inputs = Vec::with_capacity(9);
        for (p, dims) in params.iter().zip(shapes.iter()) {
            inputs.push(Self::literal(p, dims)?);
        }
        inputs.push(Self::literal(
            x,
            &[entry.batch as i64, entry.length as i64, d_in as i64],
        )?);
        inputs.push(Self::literal(y, &[entry.batch as i64])?);
        inputs.push(xla::Literal::scalar(lr));
        let outs = Self::run(&exe, &inputs)?;
        anyhow::ensure!(outs.len() == 7, "expected 7 outputs, got {}", outs.len());
        for (p, o) in params.iter_mut().zip(&outs[..6]) {
            let v = o.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            anyhow::ensure!(v.len() == p.len(), "parameter shape changed");
            p.copy_from_slice(&v);
        }
        let loss = outs[6]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(loss[0])
    }
}

// Integration tests that need real artifacts live in rust/tests/.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_cpu_initialises() {
        let engine = Engine::cpu().expect("PJRT CPU client");
        assert!(!engine.platform().is_empty());
        assert_eq!(engine.cache_size(), 0);
    }

    #[test]
    fn literal_shape_validation() {
        assert!(Engine::literal(&[1.0, 2.0], &[3]).is_err());
        assert!(Engine::literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).is_ok());
    }
}
