//! A `Send + Sync` handle to the PJRT engine.
//!
//! The `xla` crate's client and executables are `Rc`-based (not `Send`),
//! so the engine lives on a dedicated dispatcher thread and the rest of
//! the system talks to it through this handle. PJRT itself multithreads
//! the actual computation internally; one dispatcher thread does not
//! serialise the math, only the submissions.

use std::path::PathBuf;
use std::sync::mpsc;

use super::artifact::{ArtifactEntry, Registry};
use super::engine::Engine;

enum Job {
    Forward {
        entry: ArtifactEntry,
        inputs: Vec<f32>,
        reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
    },
    Grad {
        entry: ArtifactEntry,
        paths: Vec<f32>,
        cotangent: Vec<f32>,
        reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
    },
    Train {
        entry: ArtifactEntry,
        params: Vec<Vec<f32>>,
        x: Vec<f32>,
        y: Vec<f32>,
        lr: f32,
        reply: mpsc::Sender<anyhow::Result<(Vec<Vec<f32>>, f32)>>,
    },
    /// Pre-compile an artifact (warm the cache) and report success.
    Warm { entry: ArtifactEntry, reply: mpsc::Sender<anyhow::Result<()>> },
}

/// Cloneable, thread-safe handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
    platform: String,
}

impl EngineHandle {
    /// Spawn the engine thread: creates the PJRT CPU client and loads the
    /// registry there. Fails fast if either fails.
    pub fn spawn(artifact_dir: PathBuf) -> anyhow::Result<(EngineHandle, Registry)> {
        // Registry is plain data: parse it here so callers can route.
        let registry = Registry::load(&artifact_dir)?;
        let registry_for_thread = Registry::load(&artifact_dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<String>>();
        std::thread::Builder::new()
            .name("signax-engine".into())
            .spawn(move || {
                let engine = match Engine::cpu() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.platform()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let reg = registry_for_thread;
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Forward { entry, inputs, reply } => {
                            let _ = reply.send(engine.run_forward(&reg, &entry, &inputs));
                        }
                        Job::Grad { entry, paths, cotangent, reply } => {
                            let _ = reply.send(engine.run_grad(&reg, &entry, &paths, &cotangent));
                        }
                        Job::Train { entry, mut params, x, y, lr, reply } => {
                            let res = engine
                                .run_train_step(&reg, &entry, &mut params, &x, &y, lr)
                                .map(|loss| (params, loss));
                            let _ = reply.send(res);
                        }
                        Job::Warm { entry, reply } => {
                            let _ = reply.send(engine.executable(&reg, &entry).map(|_| ()));
                        }
                    }
                }
            })
            .map_err(|e| anyhow::anyhow!("spawn engine thread: {e}"))?;
        let platform = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during init"))??;
        Ok((EngineHandle { tx, platform }, registry))
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    fn send_and_wait<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<anyhow::Result<T>>) -> Job,
    ) -> anyhow::Result<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("engine thread dropped reply"))?
    }

    /// Run a sig/logsig artifact on a full `(batch, L, d)` input.
    pub fn forward(&self, entry: &ArtifactEntry, inputs: Vec<f32>) -> anyhow::Result<Vec<f32>> {
        self.send_and_wait(|reply| Job::Forward { entry: entry.clone(), inputs, reply })
    }

    /// Run a siggrad artifact.
    pub fn grad(
        &self,
        entry: &ArtifactEntry,
        paths: Vec<f32>,
        cotangent: Vec<f32>,
    ) -> anyhow::Result<Vec<f32>> {
        self.send_and_wait(|reply| Job::Grad { entry: entry.clone(), paths, cotangent, reply })
    }

    /// Run the train-step artifact; returns updated params and the loss.
    pub fn train_step(
        &self,
        entry: &ArtifactEntry,
        params: Vec<Vec<f32>>,
        x: Vec<f32>,
        y: Vec<f32>,
        lr: f32,
    ) -> anyhow::Result<(Vec<Vec<f32>>, f32)> {
        self.send_and_wait(|reply| Job::Train { entry: entry.clone(), params, x, y, lr, reply })
    }

    /// Compile an artifact ahead of use.
    pub fn warm(&self, entry: &ArtifactEntry) -> anyhow::Result<()> {
        self.send_and_wait(|reply| Job::Warm { entry: entry.clone(), reply })
    }
}
