//! Artifact registry: discovers what the AOT pipeline produced.

use std::path::{Path, PathBuf};

use crate::substrate::json::Json;

/// What a lowered graph computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(b, L, d) -> (b, sig_len)`.
    Sig,
    /// `(b, L, d), (b, sig_len) -> (b, L, d)` — signature VJP.
    SigGrad,
    /// `(b, L, d) -> (b, witt)` — Words-basis logsignature.
    LogSig,
    /// Deep-signature train step: `(params..., x, y, lr) -> (params..., loss)`.
    Train,
}

impl ArtifactKind {
    fn parse(s: &str) -> anyhow::Result<ArtifactKind> {
        Ok(match s {
            "sig" => ArtifactKind::Sig,
            "siggrad" => ArtifactKind::SigGrad,
            "logsig" => ArtifactKind::LogSig,
            "train" => ArtifactKind::Train,
            other => anyhow::bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One entry of `MANIFEST.json`.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub file: String,
    pub kind: ArtifactKind,
    pub batch: usize,
    pub length: usize,
    pub d: usize,
    pub depth: usize,
    pub out_dim: usize,
    /// Whether the L1 Pallas kernel (vs the jnp path) was lowered into it.
    pub pallas: bool,
    /// Train-artifact extras.
    pub hidden: usize,
    pub d_out: usize,
}

/// The set of available artifacts.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Registry {
    /// Load `MANIFEST.json` from an artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Registry> {
        let manifest = dir.join("MANIFEST.json");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| anyhow::anyhow!("cannot read {manifest:?}: {e}; run `make artifacts`"))?;
        let json = Json::parse(&text)?;
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("MANIFEST.json missing artifacts array"))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            let get_usize = |k: &str| a.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            entries.push(ArtifactEntry {
                file: a
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("artifact missing file"))?
                    .to_string(),
                kind: ArtifactKind::parse(
                    a.get("kind").and_then(|v| v.as_str()).unwrap_or("?"),
                )?,
                batch: get_usize("b"),
                length: get_usize("length"),
                d: get_usize("d"),
                depth: get_usize("depth"),
                out_dim: get_usize("out_dim"),
                pallas: matches!(a.get("pallas"), Some(Json::Bool(true))),
                hidden: get_usize("hidden"),
                d_out: get_usize("d_out"),
            });
        }
        Ok(Registry { dir: dir.to_path_buf(), entries })
    }

    /// Find an artifact matching kind and shapes exactly.
    pub fn find(
        &self,
        kind: ArtifactKind,
        batch: usize,
        length: usize,
        d: usize,
        depth: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == kind && e.batch == batch && e.length == length && e.d == d && e.depth == depth
        })
    }

    /// Find an artifact of the right (kind, length, d, depth) whose batch
    /// is at least `min_batch` — used by the dynamic batcher, which pads.
    /// Prefers the *largest* batch so concurrent requests coalesce into one
    /// execution (the linger deadline bounds the latency cost for sparse
    /// traffic).
    pub fn find_batchable(
        &self,
        kind: ArtifactKind,
        min_batch: usize,
        length: usize,
        d: usize,
        depth: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.length == length
                    && e.d == d
                    && e.depth == depth
                    && e.batch >= min_batch
            })
            .max_by_key(|e| e.batch)
    }

    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// The train artifact, if present.
    pub fn train(&self) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind == ArtifactKind::Train)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("MANIFEST.json"), body).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join(format!("signax-reg-{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"artifacts": [
                {"file": "sig_a.hlo.txt", "kind": "sig", "b": 32, "length": 128, "d": 4, "depth": 4, "out_dim": 340, "pallas": true},
                {"file": "sig_b.hlo.txt", "kind": "sig", "b": 8, "length": 128, "d": 4, "depth": 4, "out_dim": 340},
                {"file": "train.hlo.txt", "kind": "train", "b": 32, "length": 64, "d": 2, "depth": 3, "out_dim": 0, "hidden": 16, "d_out": 4}
            ], "sweep": "small"}"#,
        );
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.entries.len(), 3);
        let e = reg.find(ArtifactKind::Sig, 32, 128, 4, 4).unwrap();
        assert!(e.pallas);
        assert!(reg.find(ArtifactKind::Sig, 16, 128, 4, 4).is_none());
        // Batchable: the largest artifact that fits (coalescing-friendly).
        let e = reg.find_batchable(ArtifactKind::Sig, 3, 128, 4, 4).unwrap();
        assert_eq!(e.batch, 32);
        let e = reg.find_batchable(ArtifactKind::Sig, 9, 128, 4, 4).unwrap();
        assert_eq!(e.batch, 32);
        assert!(reg.find_batchable(ArtifactKind::Sig, 33, 128, 4, 4).is_none());
        let t = reg.train().unwrap();
        assert_eq!(t.hidden, 16);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Registry::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn rejects_unknown_kind() {
        let dir = std::env::temp_dir().join(format!("signax-reg2-{}", std::process::id()));
        write_manifest(&dir, r#"{"artifacts": [{"file": "x", "kind": "zzz"}]}"#);
        assert!(Registry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
