//! The deep signature model (§6.2) natively in Rust, with a pluggable
//! signature backend so Fig. 3's Signatory-vs-iisignature training
//! comparison can be reproduced on like-for-like resources:
//!
//! - model: pointwise feedforward (tanh) swept over the sequence → hidden
//!   path → `Sig^N` (or, with [`ModelConfig::logsig`], the Words-basis
//!   `LogSig^N` — §4.3's compressed readout) → learnt linear map → binary
//!   logit; BCE loss; SGD.
//! - backward: fully handwritten — BCE/linear/tanh VJPs here, the
//!   signature VJP from [`crate::signature::backward`] (reversibility) or
//!   from [`crate::baselines::iisignature_like`] (tape) depending on the
//!   selected backend; the logsig readout adds the projection-transpose +
//!   tensor-log VJP epilogue from [`crate::logsignature`].
//! - execution: with the Fused backend at `threads <= batch`, the
//!   signature forward and VJP run **lane-fused across the batch**
//!   ([`crate::ta::batch`]) — one interleaved sweep instead of per-sample
//!   scalar loops — bitwise identical to per-sample dispatch. The logsig
//!   readout batches through the same sweep (PR 5): its per-sample
//!   epilogue runs on the lane-fused signatures, so the logsig-readout
//!   train path is batched too, and stays bitwise per-sample-identical.
//!
//! The same model can instead be trained through the AOT XLA artifact via
//! [`crate::runtime::Engine::run_train_step`]; an integration test pins the
//! two training paths to each other.

use crate::baselines::iisignature_like;
use crate::exec::{ExecPlan, ExecPlanner, WorkShape};
use crate::logsignature::batch::project_sigs_into;
use crate::logsignature::{
    logsignature_from_sig, logsignature_from_sig_vjp, LogSigPlan, WordsPlanCache,
};
use crate::signature::{
    signature, signature_batch, signature_batch_vjp, signature_vjp_with, signature_with, SigConfig,
};
use crate::substrate::pool::parallel_map_indexed;
use crate::substrate::rng::Rng;
use crate::ta::SigSpec;
use crate::words::witt_dimension;
use std::sync::{Arc, OnceLock};

/// Process-wide Words-basis plan cache for the logsig readout: the plan
/// depends only on `(d_out, depth)`, but `train_step` and `accuracy` run
/// once per step/evaluation — build each plan once and reuse it forever.
/// Same [`WordsPlanCache`] type the coordinator's serving layer uses, so
/// the caching logic exists exactly once.
fn words_plan(d: usize, depth: usize) -> Arc<LogSigPlan> {
    static CACHE: OnceLock<WordsPlanCache> = OnceLock::new();
    CACHE
        .get_or_init(WordsPlanCache::new)
        .get(d, depth)
        .expect("valid spec")
}

/// Which signature implementation the training loop uses (Fig. 3's two curves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SigBackend {
    /// signax: fused forward + reversibility backward.
    Fused,
    /// iisignature-profile: conventional forward + tape backward.
    Conventional,
}

/// Model hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    pub d_in: usize,
    pub hidden: usize,
    pub d_out: usize,
    pub depth: usize,
    /// Read the model out of the **Words-basis logsignature** of the
    /// hidden path instead of the raw signature (§4.3: same information,
    /// `witt_dimension` coefficients instead of `sig_len` — a much smaller
    /// linear head at depth > 2). Native backends only; the XLA train
    /// artifact keeps the signature readout.
    pub logsig: bool,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { d_in: 2, hidden: 16, d_out: 4, depth: 3, logsig: false }
    }
}

impl ModelConfig {
    /// Width of the readout feature vector (`sig_len`, or the Lyndon-word
    /// count under the logsig readout).
    pub fn feature_dim(&self) -> usize {
        if self.logsig {
            witt_dimension(self.d_out, self.depth)
        } else {
            SigSpec::new(self.d_out, self.depth).expect("valid spec").sig_len()
        }
    }
}

/// Flat parameter container (layout mirrors `model.DeepSigParams` on the
/// Python side, so the same buffers drive the XLA train artifact).
#[derive(Clone, Debug)]
pub struct Params {
    pub w1: Vec<f32>,    // (d_in, hidden)
    pub b1: Vec<f32>,    // (hidden,)
    pub w2: Vec<f32>,    // (hidden, d_out)
    pub b2: Vec<f32>,    // (d_out,)
    pub w_out: Vec<f32>, // (feature_dim,) = sig_len, or witt dim with the logsig readout
    pub b_out: f32,
}

impl Params {
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Params {
        let fd = cfg.feature_dim();
        Params {
            w1: rng.normal_vec(cfg.d_in * cfg.hidden, (2.0 / cfg.d_in as f32).sqrt()),
            b1: vec![0.0; cfg.hidden],
            w2: rng.normal_vec(cfg.hidden * cfg.d_out, (2.0 / cfg.hidden as f32).sqrt()),
            b2: vec![0.0; cfg.d_out],
            w_out: rng.normal_vec(fd, (1.0 / fd as f32).sqrt()),
            b_out: 0.0,
        }
    }

    /// As the positional buffer list the XLA train artifact consumes.
    pub fn to_buffers(&self) -> Vec<Vec<f32>> {
        vec![
            self.w1.clone(),
            self.b1.clone(),
            self.w2.clone(),
            self.b2.clone(),
            self.w_out.clone(),
            vec![self.b_out],
        ]
    }

    pub fn from_buffers(_cfg: &ModelConfig, bufs: &[Vec<f32>]) -> Params {
        Params {
            w1: bufs[0].clone(),
            b1: bufs[1].clone(),
            w2: bufs[2].clone(),
            b2: bufs[3].clone(),
            w_out: bufs[4].clone(),
            b_out: bufs[5][0],
        }
    }
}

struct SampleGrad {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    w_out: Vec<f32>,
    b_out: f32,
    loss: f32,
}

/// Pointwise MLP forward for one sample: `pre1 = x W1 + b1`,
/// `a = tanh(pre1)`, `hid = a W2 + b2`. Returns `(a (L, hidden),
/// hid (L, d_out))`.
fn mlp_forward(cfg: &ModelConfig, p: &Params, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let (d_in, h, d_out) = (cfg.d_in, cfg.hidden, cfg.d_out);
    let l = x.len() / d_in;
    let mut a = vec![0.0f32; l * h];
    let mut hid = vec![0.0f32; l * d_out];
    for t in 0..l {
        for j in 0..h {
            let mut acc = p.b1[j];
            for c in 0..d_in {
                acc += x[t * d_in + c] * p.w1[c * h + j];
            }
            a[t * h + j] = acc.tanh();
        }
        for o in 0..d_out {
            let mut acc = p.b2[o];
            for j in 0..h {
                acc += a[t * h + j] * p.w2[j * d_out + o];
            }
            hid[t * d_out + o] = acc;
        }
    }
    (a, hid)
}

/// Pointwise MLP backward for one sample given `∂L/∂hid`; returns
/// `(g_w1, g_b1, g_w2, g_b2)`.
fn mlp_backward(
    cfg: &ModelConfig,
    p: &Params,
    x: &[f32],
    a: &[f32],
    g_hid: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (d_in, h, d_out) = (cfg.d_in, cfg.hidden, cfg.d_out);
    let l = x.len() / d_in;
    let mut g_w1 = vec![0.0f32; d_in * h];
    let mut g_b1 = vec![0.0f32; h];
    let mut g_w2 = vec![0.0f32; h * d_out];
    let mut g_b2 = vec![0.0f32; d_out];
    for t in 0..l {
        // g wrt a: g_hid W2^T; then through tanh.
        for j in 0..h {
            let mut ga = 0.0f32;
            for o in 0..d_out {
                ga += g_hid[t * d_out + o] * p.w2[j * d_out + o];
            }
            let aj = a[t * h + j];
            let gpre = ga * (1.0 - aj * aj);
            g_b1[j] += gpre;
            for c in 0..d_in {
                g_w1[c * h + j] += x[t * d_in + c] * gpre;
            }
        }
        for o in 0..d_out {
            let go = g_hid[t * d_out + o];
            g_b2[o] += go;
            for j in 0..h {
                g_w2[j * d_out + o] += a[t * h + j] * go;
            }
        }
    }
    (g_w1, g_b1, g_w2, g_b2)
}

/// BCE-with-logits head shared by both gradient paths: returns
/// `(loss, dL/dlogit)`.
#[inline]
fn bce_head(logit: f32, y: f32) -> (f32, f32) {
    let loss = logit.max(0.0) - logit * y + (-logit.abs()).exp().ln_1p();
    let dlogit = 1.0 / (1.0 + (-logit).exp()) - y;
    (loss, dlogit)
}

/// One forward/backward for one sample, returning per-parameter gradients.
/// `sig_threads > 1` runs the signature forward and VJP stream-parallel
/// (Fused backend only; the conventional tape baseline is inherently
/// serial over the stream). With a logsig readout (`lplan`), the features
/// are the Words-basis logsignature of the hidden path and the basis
/// cotangent flows back through the projection + tensor-log VJP epilogue
/// before the signature VJP — on either backend, since the epilogue only
/// needs the forward signature.
#[allow(clippy::too_many_arguments)]
fn sample_grad(
    cfg: &ModelConfig,
    spec: &SigSpec,
    p: &Params,
    x: &[f32], // (L, d_in)
    y: f32,
    backend: SigBackend,
    sig_threads: usize,
    lplan: Option<&LogSigPlan>,
) -> SampleGrad {
    let d_out = cfg.d_out;
    let (a, hid) = mlp_forward(cfg, p, x);
    let l = hid.len() / d_out;
    let sig_cfg = SigConfig::parallel(sig_threads.max(1));
    let sig = match backend {
        SigBackend::Fused if sig_threads > 1 => {
            signature_with(&hid, l, spec, &sig_cfg).expect("valid hidden path")
        }
        SigBackend::Fused => signature(&hid, l, spec),
        SigBackend::Conventional => iisignature_like::signature(&hid, l, spec),
    };
    let feat_owned;
    let feat: &[f32] = match lplan {
        Some(lp) => {
            feat_owned =
                logsignature_from_sig(&sig, spec, lp).expect("plan built for the model spec");
            &feat_owned
        }
        None => &sig,
    };
    let logit: f32 = feat.iter().zip(&p.w_out).map(|(&s, &w)| s * w).sum::<f32>() + p.b_out;
    let (loss, dlogit) = bce_head(logit, y);

    // Backward: linear head on the readout features.
    let g_w_out: Vec<f32> = feat.iter().map(|&s| s * dlogit).collect();
    let g_feat: Vec<f32> = p.w_out.iter().map(|&w| w * dlogit).collect();
    // Basis cotangent -> signature cotangent (identity without logsig).
    let g_sig = match lplan {
        Some(lp) => logsignature_from_sig_vjp(&sig, spec, lp, &g_feat)
            .expect("plan built for the model spec"),
        None => g_feat,
    };
    // Signature VJP (stream-parallel via the chunked Chen identity when
    // sig_threads > 1; see crate::signature::backward).
    let g_hid = match backend {
        SigBackend::Fused => {
            signature_vjp_with(&hid, l, spec, &sig_cfg, &g_sig)
                .expect("valid hidden path")
                .grad_path
        }
        SigBackend::Conventional => iisignature_like::signature_vjp(&hid, l, spec, &g_sig),
    };
    let (g_w1, g_b1, g_w2, g_b2) = mlp_backward(cfg, p, x, &a, &g_hid);
    SampleGrad { w1: g_w1, b1: g_b1, w2: g_w2, b2: g_b2, w_out: g_w_out, b_out: dlogit, loss }
}

/// Batched gradients through the **lane-fused engine**: the MLP stages run
/// per-sample in parallel, but the signature forward and VJP — the
/// dominant cost — each run as one lane-interleaved batched sweep across
/// all samples ([`crate::ta::batch`]), vectorising over the batch instead
/// of leaving each core's SIMD lanes idle on a scalar Horner loop. The
/// signature results are bitwise identical to the per-sample path, so this
/// is a pure execution-strategy change.
fn train_grads_lane_fused(
    cfg: &ModelConfig,
    spec: &SigSpec,
    p: &Params,
    x: &[f32],
    y: &[f32],
    threads: usize,
    lplan: Option<&LogSigPlan>,
) -> Vec<SampleGrad> {
    let (d_in, d_out) = (cfg.d_in, cfg.d_out);
    let batch = y.len();
    let sample_len = x.len() / batch;
    let l = sample_len / d_in;
    let fwd = parallel_map_indexed(batch, threads, |b| {
        mlp_forward(cfg, p, &x[b * sample_len..(b + 1) * sample_len])
    });
    let mut hid_all = vec![0.0f32; batch * l * d_out];
    for (b, (_, hid)) in fwd.iter().enumerate() {
        hid_all[b * l * d_out..(b + 1) * l * d_out].copy_from_slice(hid);
    }
    let sigs =
        signature_batch(&hid_all, batch, l, spec, threads).expect("valid hidden paths");
    let len = spec.sig_len();
    // Logsig readout: one lane-fused sweep computed the signatures above;
    // the per-sample log + projection epilogue (and its transpose below)
    // is shared with the scalar path, so features — and therefore the
    // whole update — stay bitwise identical to per-sample dispatch.
    let feat_dim = lplan.map_or(len, |lp| lp.dim());
    let feats: Option<Vec<f32>> = lplan.map(|lp| {
        // The shared per-lane log + projection epilogue (the same code
        // logsignature_batch_planned runs), so features stay bitwise
        // identical to the scalar per-sample path.
        let mut f = vec![0.0f32; batch * feat_dim];
        project_sigs_into(spec, lp, &sigs, batch, &mut f);
        f
    });
    let feat_of = |b: usize| -> &[f32] {
        match &feats {
            Some(f) => &f[b * feat_dim..(b + 1) * feat_dim],
            None => &sigs[b * len..(b + 1) * len],
        }
    };
    let mut losses = vec![0.0f32; batch];
    let mut dlogits = vec![0.0f32; batch];
    let mut g_sig_all = vec![0.0f32; batch * len];
    let mut g_feat = vec![0.0f32; feat_dim]; // reused basis-cotangent buffer
    for b in 0..batch {
        let feat = feat_of(b);
        let logit: f32 = feat.iter().zip(&p.w_out).map(|(&s, &w)| s * w).sum::<f32>() + p.b_out;
        let (loss, dlogit) = bce_head(logit, y[b]);
        losses[b] = loss;
        dlogits[b] = dlogit;
        match lplan {
            Some(lp) => {
                for (gf, &w) in g_feat.iter_mut().zip(&p.w_out) {
                    *gf = w * dlogit;
                }
                let g = logsignature_from_sig_vjp(&sigs[b * len..(b + 1) * len], spec, lp, &g_feat)
                    .expect("plan built for the model spec");
                g_sig_all[b * len..(b + 1) * len].copy_from_slice(&g);
            }
            None => {
                for (gs, &w) in g_sig_all[b * len..(b + 1) * len].iter_mut().zip(&p.w_out) {
                    *gs = w * dlogit;
                }
            }
        }
    }
    let g_hid_all = signature_batch_vjp(&hid_all, batch, l, spec, &g_sig_all, threads)
        .expect("valid hidden paths");
    parallel_map_indexed(batch, threads, |b| {
        let (a, _) = &fwd[b];
        let (w1, b1, w2, b2) = mlp_backward(
            cfg,
            p,
            &x[b * sample_len..(b + 1) * sample_len],
            a,
            &g_hid_all[b * l * d_out..(b + 1) * l * d_out],
        );
        SampleGrad {
            w1,
            b1,
            w2,
            b2,
            w_out: feat_of(b).iter().map(|&s| s * dlogits[b]).collect(),
            b_out: dlogits[b],
            loss: losses[b],
        }
    })
}

/// One SGD step over a batch. Returns the mean loss.
///
/// The execution strategy for the signature forward/VJP — the dominant
/// cost — comes from [`crate::exec::ExecPlanner`]: a lane-fused plan runs
/// both **lane-fused** across the batch (one interleaved sweep per
/// increment; see [`crate::ta::batch`]) with the MLP stages parallel over
/// samples; a stream-parallel plan (surplus threads, `threads > batch`)
/// runs each sample's chunked Chen-identity forward/backward (App. C.3
/// plus the stream dimension); a scalar plan runs serial per-sample
/// sweeps, parallel over the batch. Every strategy produces the same
/// update (lane-fused is bitwise identical to per-sample dispatch) — the
/// logsig readout included, since its log/projection epilogue and its
/// transpose run per sample on the batched sweep's signatures. The
/// Conventional backend ignores lane plans — the tape baseline has no
/// lane kernels — and dispatches per sample.
pub fn train_step(
    cfg: &ModelConfig,
    p: &mut Params,
    x: &[f32], // (batch, L, d_in)
    y: &[f32],
    lr: f32,
    backend: SigBackend,
    threads: usize,
) -> f32 {
    let batch = y.len();
    let sample_len = x.len() / batch;
    let spec = SigSpec::new(cfg.d_out, cfg.depth).expect("valid spec");
    // One cached Words-basis plan, shared across every sample, step, and
    // both execution paths (see [`words_plan`]).
    let lplan = cfg.logsig.then(|| words_plan(cfg.d_out, cfg.depth));
    let planner = ExecPlanner::new(threads);
    let plan = planner.plan_backward(&WorkShape {
        batch,
        points: sample_len / cfg.d_in,
        d: cfg.d_out,
        depth: cfg.depth,
        dtype: crate::ta::Precision::F32,
    });
    let grads = match plan {
        ExecPlan::LaneFused { .. } if backend == SigBackend::Fused => {
            train_grads_lane_fused(cfg, &spec, p, x, y, planner.threads(), lplan.as_deref())
        }
        plan => {
            // Stream parallelism inside each sample when the plan grants
            // it (Fused backend only; the conventional tape baseline is
            // inherently serial over the stream).
            let sig_threads = match plan {
                ExecPlan::StreamParallel { threads } => threads,
                _ => 1,
            };
            parallel_map_indexed(batch, planner.threads(), |b| {
                sample_grad(
                    cfg,
                    &spec,
                    p,
                    &x[b * sample_len..(b + 1) * sample_len],
                    y[b],
                    backend,
                    sig_threads,
                    lplan.as_deref(),
                )
            })
        }
    };
    let scale = lr / batch as f32;
    let mut mean_loss = 0.0f32;
    for g in &grads {
        mean_loss += g.loss;
        for (w, gv) in p.w1.iter_mut().zip(&g.w1) {
            *w -= scale * gv;
        }
        for (w, gv) in p.b1.iter_mut().zip(&g.b1) {
            *w -= scale * gv;
        }
        for (w, gv) in p.w2.iter_mut().zip(&g.w2) {
            *w -= scale * gv;
        }
        for (w, gv) in p.b2.iter_mut().zip(&g.b2) {
            *w -= scale * gv;
        }
        for (w, gv) in p.w_out.iter_mut().zip(&g.w_out) {
            *w -= scale * gv;
        }
        p.b_out -= scale * g.b_out;
    }
    mean_loss / batch as f32
}

/// Classification accuracy over a batch.
pub fn accuracy(cfg: &ModelConfig, p: &Params, x: &[f32], y: &[f32]) -> f32 {
    let batch = y.len();
    let sample_len = x.len() / batch;
    let spec = SigSpec::new(cfg.d_out, cfg.depth).expect("valid spec");
    let lplan = cfg.logsig.then(|| words_plan(cfg.d_out, cfg.depth));
    let mut correct = 0usize;
    for b in 0..batch {
        let logit = forward_logit(
            cfg,
            &spec,
            p,
            &x[b * sample_len..(b + 1) * sample_len],
            lplan.as_deref(),
        );
        if (logit > 0.0) == (y[b] > 0.5) {
            correct += 1;
        }
    }
    correct as f32 / batch as f32
}

/// Forward pass to the logit for one sample. `lplan` must be `Some` with
/// a Words-basis plan exactly when `cfg.logsig` is set — enforced by an
/// assert, because a mismatch would otherwise silently `zip` a readout of
/// one width against weights of another and return a confident nonsense
/// logit (go through [`accuracy`] / [`train_step`], which resolve the
/// cached plan themselves, when in doubt).
pub fn forward_logit(
    cfg: &ModelConfig,
    spec: &SigSpec,
    p: &Params,
    x: &[f32],
    lplan: Option<&LogSigPlan>,
) -> f32 {
    assert_eq!(
        lplan.is_some(),
        cfg.logsig,
        "forward_logit: pass a Words-basis plan exactly when cfg.logsig is set"
    );
    let (d_in, h, d_out) = (cfg.d_in, cfg.hidden, cfg.d_out);
    let l = x.len() / d_in;
    let mut hid = vec![0.0f32; l * d_out];
    for t in 0..l {
        let mut at = vec![0.0f32; h];
        for j in 0..h {
            let mut acc = p.b1[j];
            for c in 0..d_in {
                acc += x[t * d_in + c] * p.w1[c * h + j];
            }
            at[j] = acc.tanh();
        }
        for o in 0..d_out {
            let mut acc = p.b2[o];
            for j in 0..h {
                acc += at[j] * p.w2[j * d_out + o];
            }
            hid[t * d_out + o] = acc;
        }
    }
    let sig = signature(&hid, l, spec);
    let feat = match lplan {
        Some(lp) => logsignature_from_sig(&sig, spec, lp).expect("plan built for the model spec"),
        None => sig,
    };
    feat.iter().zip(&p.w_out).map(|(&s, &w)| s * w).sum::<f32>() + p.b_out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gbm::{gbm_batch, GbmConfig};

    #[test]
    fn training_decreases_loss_and_learns() {
        let cfg = ModelConfig { d_in: 2, hidden: 8, d_out: 3, depth: 2, logsig: false };
        let mut rng = Rng::new(42);
        let mut p = Params::init(&cfg, &mut rng);
        let gcfg = GbmConfig { stream: 32, ..Default::default() };
        let (x, y) = gbm_batch(&mut rng, 64, &gcfg);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..120 {
            last = train_step(&cfg, &mut p, &x, &y, 1.0, SigBackend::Fused, 4);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap(), "loss {first:?} -> {last}");
        assert!(accuracy(&cfg, &p, &x, &y) > 0.6);
    }

    #[test]
    fn backends_produce_identical_updates() {
        // Fused and conventional backends compute the same math — one step
        // from identical params must produce (nearly) identical params.
        let cfg = ModelConfig { d_in: 2, hidden: 4, d_out: 2, depth: 3, logsig: false };
        let mut rng = Rng::new(3);
        let p0 = Params::init(&cfg, &mut rng);
        let (x, y) = gbm_batch(&mut rng, 8, &GbmConfig { stream: 16, ..Default::default() });
        let mut pa = p0.clone();
        let mut pb = p0.clone();
        let la = train_step(&cfg, &mut pa, &x, &y, 0.1, SigBackend::Fused, 2);
        let lb = train_step(&cfg, &mut pb, &x, &y, 0.1, SigBackend::Conventional, 2);
        assert!((la - lb).abs() < 1e-4, "loss {la} vs {lb}");
        for (a, b) in pa.w_out.iter().zip(&pb.w_out) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in pa.w1.iter().zip(&pb.w1) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn undersubscribed_batch_trains_with_stream_parallel_backward() {
        // batch 2 with 8 threads routes 4 threads into each sample's
        // stream; one step must match the serial-per-sample step closely.
        let cfg = ModelConfig { d_in: 2, hidden: 4, d_out: 2, depth: 3, logsig: false };
        let mut rng = Rng::new(17);
        let p0 = Params::init(&cfg, &mut rng);
        let (x, y) = gbm_batch(&mut rng, 2, &GbmConfig { stream: 64, ..Default::default() });
        let mut pa = p0.clone();
        let mut pb = p0.clone();
        let la = train_step(&cfg, &mut pa, &x, &y, 0.1, SigBackend::Fused, 8);
        let lb = train_step(&cfg, &mut pb, &x, &y, 0.1, SigBackend::Fused, 2);
        // f32 reassociation in the chunked forward/backward: hold the same
        // relative envelope as the other parallel-vs-serial tests (2e-3).
        assert!((la - lb).abs() < 2e-3 * (1.0 + lb.abs()), "loss {la} vs {lb}");
        for (a, b) in pa.w1.iter().zip(&pb.w1) {
            assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
        for (a, b) in pa.w_out.iter().zip(&pb.w_out) {
            assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn lane_fused_grads_match_per_sample_bitwise() {
        // The lane-fused batched gradients must equal the per-sample path
        // bit-for-bit: the batched signature kernels perform each lane's
        // ops in the scalar order, and the MLP/head math is shared code.
        let cfg = ModelConfig { d_in: 2, hidden: 4, d_out: 2, depth: 3, logsig: false };
        let mut rng = Rng::new(29);
        let p = Params::init(&cfg, &mut rng);
        let (x, y) = gbm_batch(&mut rng, 6, &GbmConfig { stream: 12, ..Default::default() });
        let spec = SigSpec::new(2, 3).unwrap();
        let lane = train_grads_lane_fused(&cfg, &spec, &p, &x, &y, 3, None);
        let sample_len = x.len() / y.len();
        for (b, g) in lane.iter().enumerate() {
            let single = sample_grad(
                &cfg,
                &spec,
                &p,
                &x[b * sample_len..(b + 1) * sample_len],
                y[b],
                SigBackend::Fused,
                1,
                None,
            );
            assert_eq!(g.w1, single.w1, "sample {b} w1");
            assert_eq!(g.b1, single.b1);
            assert_eq!(g.w2, single.w2);
            assert_eq!(g.b2, single.b2);
            assert_eq!(g.w_out, single.w_out);
            assert_eq!(g.b_out, single.b_out);
            assert_eq!(g.loss, single.loss);
        }
    }

    #[test]
    fn logsig_readout_lane_fused_matches_per_sample_bitwise() {
        // The logsig-readout train path now batches (PR 5): its lane-fused
        // gradients must equal the per-sample path bit-for-bit, exactly
        // like the signature readout — the epilogue is shared code run on
        // bitwise-identical signatures.
        let cfg = ModelConfig { d_in: 2, hidden: 4, d_out: 2, depth: 3, logsig: true };
        let spec = SigSpec::new(2, 3).unwrap();
        let lplan = LogSigPlan::new(&spec, crate::logsignature::LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(37);
        let p = Params::init(&cfg, &mut rng);
        assert_eq!(p.w_out.len(), witt_dimension(2, 3));
        let (x, y) = gbm_batch(&mut rng, 6, &GbmConfig { stream: 12, ..Default::default() });
        let lane = train_grads_lane_fused(&cfg, &spec, &p, &x, &y, 3, Some(&lplan));
        let sample_len = x.len() / y.len();
        for (b, g) in lane.iter().enumerate() {
            let single = sample_grad(
                &cfg,
                &spec,
                &p,
                &x[b * sample_len..(b + 1) * sample_len],
                y[b],
                SigBackend::Fused,
                1,
                Some(&lplan),
            );
            assert_eq!(g.w1, single.w1, "sample {b} w1");
            assert_eq!(g.w_out, single.w_out, "sample {b} w_out");
            assert_eq!(g.b_out, single.b_out);
            assert_eq!(g.loss, single.loss);
        }
    }

    #[test]
    fn logsig_readout_trains() {
        // The compressed head still learns: loss decreases and accuracy
        // beats chance on the GBM task.
        let cfg = ModelConfig { d_in: 2, hidden: 8, d_out: 3, depth: 3, logsig: true };
        let mut rng = Rng::new(43);
        let mut p = Params::init(&cfg, &mut rng);
        assert_eq!(p.w_out.len(), witt_dimension(3, 3));
        let gcfg = GbmConfig { stream: 32, ..Default::default() };
        let (x, y) = gbm_batch(&mut rng, 64, &gcfg);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..120 {
            last = train_step(&cfg, &mut p, &x, &y, 1.0, SigBackend::Fused, 4);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap(), "loss {first:?} -> {last}");
        assert!(accuracy(&cfg, &p, &x, &y) > 0.6);
    }

    #[test]
    fn logsig_readout_backends_agree() {
        // The epilogue only needs the forward signature, so the logsig
        // readout works on the Conventional tape backend too, and one step
        // from identical params lands on (nearly) identical params.
        let cfg = ModelConfig { d_in: 2, hidden: 4, d_out: 2, depth: 3, logsig: true };
        let mut rng = Rng::new(47);
        let p0 = Params::init(&cfg, &mut rng);
        let (x, y) = gbm_batch(&mut rng, 8, &GbmConfig { stream: 16, ..Default::default() });
        let mut pa = p0.clone();
        let mut pb = p0.clone();
        let la = train_step(&cfg, &mut pa, &x, &y, 0.1, SigBackend::Fused, 2);
        let lb = train_step(&cfg, &mut pb, &x, &y, 0.1, SigBackend::Conventional, 2);
        assert!((la - lb).abs() < 1e-4, "loss {la} vs {lb}");
        for (a, b) in pa.w_out.iter().zip(&pb.w_out) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn param_buffer_roundtrip() {
        let cfg = ModelConfig::default();
        let mut rng = Rng::new(5);
        let p = Params::init(&cfg, &mut rng);
        let bufs = p.to_buffers();
        assert_eq!(bufs.len(), 6);
        let q = Params::from_buffers(&cfg, &bufs);
        assert_eq!(p.w1, q.w1);
        assert_eq!(p.b_out, q.b_out);
    }

    #[test]
    fn gradient_check_head_params() {
        // FD check on w_out (cheap: linear head).
        let cfg = ModelConfig { d_in: 2, hidden: 4, d_out: 2, depth: 2, logsig: false };
        let spec = SigSpec::new(2, 2).unwrap();
        let mut rng = Rng::new(9);
        let p = Params::init(&cfg, &mut rng);
        let (x, y) = gbm_batch(&mut rng, 1, &GbmConfig { stream: 8, ..Default::default() });
        let g = sample_grad(&cfg, &spec, &p, &x, y[0], SigBackend::Fused, 1, None);
        let h = 1e-3f32;
        for i in 0..p.w_out.len() {
            let mut pp = p.clone();
            pp.w_out[i] += h;
            let mut pm = p.clone();
            pm.w_out[i] -= h;
            let loss = |pr: &Params| {
                let logit = forward_logit(&cfg, &spec, pr, &x, None);
                logit.max(0.0) - logit * y[0] + (-logit.abs()).exp().ln_1p()
            };
            let fd = (loss(&pp) - loss(&pm)) / (2.0 * h);
            assert!(
                (fd - g.w_out[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                "w_out[{i}]: fd={fd} got={}",
                g.w_out[i]
            );
        }
    }
}
