//! Synthetic data generators used by the examples and benchmarks.

pub mod gbm;

pub use gbm::{gbm_batch, GbmConfig};

use crate::substrate::rng::Rng;

/// A Brownian-ish random path `(stream, d)` with N(0, scale²) increments —
/// the workload of the paper's §6.1 benchmarks.
pub fn random_path(rng: &mut Rng, stream: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut p = vec![0.0f32; stream * d];
    for i in 1..stream {
        for c in 0..d {
            p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * scale;
        }
    }
    p
}

/// A batch of random paths `(batch, stream, d)`.
pub fn random_batch(rng: &mut Rng, batch: usize, stream: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(batch * stream * d);
    for _ in 0..batch {
        out.extend(random_path(rng, stream, d, scale));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_path_starts_at_origin() {
        let mut rng = Rng::new(1);
        let p = random_path(&mut rng, 10, 3, 0.5);
        assert_eq!(p.len(), 30);
        assert_eq!(&p[..3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn random_batch_shape() {
        let mut rng = Rng::new(2);
        let b = random_batch(&mut rng, 4, 5, 2, 0.1);
        assert_eq!(b.len(), 4 * 5 * 2);
    }
}
