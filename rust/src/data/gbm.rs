//! Geometric Brownian motion samples — the §6.2 toy dataset.
//!
//! Paths have one of two volatilities; the task is binary classification of
//! the volatility. Each sample is a `(stream, 2)` path of (time, value),
//! matching `python/tests/test_model.py::gbm_batch` so the native and XLA
//! training loops see the same distribution.

use crate::substrate::rng::Rng;

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct GbmConfig {
    pub stream: usize,
    pub vol_low: f32,
    pub vol_high: f32,
}

impl Default for GbmConfig {
    fn default() -> Self {
        GbmConfig { stream: 64, vol_low: 0.2, vol_high: 0.6 }
    }
}

/// Generate a batch: returns `(x, y)` where `x` is `(batch, stream, 2)`
/// flattened (channels: time in [0,1], GBM value) and `y` is `(batch,)`
/// labels (1.0 = high volatility).
pub fn gbm_batch(rng: &mut Rng, batch: usize, cfg: &GbmConfig) -> (Vec<f32>, Vec<f32>) {
    let l = cfg.stream;
    let dt = 1.0 / l as f32;
    let mut x = vec![0.0f32; batch * l * 2];
    let mut y = vec![0.0f32; batch];
    for b in 0..batch {
        let high = rng.next_u64() & 1 == 1;
        let vol = if high { cfg.vol_high } else { cfg.vol_low };
        y[b] = f32::from(high as u8);
        let mut log_s = 0.0f32;
        for i in 0..l {
            let t = i as f32 / (l - 1).max(1) as f32;
            if i > 0 {
                log_s += -0.5 * vol * vol * dt + vol * dt.sqrt() * rng.normal_f32();
            }
            x[(b * l + i) * 2] = t;
            x[(b * l + i) * 2 + 1] = log_s.exp();
        }
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut rng = Rng::new(7);
        let cfg = GbmConfig::default();
        let (x, y) = gbm_batch(&mut rng, 16, &cfg);
        assert_eq!(x.len(), 16 * 64 * 2);
        assert_eq!(y.len(), 16);
        for b in 0..16 {
            // Time channel runs 0..1; value starts at 1.
            assert_eq!(x[b * 64 * 2], 0.0);
            assert!((x[(b * 64 + 63) * 2] - 1.0).abs() < 1e-6);
            assert_eq!(x[b * 64 * 2 + 1], 1.0);
            assert!(y[b] == 0.0 || y[b] == 1.0);
        }
    }

    #[test]
    fn classes_statistically_separable() {
        // High-vol paths have larger quadratic variation — the dataset is
        // learnable (mirrors the python-side sanity test).
        let mut rng = Rng::new(11);
        let cfg = GbmConfig::default();
        let (x, y) = gbm_batch(&mut rng, 256, &cfg);
        let l = cfg.stream;
        let mut qv_high = (0.0f64, 0usize);
        let mut qv_low = (0.0f64, 0usize);
        for b in 0..256 {
            let mut qv = 0.0f64;
            for i in 1..l {
                let diff = x[(b * l + i) * 2 + 1] - x[(b * l + i - 1) * 2 + 1];
                qv += (diff as f64) * (diff as f64);
            }
            if y[b] == 1.0 {
                qv_high.0 += qv;
                qv_high.1 += 1;
            } else {
                qv_low.0 += qv;
                qv_low.1 += 1;
            }
        }
        let hi = qv_high.0 / qv_high.1 as f64;
        let lo = qv_low.0 / qv_low.1.max(1) as f64;
        assert!(hi > 3.0 * lo, "hi={hi} lo={lo}");
    }

    #[test]
    fn both_classes_appear() {
        let mut rng = Rng::new(3);
        let (_, y) = gbm_batch(&mut rng, 64, &GbmConfig::default());
        let ones = y.iter().filter(|&&v| v == 1.0).count();
        assert!(ones > 10 && ones < 54);
    }
}
