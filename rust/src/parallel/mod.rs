//! Stream-level parallelism (§5.1): the signature computation, eq. (3), is
//! a noncommutative reduction with respect to ⊠, so it parallelises by
//! splitting the increments into chunks, computing each chunk's signature
//! independently (each with the fused multiply-exponentiate), and combining
//! the chunk signatures with ⊠.
//!
//! The same chunk decomposition drives the stream-parallel *backward* pass
//! ([`crate::signature::backward`]): Chen's identity factors the full
//! signature as `L_c ⊠ M_c ⊠ R_c` around each chunk, so per-chunk
//! cotangents follow from two ⊠-VJPs and the per-chunk reverse sweeps run
//! concurrently. [`chunk_signatures`] is the shared first stage.

use crate::substrate::pool::{chunk_ranges, parallel_map_indexed};
use crate::ta::fused::fused_mexp;
use crate::ta::mul::mul_assign;
use crate::ta::{Elem, SigSpec, Workspace};

/// Compute the per-chunk signatures `M_c` of the path given by
/// `point(0..n_points)`, one chunk per thread, in parallel.
///
/// Chunk `c` covers increments `[s, e)` of its range — the sub-path points
/// `s..=e` — so `M_0 ⊠ M_1 ⊠ ... = Sig(path)` by Chen's identity. Returns
/// the increment ranges alongside the identity-initialised chunk
/// signatures; both the forward reduction and the stream-parallel backward
/// build on this.
pub fn chunk_signatures<'a, E, F>(
    spec: &SigSpec,
    n_points: usize,
    point: &F,
    threads: usize,
) -> (Vec<(usize, usize)>, Vec<Vec<E>>)
where
    E: Elem,
    F: Fn(usize) -> &'a [E] + Sync,
{
    let n_incr = n_points - 1;
    let ranges = chunk_ranges(n_incr, threads);
    let chunk_sigs = parallel_map_indexed(ranges.len(), ranges.len(), |ci| {
        let (s, e) = ranges[ci];
        let mut ws = Workspace::<E>::new(spec);
        let mut sig = spec.zeros_elem::<E>();
        let d = spec.d();
        let mut z = vec![E::ZERO; d];
        for i in s..e {
            let prev = point(i);
            let cur = point(i + 1);
            for c in 0..d {
                z[c] = cur[c] - prev[c];
            }
            fused_mexp(spec, &mut sig, &z, &mut ws);
        }
        sig
    });
    (ranges, chunk_sigs)
}

/// Compute the signature of the path given by `point(0..n_points)` using a
/// chunked parallel reduction over the stream dimension. Returns the
/// signature (identity-initialised; callers fold in any `initial`).
pub fn reduce_signature<'a, E, F>(
    spec: &SigSpec,
    n_points: usize,
    point: &F,
    threads: usize,
) -> Vec<E>
where
    E: Elem,
    F: Fn(usize) -> &'a [E] + Sync,
{
    let (_, chunk_sigs) = chunk_signatures(spec, n_points, point, threads);
    // Combine left-to-right (few chunks; a tree would not help here).
    let mut iter = chunk_sigs.into_iter();
    let mut acc = iter.next().expect("at least one chunk");
    for s in iter {
        mul_assign(spec, &mut acc, &s);
    }
    acc
}

/// Tree-combine a slice of signatures `(count, sig_len)` with ⊠ in
/// parallel: used by `multi_signature_combine` and by benchmarks comparing
/// reduction strategies. Returns the ⊠-product in order.
pub fn tree_combine<E: Elem>(spec: &SigSpec, sigs: &[E], count: usize, threads: usize) -> Vec<E> {
    let len = spec.sig_len();
    assert_eq!(sigs.len(), count * len);
    assert!(count >= 1);
    let mut layer: Vec<Vec<E>> = (0..count).map(|i| sigs[i * len..(i + 1) * len].to_vec()).collect();
    while layer.len() > 1 {
        let pairs = layer.len() / 2;
        let odd = layer.len() % 2 == 1;
        let combined = parallel_map_indexed(pairs, threads, |p| {
            crate::ta::mul(spec, &layer[2 * p], &layer[2 * p + 1])
        });
        let mut next = combined;
        if odd {
            next.push(layer.last().unwrap().clone());
        }
        layer = next;
    }
    layer.pop().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;
    use crate::ta::mul;

    #[test]
    fn tree_combine_matches_left_fold() {
        let spec = SigSpec::new(2, 4).unwrap();
        let mut rng = Rng::new(17);
        let count = 7;
        let len = spec.sig_len();
        let sigs = rng.normal_vec(count * len, 0.3);
        let tree = tree_combine(&spec, &sigs, count, 4);
        let mut fold = sigs[..len].to_vec();
        for i in 1..count {
            fold = mul(&spec, &fold, &sigs[i * len..(i + 1) * len]);
        }
        assert_close(&tree, &fold, 1e-3, 1e-4);
    }

    #[test]
    fn tree_combine_single() {
        let spec = SigSpec::new(2, 2).unwrap();
        let sigs = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(tree_combine(&spec, &sigs, 1, 4), sigs);
    }

    #[test]
    fn chunk_signatures_cover_and_combine() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(11);
        let stream = 37;
        let path = rng.normal_vec(stream * 2, 0.2);
        let point = |i: usize| &path[i * 2..(i + 1) * 2];
        let (ranges, sigs) = chunk_signatures(&spec, stream, &point, 5);
        assert_eq!(ranges.len(), sigs.len());
        // Ranges tile the increments exactly.
        let mut pos = 0;
        for &(s, e) in &ranges {
            assert_eq!(s, pos);
            pos = e;
        }
        assert_eq!(pos, stream - 1);
        // Chen: the ⊠-product of the chunk signatures is the signature.
        let mut acc = sigs[0].clone();
        for s in &sigs[1..] {
            mul_assign(&spec, &mut acc, s);
        }
        let serial = crate::signature::signature(&path, stream, &spec);
        assert_close(&acc, &serial, 1e-3, 1e-4);
    }

    #[test]
    fn reduce_signature_one_thread_matches_many() {
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(3);
        let stream = 64;
        let path = rng.normal_vec(stream * 3, 0.2);
        let point = |i: usize| &path[i * 3..(i + 1) * 3];
        let one = reduce_signature(&spec, stream, &point, 1);
        for t in [2, 3, 8, 63, 200] {
            let many = reduce_signature(&spec, stream, &point, t);
            assert_close(&many, &one, 1e-3, 1e-4);
        }
    }
}
