//! The group inverse in the truncated tensor algebra (§2.3, §5.4) and its
//! handwritten VJP.
//!
//! For `x` the non-unit part, `(1 + x)^{-1} = 1 - x + x^{⊠2} - ...`
//! truncated at depth N, evaluated by the Horner-style fixpoint
//!
//! ```text
//! t_0 = 0,  t_i = -(x + x ⊠_nounit t_{i-1}),  inverse = t_N
//! ```
//!
//! (each iteration extends correctness one level deeper, since `x` has no
//! scalar term). For *signatures* specifically, the paper's identity
//! `Sig(x_1..x_L)^{-1} = Sig(x_L..x_1)` (§5.4) and the incremental
//! `exp(-z) ⊠ ·` update are cheaper; this general routine is used for
//! arbitrary group elements and as a test oracle. Generic over the sealed
//! element trait [`Elem`] (f32/f64).

use super::mul::{mul_nounit_into, mul_nounit_vjp};
use super::{Elem, SigSpec};

/// `out = x^{-1}` (non-unit parts; the implicit units multiply to 1).
pub fn inverse_into<E: Elem>(spec: &SigSpec, x: &[E], out: &mut [E]) {
    let n = spec.depth();
    debug_assert_eq!(x.len(), spec.sig_len());
    debug_assert_eq!(out.len(), spec.sig_len());
    // t_1 = -x.
    for (o, &xv) in out.iter_mut().zip(x) {
        *o = -xv;
    }
    if n == 1 {
        return;
    }
    let mut xt = spec.zeros_elem::<E>();
    for _ in 2..=n {
        mul_nounit_into(spec, x, out, &mut xt);
        for ((o, &xv), &pv) in out.iter_mut().zip(x).zip(xt.iter()) {
            *o = -(xv + pv);
        }
    }
}

/// Allocating wrapper around [`inverse_into`].
pub fn inverse<E: Elem>(spec: &SigSpec, x: &[E]) -> Vec<E> {
    let mut out = spec.zeros_elem::<E>();
    inverse_into(spec, x, &mut out);
    out
}

/// VJP of `y = x^{-1}`: accumulates `∂L/∂x` into `gx` given `g = ∂L/∂y`.
///
/// Replays the fixpoint storing each `t_i`, then reverses.
pub fn inverse_vjp<E: Elem>(spec: &SigSpec, x: &[E], g: &[E], gx: &mut [E]) {
    let n = spec.depth();
    // Forward replay.
    let mut t_hist: Vec<Vec<E>> = Vec::with_capacity(n);
    let mut t: Vec<E> = x.iter().map(|&v| -v).collect();
    t_hist.push(t.clone());
    let mut xt = spec.zeros_elem::<E>();
    for _ in 2..=n {
        mul_nounit_into(spec, x, &t, &mut xt);
        let mut t_new = spec.zeros_elem::<E>();
        for ((o, &xv), &pv) in t_new.iter_mut().zip(x).zip(xt.iter()) {
            *o = -(xv + pv);
        }
        t = t_new;
        t_hist.push(t.clone());
    }
    // Reverse: gt_i flows back through t_i = -(x + x ⊠' t_{i-1}).
    let mut gt = g.to_vec();
    for i in (2..=n).rev() {
        let t_prev = &t_hist[i - 2];
        let neg_gt: Vec<E> = gt.iter().map(|&v| -v).collect();
        for (o, &gv) in gx.iter_mut().zip(&neg_gt) {
            *o += gv;
        }
        let mut gt_prev = spec.zeros_elem::<E>();
        mul_nounit_vjp(spec, x, t_prev, &neg_gt, gx, &mut gt_prev);
        gt = gt_prev;
    }
    // t_1 = -x.
    for (o, &gv) in gx.iter_mut().zip(&gt) {
        *o -= gv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::ta::{exp, mul};

    #[test]
    fn inverse_times_self_is_identity() {
        property("x ⊠ x⁻¹ = 1", 30, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 6);
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let x = g.normal_vec(s.sig_len(), 0.6);
            let inv = inverse(&s, &x);
            let prod = mul(&s, &x, &inv);
            // Identity has all stored (non-unit) entries zero.
            assert_close(&prod, &s.zeros(), 1e-4, 5e-4);
            let prod2 = mul(&s, &inv, &x);
            assert_close(&prod2, &s.zeros(), 1e-4, 5e-4);
        });
    }

    #[test]
    fn inverse_of_exp_is_exp_of_negation() {
        property("exp(z)⁻¹ = exp(-z)", 20, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let s = SigSpec::new(d, n).unwrap();
            let z = g.normal_vec(d, 0.7);
            let zneg: Vec<f32> = z.iter().map(|&v| -v).collect();
            assert_close(&inverse(&s, &exp(&s, &z)), &exp(&s, &zneg), 1e-4, 1e-5);
        });
    }

    #[test]
    fn inverse_depth1_is_negation() {
        let s = SigSpec::new(3, 1).unwrap();
        assert_eq!(inverse(&s, &[1.0f32, -2.0, 3.0]), vec![-1.0, 2.0, -3.0]);
    }

    #[test]
    fn inverse_f64_times_self_is_identity() {
        let s = SigSpec::new(3, 4).unwrap();
        let mut rng = crate::substrate::rng::Rng::new(9);
        let x32 = rng.normal_vec(s.sig_len(), 0.5);
        let x: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
        let inv = inverse(&s, &x);
        let prod = mul(&s, &x, &inv);
        for (i, v) in prod.iter().enumerate() {
            assert!(v.abs() < 1e-10, "prod[{i}] = {v}");
        }
    }

    #[test]
    fn inverse_is_involutive() {
        let s = SigSpec::new(2, 4).unwrap();
        let mut rng = crate::substrate::rng::Rng::new(5);
        let x = rng.normal_vec(s.sig_len(), 0.5);
        let twice = inverse(&s, &inverse(&s, &x));
        assert_close(&twice, &x, 1e-4, 1e-5);
    }

    #[test]
    fn inverse_vjp_matches_finite_differences() {
        property("inverse vjp fd", 6, |gen| {
            let d = gen.usize_in(1, 3);
            let n = gen.usize_in(1, 4);
            gen.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let x = gen.normal_vec(s.sig_len(), 0.4);
            let g = gen.normal_vec(s.sig_len(), 1.0);
            let mut gx = s.zeros();
            inverse_vjp(&s, &x, &g, &mut gx);
            let h = 1e-2f32;
            for i in 0..x.len() {
                let mut xp = x.clone();
                xp[i] += h;
                let mut xm = x.clone();
                xm[i] -= h;
                let fd: f32 = inverse(&s, &xp)
                    .iter()
                    .zip(inverse(&s, &xm).iter())
                    .zip(&g)
                    .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                    .sum();
                assert!(
                    (fd - gx[i]).abs() < 3e-2 * (1.0 + fd.abs()),
                    "gx[{i}]: fd={fd} vjp={}",
                    gx[i]
                );
            }
        });
    }
}
