//! The tensor exponential `exp(z) = (z, z^⊗2/2!, ..., z^⊗N/N!)` (§2.2) and
//! its handwritten VJP.
//!
//! `exp` is the signature of a single linear segment with increment `z`
//! (`Sig((x1, x2)) = exp(x2 - x1)`), so it is both the base case of every
//! signature computation and the reference the fused operation is checked
//! against. Generic over the sealed element trait [`Elem`] (f32/f64).

use super::mul::{contract_left_add, contract_right_add};
use super::{Elem, SigSpec};

/// `out = exp(z)` where `z` has `spec.d()` entries.
pub fn exp_into<E: Elem>(spec: &SigSpec, z: &[E], out: &mut [E]) {
    debug_assert_eq!(z.len(), spec.d());
    debug_assert_eq!(out.len(), spec.sig_len());
    out[..spec.d()].copy_from_slice(z);
    exp_in_place(spec, out);
}

/// Build `exp` in place from an increment already staged in level 1:
/// on entry `out[..d]` holds `z`, on exit `out = exp(z)`. Lets allocation-
/// free callers (e.g.
/// [`crate::signature::forward::two_point_signature_into`]) skip the
/// separate `z` buffer.
pub fn exp_in_place<E: Elem>(spec: &SigSpec, out: &mut [E]) {
    debug_assert_eq!(out.len(), spec.sig_len());
    let d = spec.d();
    for k in 2..=spec.depth() {
        let inv_k = E::recip_usize(k);
        let (lo, hi) = out.split_at_mut(spec.off(k));
        let z = &lo[..d];
        let prev = &lo[spec.off(k - 1)..];
        let dst = &mut hi[..spec.level_len(k)];
        // E_k = E_{k-1} ⊗ (z / k)
        for (p, &ep) in prev.iter().enumerate() {
            let row = &mut dst[p * d..(p + 1) * d];
            for (q, &zq) in z.iter().enumerate() {
                row[q] = ep * zq * inv_k;
            }
        }
    }
}

/// Allocating wrapper around [`exp_into`].
pub fn exp<E: Elem>(spec: &SigSpec, z: &[E]) -> Vec<E> {
    let mut out = spec.zeros_elem::<E>();
    exp_into(spec, z, &mut out);
    out
}

/// VJP of `E = exp(z)`: accumulates `∂L/∂z` into `gz` given `g = ∂L/∂E`.
///
/// Recomputes the forward levels internally (they are cheap relative to the
/// contractions) so no forward state needs to be retained — consistent with
/// the library-wide reversibility strategy (App. C).
pub fn exp_vjp<E: Elem>(spec: &SigSpec, z: &[E], g: &[E], gz: &mut [E]) {
    let d = spec.d();
    let n = spec.depth();
    debug_assert_eq!(gz.len(), d);
    // Recompute E (forward).
    let e = exp(spec, z);
    // gE is built top-down: gE_N = g_N; gE_{k-1} = g_{k-1} + contraction of
    // gE_k with z/k (since E_k = E_{k-1} ⊗ z/k).
    let mut ge_k: Vec<E> = spec.level(g, n).to_vec();
    for k in (2..=n).rev() {
        let inv_k = E::recip_usize(k);
        let e_prev = spec.level(&e, k - 1);
        // gz[q] += Σ_p gE_k[p,q] * E_{k-1}[p] / k
        let mut gz_part = vec![E::ZERO; d];
        contract_left_add(&ge_k, e_prev, &mut gz_part);
        for (o, v) in gz.iter_mut().zip(&gz_part) {
            *o += *v * inv_k;
        }
        // gE_{k-1}[p] = g_{k-1}[p] + Σ_q gE_k[p,q] * z[q] / k
        let mut ge_prev = spec.level(g, k - 1).to_vec();
        let mut scratch = vec![E::ZERO; ge_prev.len()];
        contract_right_add(&ge_k, z, &mut scratch);
        for (o, s) in ge_prev.iter_mut().zip(&scratch) {
            *o += *s * inv_k;
        }
        ge_k = ge_prev;
    }
    // Level 1: E_1 = z.
    for (o, &gv) in gz.iter_mut().zip(ge_k.iter()) {
        *o += gv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};

    #[test]
    fn exp_d1_matches_scalar_series() {
        let s = SigSpec::new(1, 5).unwrap();
        let z = 0.7f32;
        let e = exp(&s, &[z]);
        let expect: Vec<f32> = (1..=5)
            .map(|k| z.powi(k as i32) / (1..=k).product::<usize>() as f32)
            .collect();
        assert_close(&e, &expect, 1e-6, 1e-8);
    }

    #[test]
    fn exp_levels_are_scaled_tensor_powers() {
        let s = SigSpec::new(3, 3).unwrap();
        let z = [1.0f32, -2.0, 0.5];
        let e = exp(&s, &z);
        // Level 2 entry (i,j) = z_i z_j / 2.
        for i in 0..3 {
            for j in 0..3 {
                let got = s.level(&e, 2)[i * 3 + j];
                assert!((got - z[i] * z[j] / 2.0).abs() < 1e-6);
            }
        }
        // Level 3 entry (i,j,k) = z_i z_j z_k / 6.
        for i in 0..3 {
            for j in 0..3 {
                for k in 0..3 {
                    let got = s.level(&e, 3)[(i * 3 + j) * 3 + k];
                    assert!((got - z[i] * z[j] * z[k] / 6.0).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn exp_of_zero_is_identity() {
        let s = SigSpec::new(4, 3).unwrap();
        let e = exp(&s, &[0.0f32; 4]);
        assert!(e.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn exp_f64_levels_match_f32_upcast_closely() {
        // The f64 instantiation runs the same recurrence at higher
        // precision: on f32-representable inputs the downcast agrees to
        // f32 roundoff.
        let s = SigSpec::new(3, 4).unwrap();
        let z32 = [0.25f32, -0.5, 0.125];
        let z64: Vec<f64> = z32.iter().map(|&v| v as f64).collect();
        let e32 = exp(&s, &z32);
        let e64 = exp(&s, &z64);
        for (a, b) in e32.iter().zip(&e64) {
            assert!((*a as f64 - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn exp_additivity_on_parallel_increments() {
        // exp(z) ⊠ exp(z) = exp(2z) for a straight path (1D BCH is trivial;
        // in general only parallel increments commute).
        property("exp parallel additivity", 20, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let z = g.normal_vec(d, 0.5);
            let e = exp(&s, &z);
            let combined = crate::ta::mul(&s, &e, &e);
            let z2: Vec<f32> = z.iter().map(|&x| 2.0 * x).collect();
            assert_close(&combined, &exp(&s, &z2), 1e-4, 1e-6);
        });
    }

    #[test]
    fn exp_vjp_matches_finite_differences() {
        property("exp vjp fd", 10, |gen| {
            let d = gen.usize_in(1, 3);
            let n = gen.usize_in(1, 4);
            gen.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let z = gen.normal_vec(d, 0.6);
            let g = gen.normal_vec(s.sig_len(), 1.0);
            let mut gz = vec![0.0; d];
            exp_vjp(&s, &z, &g, &mut gz);
            let h = 1e-2f32;
            for i in 0..d {
                let mut zp = z.clone();
                zp[i] += h;
                let mut zm = z.clone();
                zm[i] -= h;
                let fp = exp(&s, &zp);
                let fm = exp(&s, &zm);
                let fd: f32 = fp
                    .iter()
                    .zip(&fm)
                    .zip(&g)
                    .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                    .sum();
                assert!(
                    (fd - gz[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "i={i} fd={fd} vjp={}",
                    gz[i]
                );
            }
        });
    }
}
