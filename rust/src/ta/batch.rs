//! **Batch-lane execution engine**: the fused multiply-exponentiate of
//! [`super::fused`] vectorised *across the batch* instead of within a path.
//!
//! The paper's two-level CPU parallelism (§5.1) assigns one thread per
//! path, so in the serving-realistic regime — many short streams at small
//! `d` — each core runs a scalar Horner loop over `d ∈ {2, 3, 4}` channels
//! and the SIMD lanes sit idle. Following the pySigLib observation that
//! this regime is won by batch-axis vectorisation, this module processes
//! `L` same-spec signatures together in a **lane-interleaved layout**:
//! element `i` of lane `l` lives at `buf[i * L + l]`, so every scalar of
//! the scalar kernels becomes an `L`-vector and the innermost loops run
//! contiguously over the lanes — auto-vectorising regardless of `d`.
//!
//! Each lane performs *exactly* the same floating-point operations in the
//! same order as the scalar kernels ([`super::fused::fused_mexp`] /
//! [`fused_mexp_left`] / `fused_mexp_vjp`), so lane-fused results are
//! **bitwise identical** to per-path dispatch — pinned by the tests below.
//! The VJP mirrors the scalar Horner backward at *every* dimension: the
//! scalar side dispatches to monomorphised bodies for `d ≤ 8` and to the
//! runtime-`d` [`fused_mexp_vjp_dyn`] beyond, and both replay the same op
//! order as this batched twin, so there is no dimension ceiling on the
//! lane path. All kernels are generic over the sealed element trait
//! [`Elem`] (f32/f64); f32 call sites infer `E = f32` unchanged.
//!
//! [`fused_mexp_left`]: super::fused::fused_mexp_left
//! [`fused_mexp_vjp_dyn`]: super::fused::fused_mexp_vjp_dyn

use super::{Elem, SigSpec};

/// Reusable scratch for the lane kernels, sized for one `(SigSpec, lanes)`
/// pair — the batched analogue of [`super::Workspace`], holding `lanes`
/// interleaved signatures' worth of Horner and staging buffers.
pub struct BatchWorkspace<E: Elem = f32> {
    lanes: usize,
    /// Ping/pong Horner buffers, each `d^(depth-1) * lanes` long.
    h0: Vec<E>,
    h1: Vec<E>,
    /// `z/m` staging, `(d * depth) * lanes` long.
    zdiv: Vec<E>,
    /// Forward-chain storage for the VJP, `sig_len * lanes` long.
    t2: Vec<E>,
    /// Per-level `∂L/∂z` accumulator for the VJP, `d * lanes` long.
    gza: Vec<E>,
}

impl<E: Elem> BatchWorkspace<E> {
    pub fn new(spec: &SigSpec, lanes: usize) -> BatchWorkspace<E> {
        assert!(lanes >= 1, "need at least one lane");
        let horner = if spec.depth() >= 2 {
            spec.level_len(spec.depth()) / spec.d()
        } else {
            spec.d()
        };
        BatchWorkspace {
            lanes,
            h0: vec![E::ZERO; horner * lanes],
            h1: vec![E::ZERO; horner * lanes],
            zdiv: vec![E::ZERO; spec.d() * spec.depth() * lanes],
            t2: vec![E::ZERO; spec.sig_len() * lanes],
            gza: vec![E::ZERO; spec.d() * lanes],
        }
    }

    /// Number of interleaved lanes this workspace serves.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }
}

/// Scatter `lanes` row-major items (each `item_len` long, `row(l)` yields
/// lane `l`'s item) into the lane-interleaved layout:
/// `out[i * lanes + l] = row(l)[i]`.
pub fn pack_lanes<'a, E: Elem>(
    item_len: usize,
    lanes: usize,
    row: impl Fn(usize) -> &'a [E],
    out: &mut [E],
) {
    debug_assert_eq!(out.len(), item_len * lanes);
    for l in 0..lanes {
        let r = row(l);
        debug_assert_eq!(r.len(), item_len);
        for (i, &v) in r.iter().enumerate() {
            out[i * lanes + l] = v;
        }
    }
}

/// Gather lane `l` out of a lane-interleaved buffer back into a row-major
/// item: `out[i] = interleaved[i * lanes + l]`.
pub fn unpack_lane<E: Elem>(
    item_len: usize,
    lanes: usize,
    interleaved: &[E],
    l: usize,
    out: &mut [E],
) {
    debug_assert_eq!(interleaved.len(), item_len * lanes);
    debug_assert_eq!(out.len(), item_len);
    debug_assert!(l < lanes);
    for (i, o) in out.iter_mut().enumerate() {
        *o = interleaved[i * lanes + l];
    }
}

/// Stage `z/m` for `m = 1..=depth` into `ws.zdiv` (lane-interleaved; block
/// `m-1` holds `z/m`, laid out like `z` itself).
#[inline]
fn stage_zdiv_batch<E: Elem>(spec: &SigSpec, z: &[E], ws: &mut BatchWorkspace<E>) {
    let dl = spec.d() * ws.lanes;
    debug_assert_eq!(z.len(), dl);
    for m in 1..=spec.depth() {
        let inv = E::recip_usize(m);
        let row = &mut ws.zdiv[(m - 1) * dl..m * dl];
        for (r, &zq) in row.iter_mut().zip(z) {
            *r = zq * inv;
        }
    }
}

/// Lane-wise `dst[l] = src[l] * z[l] + add[l]` over `lanes` contiguous
/// values — the vectorised body of every middle Horner step.
#[inline(always)]
fn lane_fma<E: Elem>(dst: &mut [E], src: &[E], z: &[E], add: &[E]) {
    for ((dv, (&sv, &zv)), &av) in dst.iter_mut().zip(src.iter().zip(z)).zip(add) {
        *dv = sv * zv + av;
    }
}

/// Lane-wise `dst[l] += src[l] * z[l]` — the vectorised final Horner step.
#[inline(always)]
fn lane_fma_acc<E: Elem>(dst: &mut [E], src: &[E], z: &[E]) {
    for (dv, (&sv, &zv)) in dst.iter_mut().zip(src.iter().zip(z)) {
        *dv += sv * zv;
    }
}

/// In-place batched fused multiply-exponentiate: `a_l ← a_l ⊠ exp(z_l)`
/// for every lane `l`, on lane-interleaved `a` (`sig_len * lanes`) and `z`
/// (`d * lanes`). Bitwise identical per lane to [`super::fused::fused_mexp`].
pub fn fused_mexp_batch<E: Elem>(spec: &SigSpec, a: &mut [E], z: &[E], ws: &mut BatchWorkspace<E>) {
    let d = spec.d();
    let n = spec.depth();
    let lanes = ws.lanes;
    debug_assert_eq!(a.len(), spec.sig_len() * lanes);
    debug_assert_eq!(z.len(), d * lanes);
    stage_zdiv_batch(spec, z, ws);
    for k in (2..=n).rev() {
        // B_1 = z/k + A_1 (lane-wise).
        {
            let b = &mut ws.h0[..d * lanes];
            let zk = &ws.zdiv[(k - 1) * d * lanes..k * d * lanes];
            for ((bv, &zv), &av) in b.iter_mut().zip(zk).zip(&a[..d * lanes]) {
                *bv = zv + av;
            }
        }
        let mut cur_in_h0 = true;
        let mut cur_len = d;
        for i in 2..k {
            // B_i = B_{i-1} ⊗ (z / (k-i+1)) + A_i.
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (src, dst) = if cur_in_h0 {
                (&ws.h0[..cur_len * lanes], &mut ws.h1[..cur_len * d * lanes])
            } else {
                (&ws.h1[..cur_len * lanes], &mut ws.h0[..cur_len * d * lanes])
            };
            let zm = &ws.zdiv[(m - 1) * d * lanes..m * d * lanes];
            let ai = &a[oi * lanes..(oi + li) * lanes];
            for p in 0..cur_len {
                let sp = &src[p * lanes..(p + 1) * lanes];
                for q in 0..d {
                    let e = p * d + q;
                    lane_fma(
                        &mut dst[e * lanes..(e + 1) * lanes],
                        sp,
                        &zm[q * lanes..(q + 1) * lanes],
                        &ai[e * lanes..(e + 1) * lanes],
                    );
                }
            }
            cur_in_h0 = !cur_in_h0;
            cur_len *= d;
        }
        // Final step writes into A_k in place: A_k += B_{k-1} ⊗ z.
        let ok = spec.off(k);
        let dst = &mut a[ok * lanes..(ok + cur_len * d) * lanes];
        let src = if cur_in_h0 { &ws.h0[..cur_len * lanes] } else { &ws.h1[..cur_len * lanes] };
        for p in 0..cur_len {
            let sp = &src[p * lanes..(p + 1) * lanes];
            for q in 0..d {
                let e = p * d + q;
                lane_fma_acc(
                    &mut dst[e * lanes..(e + 1) * lanes],
                    sp,
                    &z[q * lanes..(q + 1) * lanes],
                );
            }
        }
    }
    // Level 1: A_1 += z.
    for (av, &zv) in a[..d * lanes].iter_mut().zip(z) {
        *av += zv;
    }
}

/// Batched mirrored fused operation: `a_l ← exp(z_l) ⊠ a_l` per lane —
/// the incremental inverted-signature step (§4.2), lane-interleaved.
/// Bitwise identical per lane to [`super::fused::fused_mexp_left`].
pub fn fused_mexp_left_batch<E: Elem>(
    spec: &SigSpec,
    a: &mut [E],
    z: &[E],
    ws: &mut BatchWorkspace<E>,
) {
    let d = spec.d();
    let n = spec.depth();
    let lanes = ws.lanes;
    debug_assert_eq!(a.len(), spec.sig_len() * lanes);
    debug_assert_eq!(z.len(), d * lanes);
    stage_zdiv_batch(spec, z, ws);
    for k in (2..=n).rev() {
        // B_1 = A_1 + z/k.
        {
            let b = &mut ws.h0[..d * lanes];
            let zk = &ws.zdiv[(k - 1) * d * lanes..k * d * lanes];
            for ((bv, &zv), &av) in b.iter_mut().zip(zk).zip(&a[..d * lanes]) {
                *bv = zv + av;
            }
        }
        let mut cur_in_h0 = true;
        let mut cur_len = d;
        for i in 2..k {
            // B_i = A_i + (z/(k-i+1)) ⊗ B_{i-1}  (z factor on the left).
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (src, dst) = if cur_in_h0 {
                (&ws.h0[..cur_len * lanes], &mut ws.h1[..cur_len * d * lanes])
            } else {
                (&ws.h1[..cur_len * lanes], &mut ws.h0[..cur_len * d * lanes])
            };
            let zm = &ws.zdiv[(m - 1) * d * lanes..m * d * lanes];
            let ai = &a[oi * lanes..(oi + li) * lanes];
            for q in 0..d {
                let zq = &zm[q * lanes..(q + 1) * lanes];
                for p in 0..cur_len {
                    let e = q * cur_len + p;
                    lane_fma(
                        &mut dst[e * lanes..(e + 1) * lanes],
                        &src[p * lanes..(p + 1) * lanes],
                        zq,
                        &ai[e * lanes..(e + 1) * lanes],
                    );
                }
            }
            cur_in_h0 = !cur_in_h0;
            cur_len *= d;
        }
        // Final: A_k += z ⊗ B_{k-1}.
        let ok = spec.off(k);
        let dst = &mut a[ok * lanes..(ok + cur_len * d) * lanes];
        let src = if cur_in_h0 { &ws.h0[..cur_len * lanes] } else { &ws.h1[..cur_len * lanes] };
        for q in 0..d {
            let zq = &z[q * lanes..(q + 1) * lanes];
            for p in 0..cur_len {
                let e = q * cur_len + p;
                lane_fma_acc(
                    &mut dst[e * lanes..(e + 1) * lanes],
                    &src[p * lanes..(p + 1) * lanes],
                    zq,
                );
            }
        }
    }
    for (av, &zv) in a[..d * lanes].iter_mut().zip(z) {
        *av += zv;
    }
}

/// Batched VJP of `C_l = A_l ⊠ exp(z_l)`: given lane-interleaved
/// `g = ∂L/∂C`, accumulates `∂L/∂A` into `ga` and `∂L/∂z` into `gz`
/// (both lane-interleaved).
///
/// Mirrors the scalar Horner backward ([`super::fused::fused_mexp_vjp`])
/// operation-for-operation at *every* `d` — the scalar dispatcher picks a
/// monomorphised body for `d ≤ 8` and the runtime-`d`
/// [`super::fused::fused_mexp_vjp_dyn`] beyond, and both replay the same
/// op order as this kernel — so per-lane results are bitwise identical to
/// per-path dispatch with no dimension ceiling.
pub fn fused_mexp_vjp_batch<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    g: &[E],
    ga: &mut [E],
    gz: &mut [E],
    ws: &mut BatchWorkspace<E>,
) {
    let d = spec.d();
    let n = spec.depth();
    let lanes = ws.lanes;
    debug_assert_eq!(a.len(), spec.sig_len() * lanes);
    debug_assert_eq!(g.len(), spec.sig_len() * lanes);
    debug_assert_eq!(ga.len(), spec.sig_len() * lanes);
    debug_assert_eq!(z.len(), d * lanes);
    debug_assert_eq!(gz.len(), d * lanes);
    stage_zdiv_batch(spec, z, ws);
    // Level 1: C_1 = A_1 + z.
    for i in 0..d * lanes {
        ga[i] += g[i];
        gz[i] += g[i];
    }
    for k in (2..=n).rev() {
        // Recompute the forward Horner chain for level k, storing B_i at
        // t2[off(i) * lanes..] (B_i has exactly level-i length per lane).
        {
            let b1 = &mut ws.t2[..d * lanes];
            let zk = &ws.zdiv[(k - 1) * d * lanes..k * d * lanes];
            for ((bv, &zv), &av) in b1.iter_mut().zip(zk).zip(&a[..d * lanes]) {
                *bv = zv + av;
            }
        }
        let mut cur_len = d;
        for i in 2..k {
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (lo, hi) = ws.t2.split_at_mut(oi * lanes);
            let src = &lo[spec.off(i - 1) * lanes..(spec.off(i - 1) + cur_len) * lanes];
            let dst = &mut hi[..li * lanes];
            let zm = &ws.zdiv[(m - 1) * d * lanes..m * d * lanes];
            let ai = &a[oi * lanes..(oi + li) * lanes];
            for p in 0..cur_len {
                let sp = &src[p * lanes..(p + 1) * lanes];
                for q in 0..d {
                    let e = p * d + q;
                    lane_fma(
                        &mut dst[e * lanes..(e + 1) * lanes],
                        sp,
                        &zm[q * lanes..(q + 1) * lanes],
                        &ai[e * lanes..(e + 1) * lanes],
                    );
                }
            }
            cur_len *= d;
        }
        // Unwind. Final step: C_k = B_{k-1} ⊗ z + A_k.
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let gk = &g[ok * lanes..(ok + lk) * lanes];
        for (x, &gv) in ga[ok * lanes..(ok + lk) * lanes].iter_mut().zip(gk) {
            *x += gv;
        }
        // gB_{k-1}[p] = Σ_q gk[p,q] z[q];  gz[q] += Σ_p B_{k-1}[p] gk[p,q].
        let bk1 = &ws.t2[spec.off(k - 1) * lanes..(spec.off(k - 1) + cur_len) * lanes];
        let gb = &mut ws.h0[..cur_len * lanes];
        for p in 0..cur_len {
            let gbp = &mut gb[p * lanes..(p + 1) * lanes];
            gbp.fill(E::ZERO);
            let bp = &bk1[p * lanes..(p + 1) * lanes];
            for q in 0..d {
                let row = &gk[(p * d + q) * lanes..(p * d + q + 1) * lanes];
                let zq = &z[q * lanes..(q + 1) * lanes];
                let gzq = &mut gz[q * lanes..(q + 1) * lanes];
                for l in 0..lanes {
                    gbp[l] += row[l] * zq[l];
                    gzq[l] += bp[l] * row[l];
                }
            }
        }
        // Middle steps: B_i = B_{i-1} ⊗ z/m + A_i, i = k-1 .. 2.
        let mut cur_in_h0 = true;
        let mut len_i = cur_len; // length of B_i for current i (= d^i)
        for i in (2..k).rev() {
            let m = k - i + 1;
            let inv_m = E::recip_usize(m);
            let zm = &ws.zdiv[(m - 1) * d * lanes..m * d * lanes];
            let oi = spec.off(i);
            let prev_len = len_i / d;
            let b_prev = &ws.t2[spec.off(i - 1) * lanes..(spec.off(i - 1) + prev_len) * lanes];
            let (gb_i, gb_prev) = if cur_in_h0 {
                (&ws.h0[..len_i * lanes], &mut ws.h1[..prev_len * lanes])
            } else {
                (&ws.h1[..len_i * lanes], &mut ws.h0[..prev_len * lanes])
            };
            // gA_i += gB_i.
            for (x, &gv) in ga[oi * lanes..(oi + len_i) * lanes].iter_mut().zip(gb_i) {
                *x += gv;
            }
            // gB_{i-1}[p] = Σ_q gB_i[p,q] zm[q];
            // gz[q] += inv_m * Σ_p B_{i-1}[p] gB_i[p,q].
            ws.gza.fill(E::ZERO);
            for p in 0..prev_len {
                let gbp = &mut gb_prev[p * lanes..(p + 1) * lanes];
                gbp.fill(E::ZERO);
                let bp = &b_prev[p * lanes..(p + 1) * lanes];
                for q in 0..d {
                    let row = &gb_i[(p * d + q) * lanes..(p * d + q + 1) * lanes];
                    let zq = &zm[q * lanes..(q + 1) * lanes];
                    let gzq = &mut ws.gza[q * lanes..(q + 1) * lanes];
                    for l in 0..lanes {
                        gbp[l] += row[l] * zq[l];
                        gzq[l] += bp[l] * row[l];
                    }
                }
            }
            for (o, &v) in gz.iter_mut().zip(&ws.gza) {
                *o += inv_m * v;
            }
            cur_in_h0 = !cur_in_h0;
            len_i = prev_len;
        }
        // Innermost: B_1 = z/k + A_1.
        let gb1 = if cur_in_h0 { &ws.h0[..d * lanes] } else { &ws.h1[..d * lanes] };
        let inv_k = E::recip_usize(k);
        for (i, &gv) in gb1.iter().enumerate() {
            ga[i] += gv;
            gz[i] += inv_k * gv;
        }
    }
}

/// Lane-wise `out[(p, q)] += a[p] * b[q]` over interleaved level slices —
/// the batched replay of [`super::mul::outer_add`]: `p` outer over `a`'s
/// elements, `q` inner over `b`'s, lanes contiguous innermost.
#[inline]
fn outer_add_lanes<E: Elem>(lanes: usize, a: &[E], b: &[E], out: &mut [E]) {
    let la = a.len() / lanes;
    let lb = b.len() / lanes;
    debug_assert_eq!(a.len(), la * lanes);
    debug_assert_eq!(b.len(), lb * lanes);
    debug_assert_eq!(out.len(), la * lb * lanes);
    for p in 0..la {
        let ap = &a[p * lanes..(p + 1) * lanes];
        let rows = &mut out[p * lb * lanes..(p + 1) * lb * lanes];
        for q in 0..lb {
            let bq = &b[q * lanes..(q + 1) * lanes];
            let row = &mut rows[q * lanes..(q + 1) * lanes];
            for ((rv, &av), &bv) in row.iter_mut().zip(ap).zip(bq) {
                *rv += av * bv;
            }
        }
    }
}

/// The loop body shared by [`mul_nounit_batch_into`] and
/// [`inverse_batch_into`] (which needs it while mutably borrowing the
/// workspace scratch): the no-unit ⊠ replaying
/// [`super::mul::mul_nounit_into`] per lane.
fn mul_nounit_lanes<E: Elem>(spec: &SigSpec, lanes: usize, a: &[E], b: &[E], out: &mut [E]) {
    let n = spec.depth();
    debug_assert_eq!(a.len(), spec.sig_len() * lanes);
    debug_assert_eq!(b.len(), spec.sig_len() * lanes);
    debug_assert_eq!(out.len(), spec.sig_len() * lanes);
    for k in 1..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let dst = &mut out[ok * lanes..(ok + lk) * lanes];
        dst.fill(E::ZERO);
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            outer_add_lanes(
                lanes,
                &a[oi * lanes..(oi + li) * lanes],
                &b[oj * lanes..(oj + lj) * lanes],
                dst,
            );
        }
    }
}

/// Batched full ⊠ with implicit units: `out_l = a_l ⊠ b_l` for every lane,
/// on lane-interleaved buffers (`sig_len * lanes` each; `out` may not alias
/// the inputs). Replays [`super::mul::mul_into`]'s op order per lane —
/// levels ascending, unit terms first, then the `A_i ⊗ B_{k-i}` outer
/// products in `i` order — so results are **bitwise identical** per lane.
/// This is the kernel behind batched window-slide advancement: one call
/// advances `lanes` stored-inverse Chen combinations `I_i ⊠ S_j` (§5.5).
pub fn mul_batch_into<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    b: &[E],
    out: &mut [E],
    ws: &mut BatchWorkspace<E>,
) {
    let n = spec.depth();
    let lanes = ws.lanes;
    debug_assert_eq!(a.len(), spec.sig_len() * lanes);
    debug_assert_eq!(b.len(), spec.sig_len() * lanes);
    debug_assert_eq!(out.len(), spec.sig_len() * lanes);
    for k in 1..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let dst = &mut out[ok * lanes..(ok + lk) * lanes];
        let ak = &a[ok * lanes..(ok + lk) * lanes];
        let bk = &b[ok * lanes..(ok + lk) * lanes];
        // A_0 ⊗ B_k + A_k ⊗ B_0 = A_k + B_k (lane-wise).
        for ((dv, &x), &y) in dst.iter_mut().zip(ak).zip(bk) {
            *dv = x + y;
        }
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            outer_add_lanes(
                lanes,
                &a[oi * lanes..(oi + li) * lanes],
                &b[oj * lanes..(oj + lj) * lanes],
                dst,
            );
        }
    }
}

/// Batched no-unit ⊠ (both inputs treated as having zero scalar term):
/// `out_k = Σ_{i=1}^{k-1} a_i ⊗ b_{k-i}` per lane. Bitwise identical per
/// lane to [`super::mul::mul_nounit_into`].
pub fn mul_nounit_batch_into<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    b: &[E],
    out: &mut [E],
    ws: &mut BatchWorkspace<E>,
) {
    mul_nounit_lanes(spec, ws.lanes, a, b, out);
}

/// Batched group inverse: `out_l = x_l^{-1}` per lane, via the same
/// Horner-style fixpoint as [`super::inverse::inverse_into`]
/// (`t_1 = -x; t_i = -(x + x ⊠' t_{i-1})`), using `ws.t2` as the
/// lane-interleaved `x ⊠' t` scratch. Bitwise identical per lane.
pub fn inverse_batch_into<E: Elem>(
    spec: &SigSpec,
    x: &[E],
    out: &mut [E],
    ws: &mut BatchWorkspace<E>,
) {
    let n = spec.depth();
    let lanes = ws.lanes;
    debug_assert_eq!(x.len(), spec.sig_len() * lanes);
    debug_assert_eq!(out.len(), spec.sig_len() * lanes);
    // t_1 = -x.
    for (o, &xv) in out.iter_mut().zip(x) {
        *o = -xv;
    }
    if n == 1 {
        return;
    }
    let len = spec.sig_len() * lanes;
    for _ in 2..=n {
        mul_nounit_lanes(spec, lanes, x, out, &mut ws.t2[..len]);
        for ((o, &xv), &pv) in out.iter_mut().zip(x).zip(ws.t2[..len].iter()) {
            *o = -(xv + pv);
        }
    }
}

/// Batched in-place tensor exponential: on entry `out[..d * lanes]` holds
/// the increments `z_l` (lane-interleaved), on exit `out_l = exp(z_l)` for
/// every lane — the batched twin of [`super::exp::exp_in_place`], replaying
/// `E_k = E_{k-1} ⊗ (z/k)` in the same op order so each lane is bitwise
/// identical to the scalar kernel. This is the adjacent-interval
/// (`j == i + 1`) window-slide case: `Sig(x_i..x_{i+1}) = exp(x_{i+1} - x_i)`.
pub fn exp_batch_in_place<E: Elem>(spec: &SigSpec, out: &mut [E], ws: &mut BatchWorkspace<E>) {
    let d = spec.d();
    let lanes = ws.lanes;
    debug_assert_eq!(out.len(), spec.sig_len() * lanes);
    for k in 2..=spec.depth() {
        let inv_k = E::recip_usize(k);
        let (lo, hi) = out.split_at_mut(spec.off(k) * lanes);
        let z = &lo[..d * lanes];
        let prev = &lo[spec.off(k - 1) * lanes..];
        let dst = &mut hi[..spec.level_len(k) * lanes];
        // E_k = E_{k-1} ⊗ (z / k), lanes innermost.
        for p in 0..prev.len() / lanes {
            let ep = &prev[p * lanes..(p + 1) * lanes];
            let rows = &mut dst[p * d * lanes..(p + 1) * d * lanes];
            for q in 0..d {
                let zq = &z[q * lanes..(q + 1) * lanes];
                let row = &mut rows[q * lanes..(q + 1) * lanes];
                for ((rv, &ev), &zv) in row.iter_mut().zip(ep).zip(zq) {
                    *rv = ev * zv * inv_k;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::property;
    use crate::ta::exp::exp_in_place;
    use crate::ta::fused::{fused_mexp, fused_mexp_left, fused_mexp_vjp};
    use crate::ta::inverse::inverse_into;
    use crate::ta::mul::{mul_into, mul_nounit_into};
    use crate::ta::Workspace;

    #[test]
    fn pack_unpack_roundtrip() {
        let rows = [vec![1.0f32, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let mut inter = vec![0.0f32; 6];
        pack_lanes(3, 2, |l| rows[l].as_slice(), &mut inter);
        assert_eq!(inter, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        let mut out = vec![0.0f32; 3];
        unpack_lane(3, 2, &inter, 1, &mut out);
        assert_eq!(out, rows[1]);
    }

    #[test]
    fn batch_forward_is_bitwise_per_lane() {
        // Each lane of fused_mexp_batch must reproduce the scalar
        // fused_mexp bit-for-bit: the lane kernel performs the same ops in
        // the same order, just interleaved.
        property("fused_mexp_batch == fused_mexp bitwise", 30, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 6 });
            let lanes = g.usize_in(1, 7);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let s = SigSpec::new(d, n).unwrap();
            let len = s.sig_len();
            let a_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(len, 0.8)).collect();
            let z_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(d, 0.8)).collect();
            let mut a = vec![0.0f32; len * lanes];
            let mut z = vec![0.0f32; d * lanes];
            pack_lanes(len, lanes, |l| a_rows[l].as_slice(), &mut a);
            pack_lanes(d, lanes, |l| z_rows[l].as_slice(), &mut z);
            let mut bws = BatchWorkspace::new(&s, lanes);
            fused_mexp_batch(&s, &mut a, &z, &mut bws);
            let mut ws = Workspace::new(&s);
            let mut row = vec![0.0f32; len];
            for l in 0..lanes {
                let mut expect = a_rows[l].clone();
                fused_mexp(&s, &mut expect, &z_rows[l], &mut ws);
                unpack_lane(len, lanes, &a, l, &mut row);
                assert_eq!(row, expect, "lane {l} diverged from scalar fused_mexp");
            }
        });
    }

    #[test]
    fn batch_left_is_bitwise_per_lane() {
        property("fused_mexp_left_batch == fused_mexp_left bitwise", 30, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 6 });
            let lanes = g.usize_in(1, 7);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let s = SigSpec::new(d, n).unwrap();
            let len = s.sig_len();
            let a_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(len, 0.8)).collect();
            let z_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(d, 0.8)).collect();
            let mut a = vec![0.0f32; len * lanes];
            let mut z = vec![0.0f32; d * lanes];
            pack_lanes(len, lanes, |l| a_rows[l].as_slice(), &mut a);
            pack_lanes(d, lanes, |l| z_rows[l].as_slice(), &mut z);
            let mut bws = BatchWorkspace::new(&s, lanes);
            fused_mexp_left_batch(&s, &mut a, &z, &mut bws);
            let mut ws = Workspace::new(&s);
            let mut row = vec![0.0f32; len];
            for l in 0..lanes {
                let mut expect = a_rows[l].clone();
                fused_mexp_left(&s, &mut expect, &z_rows[l], &mut ws);
                unpack_lane(len, lanes, &a, l, &mut row);
                assert_eq!(row, expect, "lane {l} diverged from scalar fused_mexp_left");
            }
        });
    }

    /// Shared body for the per-lane bitwise VJP checks: packs `lanes`
    /// random problems, runs the batched VJP, and compares every lane
    /// against scalar dispatch (`fused_mexp_vjp`) with `assert_eq`.
    fn check_vjp_bitwise_f32(s: &SigSpec, lanes: usize, seed: u64) {
        let d = s.d();
        let len = s.sig_len();
        let mut rng = crate::substrate::rng::Rng::new(seed);
        let a_rows: Vec<Vec<f32>> = (0..lanes).map(|_| rng.normal_vec(len, 0.6)).collect();
        let z_rows: Vec<Vec<f32>> = (0..lanes).map(|_| rng.normal_vec(d, 0.6)).collect();
        let g_rows: Vec<Vec<f32>> = (0..lanes).map(|_| rng.normal_vec(len, 1.0)).collect();
        let mut a = vec![0.0f32; len * lanes];
        let mut z = vec![0.0f32; d * lanes];
        let mut cot = vec![0.0f32; len * lanes];
        pack_lanes(len, lanes, |l| a_rows[l].as_slice(), &mut a);
        pack_lanes(d, lanes, |l| z_rows[l].as_slice(), &mut z);
        pack_lanes(len, lanes, |l| g_rows[l].as_slice(), &mut cot);
        let mut ga = vec![0.0f32; len * lanes];
        let mut gz = vec![0.0f32; d * lanes];
        let mut bws = BatchWorkspace::new(s, lanes);
        fused_mexp_vjp_batch(s, &a, &z, &cot, &mut ga, &mut gz, &mut bws);
        let mut ws = Workspace::new(s);
        let mut ga_row = vec![0.0f32; len];
        let mut gz_row = vec![0.0f32; d];
        for l in 0..lanes {
            let mut ga_ref = s.zeros();
            let mut gz_ref = vec![0.0f32; d];
            fused_mexp_vjp(s, &a_rows[l], &z_rows[l], &g_rows[l], &mut ga_ref, &mut gz_ref, &mut ws);
            unpack_lane(len, lanes, &ga, l, &mut ga_row);
            unpack_lane(d, lanes, &gz, l, &mut gz_row);
            assert_eq!(ga_row, ga_ref, "lane {l} ga diverged (d={d} lanes={lanes})");
            assert_eq!(gz_row, gz_ref, "lane {l} gz diverged (d={d} lanes={lanes})");
        }
    }

    /// The f64 twin of [`check_vjp_bitwise_f32`].
    fn check_vjp_bitwise_f64(s: &SigSpec, lanes: usize, seed: u64) {
        let d = s.d();
        let len = s.sig_len();
        let mut rng = crate::substrate::rng::Rng::new(seed);
        let up = |v: Vec<f32>| -> Vec<f64> { v.into_iter().map(|x| x as f64).collect() };
        let a_rows: Vec<Vec<f64>> = (0..lanes).map(|_| up(rng.normal_vec(len, 0.6))).collect();
        let z_rows: Vec<Vec<f64>> = (0..lanes).map(|_| up(rng.normal_vec(d, 0.6))).collect();
        let g_rows: Vec<Vec<f64>> = (0..lanes).map(|_| up(rng.normal_vec(len, 1.0))).collect();
        let mut a = vec![0.0f64; len * lanes];
        let mut z = vec![0.0f64; d * lanes];
        let mut cot = vec![0.0f64; len * lanes];
        pack_lanes(len, lanes, |l| a_rows[l].as_slice(), &mut a);
        pack_lanes(d, lanes, |l| z_rows[l].as_slice(), &mut z);
        pack_lanes(len, lanes, |l| g_rows[l].as_slice(), &mut cot);
        let mut ga = vec![0.0f64; len * lanes];
        let mut gz = vec![0.0f64; d * lanes];
        let mut bws = BatchWorkspace::<f64>::new(s, lanes);
        fused_mexp_vjp_batch(s, &a, &z, &cot, &mut ga, &mut gz, &mut bws);
        let mut ws = Workspace::<f64>::new(s);
        let mut ga_row = vec![0.0f64; len];
        let mut gz_row = vec![0.0f64; d];
        for l in 0..lanes {
            let mut ga_ref = s.zeros_elem::<f64>();
            let mut gz_ref = vec![0.0f64; d];
            fused_mexp_vjp(s, &a_rows[l], &z_rows[l], &g_rows[l], &mut ga_ref, &mut gz_ref, &mut ws);
            unpack_lane(len, lanes, &ga, l, &mut ga_row);
            unpack_lane(d, lanes, &gz, l, &mut gz_row);
            assert_eq!(ga_row, ga_ref, "lane {l} ga diverged (f64 d={d} lanes={lanes})");
            assert_eq!(gz_row, gz_ref, "lane {l} gz diverged (f64 d={d} lanes={lanes})");
        }
    }

    #[test]
    fn batch_vjp_is_bitwise_per_lane_at_any_d() {
        // The batched backward mirrors the scalar Horner backward
        // op-for-op at every d (mono bodies for d <= 8, fused_mexp_vjp_dyn
        // beyond), so it must match scalar dispatch bit-for-bit per lane.
        property("fused_mexp_vjp_batch == fused_mexp_vjp bitwise", 20, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 5 });
            let lanes = g.usize_in(1, 6);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let s = SigSpec::new(d, n).unwrap();
            check_vjp_bitwise_f32(&s, lanes, g.usize_in(1, 100_000) as u64);
        });
    }

    #[test]
    fn batch_vjp_bitwise_across_the_dimension_sweep_f32() {
        // The issue's pinned sweep: d ∈ {3, 8, 9, 12, 20}, including lane
        // counts that leave ragged tails against the planner's block size
        // (LANE_BLOCK = 16 → lanes ∈ {3, 5} exercise partial blocks).
        for &(d, n) in &[(3usize, 4usize), (8, 3), (9, 3), (12, 3), (20, 2)] {
            let s = SigSpec::new(d, n).unwrap();
            for &lanes in &[1usize, 3, 5] {
                check_vjp_bitwise_f32(&s, lanes, 100 + (d * 10 + lanes) as u64);
            }
        }
    }

    #[test]
    fn batch_vjp_bitwise_across_the_dimension_sweep_f64() {
        for &(d, n) in &[(3usize, 4usize), (8, 3), (9, 3), (12, 3), (20, 2)] {
            let s = SigSpec::new(d, n).unwrap();
            for &lanes in &[1usize, 3, 5] {
                check_vjp_bitwise_f64(&s, lanes, 200 + (d * 10 + lanes) as u64);
            }
        }
    }

    #[test]
    fn mul_batch_is_bitwise_per_lane() {
        // Each lane of mul_batch_into must reproduce scalar mul_into
        // bit-for-bit — same op order (levels ascending, unit terms, then
        // outer products in i order), just interleaved.
        property("mul_batch_into == mul_into bitwise", 30, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 6 });
            let lanes = g.usize_in(1, 7);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let s = SigSpec::new(d, n).unwrap();
            let len = s.sig_len();
            let a_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(len, 0.7)).collect();
            let b_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(len, 0.7)).collect();
            let mut a = vec![0.0f32; len * lanes];
            let mut b = vec![0.0f32; len * lanes];
            pack_lanes(len, lanes, |l| a_rows[l].as_slice(), &mut a);
            pack_lanes(len, lanes, |l| b_rows[l].as_slice(), &mut b);
            let mut out = vec![0.0f32; len * lanes];
            let mut nou = vec![0.0f32; len * lanes];
            let mut bws = BatchWorkspace::new(&s, lanes);
            mul_batch_into(&s, &a, &b, &mut out, &mut bws);
            mul_nounit_batch_into(&s, &a, &b, &mut nou, &mut bws);
            let mut row = vec![0.0f32; len];
            for l in 0..lanes {
                let mut expect = s.zeros();
                mul_into(&s, &a_rows[l], &b_rows[l], &mut expect);
                unpack_lane(len, lanes, &out, l, &mut row);
                assert_eq!(row, expect, "lane {l} diverged from scalar mul_into");
                let mut expect_nou = s.zeros();
                mul_nounit_into(&s, &a_rows[l], &b_rows[l], &mut expect_nou);
                unpack_lane(len, lanes, &nou, l, &mut row);
                assert_eq!(row, expect_nou, "lane {l} diverged from scalar mul_nounit_into");
            }
        });
    }

    #[test]
    fn inverse_batch_is_bitwise_per_lane() {
        property("inverse_batch_into == inverse_into bitwise", 30, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 6 });
            let lanes = g.usize_in(1, 7);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let s = SigSpec::new(d, n).unwrap();
            let len = s.sig_len();
            let x_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(len, 0.6)).collect();
            let mut x = vec![0.0f32; len * lanes];
            pack_lanes(len, lanes, |l| x_rows[l].as_slice(), &mut x);
            let mut out = vec![0.0f32; len * lanes];
            let mut bws = BatchWorkspace::new(&s, lanes);
            inverse_batch_into(&s, &x, &mut out, &mut bws);
            let mut row = vec![0.0f32; len];
            for l in 0..lanes {
                let mut expect = s.zeros();
                inverse_into(&s, &x_rows[l], &mut expect);
                unpack_lane(len, lanes, &out, l, &mut row);
                assert_eq!(row, expect, "lane {l} diverged from scalar inverse_into");
            }
        });
    }

    #[test]
    fn exp_batch_is_bitwise_per_lane() {
        // exp_batch_in_place consumes the staged level-1 increments and
        // fully overwrites levels >= 2, exactly like the scalar twin.
        property("exp_batch_in_place == exp_in_place bitwise", 30, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 6 });
            let lanes = g.usize_in(1, 7);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let s = SigSpec::new(d, n).unwrap();
            let len = s.sig_len();
            let z_rows: Vec<Vec<f32>> = (0..lanes).map(|_| g.normal_vec(d, 0.8)).collect();
            let mut out = vec![0.0f32; len * lanes];
            pack_lanes(d, lanes, |l| z_rows[l].as_slice(), &mut out[..d * lanes]);
            let mut bws = BatchWorkspace::new(&s, lanes);
            exp_batch_in_place(&s, &mut out, &mut bws);
            let mut row = vec![0.0f32; len];
            for l in 0..lanes {
                let mut expect = s.zeros();
                expect[..d].copy_from_slice(&z_rows[l]);
                exp_in_place(&s, &mut expect);
                unpack_lane(len, lanes, &out, l, &mut row);
                assert_eq!(row, expect, "lane {l} diverged from scalar exp_in_place");
            }
        });
    }

    #[test]
    fn chen_family_batch_bitwise_f64_sweep() {
        // The f64 instantiations of the Chen-family lane kernels replay the
        // same op order at their own precision — pinned on the dimension
        // sweep with ragged lane counts.
        let up = |v: Vec<f32>| -> Vec<f64> { v.into_iter().map(|x| x as f64).collect() };
        for &(d, n) in &[(3usize, 4usize), (8, 3), (12, 3), (20, 2)] {
            let s = SigSpec::new(d, n).unwrap();
            let len = s.sig_len();
            for &lanes in &[1usize, 3, 5] {
                let mut rng = crate::substrate::rng::Rng::new(300 + (d * 10 + lanes) as u64);
                let a_rows: Vec<Vec<f64>> =
                    (0..lanes).map(|_| up(rng.normal_vec(len, 0.6))).collect();
                let b_rows: Vec<Vec<f64>> =
                    (0..lanes).map(|_| up(rng.normal_vec(len, 0.6))).collect();
                let z_rows: Vec<Vec<f64>> = (0..lanes).map(|_| up(rng.normal_vec(d, 0.8))).collect();
                let mut a = vec![0.0f64; len * lanes];
                let mut b = vec![0.0f64; len * lanes];
                pack_lanes(len, lanes, |l| a_rows[l].as_slice(), &mut a);
                pack_lanes(len, lanes, |l| b_rows[l].as_slice(), &mut b);
                let mut bws = BatchWorkspace::<f64>::new(&s, lanes);
                let mut prod = vec![0.0f64; len * lanes];
                let mut inv = vec![0.0f64; len * lanes];
                let mut expv = vec![0.0f64; len * lanes];
                mul_batch_into(&s, &a, &b, &mut prod, &mut bws);
                inverse_batch_into(&s, &a, &mut inv, &mut bws);
                pack_lanes(d, lanes, |l| z_rows[l].as_slice(), &mut expv[..d * lanes]);
                exp_batch_in_place(&s, &mut expv, &mut bws);
                let mut row = vec![0.0f64; len];
                for l in 0..lanes {
                    let mut want = s.zeros_elem::<f64>();
                    mul_into(&s, &a_rows[l], &b_rows[l], &mut want);
                    unpack_lane(len, lanes, &prod, l, &mut row);
                    assert_eq!(row, want, "mul lane {l} (f64 d={d} lanes={lanes})");
                    let mut want = s.zeros_elem::<f64>();
                    inverse_into(&s, &a_rows[l], &mut want);
                    unpack_lane(len, lanes, &inv, l, &mut row);
                    assert_eq!(row, want, "inverse lane {l} (f64 d={d} lanes={lanes})");
                    let mut want = s.zeros_elem::<f64>();
                    want[..d].copy_from_slice(&z_rows[l]);
                    exp_in_place(&s, &mut want);
                    unpack_lane(len, lanes, &expv, l, &mut row);
                    assert_eq!(row, want, "exp lane {l} (f64 d={d} lanes={lanes})");
                }
            }
        }
    }

    #[test]
    fn single_lane_is_the_scalar_kernel() {
        // lanes = 1 interleaving is the identity layout: the batch kernel
        // degenerates to the scalar one on the same buffers.
        let s = SigSpec::new(3, 4).unwrap();
        let mut rng = crate::substrate::rng::Rng::new(7);
        let a0 = rng.normal_vec(s.sig_len(), 0.5);
        let z = rng.normal_vec(3, 0.5);
        let mut batch = a0.clone();
        let mut scalar = a0;
        let mut bws = BatchWorkspace::new(&s, 1);
        let mut ws = Workspace::new(&s);
        fused_mexp_batch(&s, &mut batch, &z, &mut bws);
        fused_mexp(&s, &mut scalar, &z, &mut ws);
        assert_eq!(batch, scalar);
    }

    #[test]
    fn workspace_sizes_scale_with_lanes() {
        let s = SigSpec::new(3, 4).unwrap();
        let w: BatchWorkspace = BatchWorkspace::new(&s, 5);
        assert_eq!(w.lanes(), 5);
        assert_eq!(w.h0.len(), 27 * 5); // d^(N-1) per lane
        assert_eq!(w.zdiv.len(), 12 * 5);
        assert_eq!(w.t2.len(), s.sig_len() * 5);
        assert_eq!(w.gza.len(), 3 * 5);
    }
}
