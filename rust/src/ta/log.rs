//! The tensor logarithm (§2.3, eq. (4)) and its handwritten VJP.
//!
//! For `x` the non-unit part of a group-like element (our storage never
//! holds the unit), `log(1 + x) = Σ_{k=1..N} (-1)^{k+1} x^{⊠k} / k`,
//! evaluated by a Horner scheme over elements with an explicit scalar part:
//!
//! ```text
//! log(1+x) = x ⊠ r_1,   r_N = 1/N,   r_m = 1/m - x ⊠ r_{m+1}
//! ```
//!
//! where each `r_m = (s_m, t_m)` is a scalar plus a non-unit tensor and
//! `x ⊠ (s + t) = s·x + x ⊠_nounit t`. This costs `N-1` non-unit products.
//! All routines are generic over the sealed element trait [`Elem`]
//! (f32/f64); the default type parameter keeps existing f32 call sites
//! compiling unchanged.

use super::mul::{mul_nounit_into, mul_nounit_vjp};
use super::{Elem, SigSpec};

/// Reusable scratch for [`log_into_ws`]: the Horner recursion's running
/// tensor `t` and the product buffer `x ⊠_nounit t`. One workspace serves
/// any number of calls against the same spec — the batched logsignature
/// epilogue and `Path::logsig_query_into` reuse one across lanes/queries
/// instead of allocating two `sig_len` buffers per log.
pub struct LogWorkspace<E: Elem = f32> {
    t: Vec<E>,
    xt: Vec<E>,
}

impl<E: Elem> LogWorkspace<E> {
    pub fn new(spec: &SigSpec) -> LogWorkspace<E> {
        LogWorkspace { t: spec.zeros_elem::<E>(), xt: spec.zeros_elem::<E>() }
    }

    /// Whether this workspace was sized for `spec`.
    pub fn fits(&self, spec: &SigSpec) -> bool {
        self.t.len() == spec.sig_len()
    }
}

/// `out = log(x)` where `x` is the non-unit part of a group-like element.
pub fn log_into<E: Elem>(spec: &SigSpec, x: &[E], out: &mut [E]) {
    let mut ws = LogWorkspace::<E>::new(spec);
    log_into_ws(spec, x, out, &mut ws);
}

/// [`log_into`] reusing caller-owned scratch: identical op sequence (the
/// workspace buffers are fully (re)initialised before use), so results
/// are bitwise identical however the workspace was previously used.
pub fn log_into_ws<E: Elem>(spec: &SigSpec, x: &[E], out: &mut [E], ws: &mut LogWorkspace<E>) {
    let n = spec.depth();
    debug_assert_eq!(x.len(), spec.sig_len());
    debug_assert_eq!(out.len(), spec.sig_len());
    debug_assert!(ws.fits(spec));
    if n == 1 {
        out.copy_from_slice(x);
        return;
    }
    // r = (s, t); start at r_N = (1/N, 0).
    let mut s = E::recip_usize(n);
    let t = &mut ws.t;
    let xt = &mut ws.xt;
    t.fill(E::ZERO);
    for m in (1..n).rev() {
        // r_m = 1/m - x ⊠ r_{m+1} = (1/m, -(s·x + x ⊠_nounit t)).
        mul_nounit_into(spec, x, t, xt);
        for ((tv, &xv), &pv) in t.iter_mut().zip(x).zip(xt.iter()) {
            *tv = -(s * xv + pv);
        }
        s = E::recip_usize(m);
    }
    // log = x ⊠ r_1 = s·x + x ⊠_nounit t   (s = 1 here).
    debug_assert_eq!(s, E::ONE);
    mul_nounit_into(spec, x, t, out);
    for (ov, &xv) in out.iter_mut().zip(x) {
        *ov += s * xv;
    }
}

/// Allocating wrapper around [`log_into`].
pub fn log<E: Elem>(spec: &SigSpec, x: &[E]) -> Vec<E> {
    let mut out = spec.zeros_elem::<E>();
    log_into(spec, x, &mut out);
    out
}

/// VJP of `y = log(x)`: accumulates `∂L/∂x` into `gx` given `g = ∂L/∂y`.
///
/// Re-runs the Horner recursion storing each `t_m`, then reverses it.
pub fn log_vjp<E: Elem>(spec: &SigSpec, x: &[E], g: &[E], gx: &mut [E]) {
    let n = spec.depth();
    if n == 1 {
        for (o, &gv) in gx.iter_mut().zip(g) {
            *o += gv;
        }
        return;
    }
    // Forward replay, storing t_{m} for m = N..1 (t_hist[0] = t_N = 0, ...,
    // t_hist[N-1] = t_1) and the scalars s_m = 1/m.
    let mut t_hist: Vec<Vec<E>> = Vec::with_capacity(n);
    let mut t = spec.zeros_elem::<E>();
    t_hist.push(t.clone()); // t_N
    let mut xt = spec.zeros_elem::<E>();
    for m in (1..n).rev() {
        let s = E::recip_usize(m + 1); // scalar of r_{m+1}
        mul_nounit_into(spec, x, &t, &mut xt);
        let mut t_new = spec.zeros_elem::<E>();
        for (((tv, &xv), &pv), _) in t_new.iter_mut().zip(x).zip(xt.iter()).zip(0..) {
            *tv = -(s * xv + pv);
        }
        t = t_new;
        t_hist.push(t.clone());
    }
    // t_hist[idx] = t_{N - idx}.
    let t_m = |m: usize| &t_hist[n - m];

    // Reverse: log = 1·x + x ⊠_nounit t_1.
    let mut gt = spec.zeros_elem::<E>(); // gradient wrt t_1
    for (o, &gv) in gx.iter_mut().zip(g) {
        *o += gv;
    }
    mul_nounit_vjp(spec, x, t_m(1), g, gx, &mut gt);
    // For m = 1..N-1: t_m = -(s_{m+1}·x + x ⊠_nounit t_{m+1}).
    for m in 1..n {
        let s_next = E::recip_usize(m + 1);
        // gx += -s_next * gt ; (gx, gt_next) += vjp of x ⊠_nounit t_{m+1} with cotangent -gt.
        let neg_gt: Vec<E> = gt.iter().map(|&v| -v).collect();
        for (o, &gv) in gx.iter_mut().zip(&neg_gt) {
            *o += s_next * gv;
        }
        let mut gt_next = spec.zeros_elem::<E>();
        mul_nounit_vjp(spec, x, t_m(m + 1), &neg_gt, gx, &mut gt_next);
        gt = gt_next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::ta::{exp, mul};

    #[test]
    fn log_of_exp_is_z_padded() {
        // log(exp(z)) = (z, 0, 0, ...): the log of a one-segment signature
        // is the increment placed in level 1.
        property("log ∘ exp = id", 30, |g| {
            let d = g.usize_in(1, 5);
            let n = g.usize_in(1, 6);
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let z = g.normal_vec(d, 0.7);
            let l = log(&s, &exp(&s, &z));
            let mut expect = s.zeros();
            expect[..d].copy_from_slice(&z);
            assert_close(&l, &expect, 1e-4, 1e-5);
        });
    }

    #[test]
    fn log_d1_closed_form() {
        // d=1 group-likes are exp(z); log of arbitrary (x1, x2) at N=2 is
        // (x1, x2 - x1^2/2).
        let s = SigSpec::new(1, 2).unwrap();
        let l = log(&s, &[3.0f32, 7.0]);
        assert_close(&l, &[3.0, 7.0 - 4.5], 1e-6, 1e-7);
    }

    #[test]
    fn log_f64_agrees_with_f32_on_representable_inputs() {
        let s = SigSpec::new(2, 4).unwrap();
        let mut rng = crate::substrate::rng::Rng::new(11);
        let x32 = rng.normal_vec(s.sig_len(), 0.5);
        let x64: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
        let l32 = log(&s, &x32);
        let l64 = log(&s, &x64);
        for (a, b) in l32.iter().zip(&l64) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn log_level2_antisymmetrisation() {
        // For a group-like element, log level 2 is the antisymmetric part
        // of level 2: log_2 = x_2 - (x_1 ⊗ x_1)/2.
        let s = SigSpec::new(3, 2).unwrap();
        let z1 = [0.5f32, -1.0, 0.25];
        let z2 = [0.3f32, 0.8, -0.6];
        let sig = mul(&s, &exp(&s, &z1), &exp(&s, &z2));
        let l = log(&s, &sig);
        // Level 1 of log = total increment.
        for i in 0..3 {
            assert!((l[i] - (z1[i] + z2[i])).abs() < 1e-5);
        }
        // Level 2 of log should be antisymmetric.
        let l2 = s.level(&l, 2);
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (l2[i * 3 + j] + l2[j * 3 + i]).abs() < 1e-5,
                    "not antisymmetric at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn log_into_ws_reuse_is_bitwise_identical() {
        // A dirty, repeatedly reused workspace must never change a single
        // bit of the result — the batched logsignature epilogue relies on
        // this for its per-lane parity with the scalar path.
        property("log ws reuse bitwise", 20, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 6);
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = LogWorkspace::new(&s);
            for _ in 0..3 {
                let x = g.normal_vec(s.sig_len(), 0.5);
                let fresh = log(&s, &x);
                let mut reused = s.zeros();
                log_into_ws(&s, &x, &mut reused, &mut ws);
                assert_eq!(reused, fresh);
            }
        });
    }

    #[test]
    fn log_workspace_fits_checks_spec() {
        let a = SigSpec::new(2, 3).unwrap();
        let b = SigSpec::new(3, 3).unwrap();
        let ws: LogWorkspace = LogWorkspace::new(&a);
        assert!(ws.fits(&a));
        assert!(!ws.fits(&b));
    }

    #[test]
    fn log_depth1_is_identity() {
        let s = SigSpec::new(4, 1).unwrap();
        let x = [1.0f32, -2.0, 3.0, -4.0];
        assert_eq!(log(&s, &x), x.to_vec());
    }

    #[test]
    fn log_vjp_matches_finite_differences() {
        property("log vjp fd", 6, |gen| {
            let d = gen.usize_in(1, 3);
            let n = gen.usize_in(1, 4);
            gen.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let x = gen.normal_vec(s.sig_len(), 0.4);
            let g = gen.normal_vec(s.sig_len(), 1.0);
            let mut gx = s.zeros();
            log_vjp(&s, &x, &g, &mut gx);
            let h = 1e-2f32;
            for i in 0..x.len() {
                let mut xp = x.clone();
                xp[i] += h;
                let mut xm = x.clone();
                xm[i] -= h;
                let fd: f32 = log(&s, &xp)
                    .iter()
                    .zip(log(&s, &xm).iter())
                    .zip(&g)
                    .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                    .sum();
                assert!(
                    (fd - gx[i]).abs() < 3e-2 * (1.0 + fd.abs()),
                    "gx[{i}]: fd={fd} vjp={}",
                    gx[i]
                );
            }
        });
    }
}
