//! The **fused multiply-exponentiate** (§4.1, App. A.1) — the paper's key
//! algorithmic improvement and this library's hot path.
//!
//! `fused_mexp` computes `A ← A ⊠ exp(z)` *in place* via the Horner scheme
//! of eq. (5):
//!
//! ```text
//! (A ⊠ exp(z))_k =
//!   ((...((z/k + A_1) ⊗ z/(k-1) + A_2) ⊗ z/(k-2) + ...) ⊗ z/2 + A_{k-1}) ⊗ z + A_k
//! ```
//!
//! using `F(d,N) = d(N-1) + Σ_k Σ_{i=2..k} d^i = O(d^N)` scalar
//! multiplications versus the conventional `C(d,N) = Θ(N d^N)` (see
//! [`super::opcount`]). In-place evaluation is possible because the output
//! level `k` depends only on input levels `i ≤ k`: processing levels from
//! `N` downward never reads an overwritten level.
//!
//! `fused_mexp_left` is the mirrored `A ← exp(z) ⊠ A`, used to maintain
//! *inverted* signatures incrementally (`InvertSig_{j} = exp(-z_j) ⊠
//! InvertSig_{j-1}`) for the Path class (§4.2).
//!
//! Everything here is generic over the sealed element trait
//! [`Elem`] (f32/f64); existing `&[f32]` call sites infer `E = f32`
//! unchanged. The forward and the VJP each have **two** interchangeable
//! bodies: a `const D`-monomorphised one whose innermost channel loops have
//! a compile-time trip count, and a runtime-`d` one
//! ([`fused_mexp_generic`], [`fused_mexp_vjp_dyn`]) that replays the *same*
//! floating-point op order with a runtime trip count. The dispatchers pick
//! the mono body for `d ≤ 8` — the crossover is benchmark-arbitrated
//! (`benches/batch_lanes.rs` records mono-vs-dyn timings in
//! `BENCH_batch.json`) — and the dyn body everywhere else, so every `d`
//! rides the fast Horner VJP and the two bodies are bitwise-identical
//! wherever they overlap.

use super::exp::{exp_into, exp_vjp};
use super::mul::{mul_vjp, outer_add};
use super::{Elem, SigSpec, Workspace};

/// Stage `z/m` for `m = 1..=depth` into `ws.zdiv` (row `m-1` holds `z/m`).
#[inline]
fn stage_zdiv<E: Elem>(spec: &SigSpec, z: &[E], ws: &mut Workspace<E>) {
    let d = spec.d();
    for m in 1..=spec.depth() {
        let inv = E::recip_usize(m);
        let row = &mut ws.zdiv[(m - 1) * d..m * d];
        for (r, &zq) in row.iter_mut().zip(z) {
            *r = zq * inv;
        }
    }
}

/// In-place fused multiply-exponentiate: `a ← a ⊠ exp(z)`.
///
/// Dispatches to a `d`-monomorphised body for the paper's benchmark range
/// (`d ≤ 8`): the innermost Horner loops run over the `d` channels, and a
/// compile-time trip count lets them unroll/vectorise (§Perf: ~2–3×
/// wall-clock on the generic loop at small `d`). Beyond that the
/// runtime-`d` body takes over — same op order, so results are identical.
pub fn fused_mexp<E: Elem>(spec: &SigSpec, a: &mut [E], z: &[E], ws: &mut Workspace<E>) {
    match spec.d() {
        1 => fused_mexp_mono::<E, 1>(spec, a, z, ws),
        2 => fused_mexp_mono::<E, 2>(spec, a, z, ws),
        3 => fused_mexp_mono::<E, 3>(spec, a, z, ws),
        4 => fused_mexp_mono::<E, 4>(spec, a, z, ws),
        5 => fused_mexp_mono::<E, 5>(spec, a, z, ws),
        6 => fused_mexp_mono::<E, 6>(spec, a, z, ws),
        7 => fused_mexp_mono::<E, 7>(spec, a, z, ws),
        8 => fused_mexp_mono::<E, 8>(spec, a, z, ws),
        _ => fused_mexp_generic(spec, a, z, ws),
    }
}

#[inline(always)]
fn fused_mexp_mono<E: Elem, const D: usize>(
    spec: &SigSpec,
    a: &mut [E],
    z: &[E],
    ws: &mut Workspace<E>,
) {
    let n = spec.depth();
    debug_assert_eq!(spec.d(), D);
    debug_assert_eq!(a.len(), spec.sig_len());
    let z: &[E; D] = z.try_into().expect("z has d entries");
    stage_zdiv(spec, z, ws);
    for k in (2..=n).rev() {
        // B_1 = z/k + A_1.
        let b = &mut ws.h0[..D];
        let zk = &ws.zdiv[(k - 1) * D..k * D];
        for ((bv, &zv), &av) in b.iter_mut().zip(zk).zip(&a[..D]) {
            *bv = zv + av;
        }
        let mut cur_in_h0 = true;
        let mut cur_len = D;
        for i in 2..k {
            // B_i = B_{i-1} ⊗ (z / (k-i+1)) + A_i.
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (src, dst) = if cur_in_h0 {
                (&ws.h0[..cur_len], &mut ws.h1[..cur_len * D])
            } else {
                (&ws.h1[..cur_len], &mut ws.h0[..cur_len * D])
            };
            let zm: &[E; D] = (&ws.zdiv[(m - 1) * D..m * D]).try_into().unwrap();
            let ai = &a[oi..oi + li];
            for (p, &sp) in src.iter().enumerate() {
                let row: &mut [E; D] = (&mut dst[p * D..(p + 1) * D]).try_into().unwrap();
                let arow: &[E; D] = (&ai[p * D..(p + 1) * D]).try_into().unwrap();
                for q in 0..D {
                    row[q] = sp * zm[q] + arow[q];
                }
            }
            cur_in_h0 = !cur_in_h0;
            cur_len *= D;
        }
        // Final step writes into A_k in place: A_k += B_{k-1} ⊗ z.
        let ok = spec.off(k);
        let dst = &mut a[ok..ok + cur_len * D];
        let src = if cur_in_h0 { &ws.h0[..cur_len] } else { &ws.h1[..cur_len] };
        for (p, &sp) in src.iter().enumerate() {
            let row: &mut [E; D] = (&mut dst[p * D..(p + 1) * D]).try_into().unwrap();
            for q in 0..D {
                row[q] += sp * z[q];
            }
        }
    }
    // Level 1: A_1 += z.
    for (av, &zv) in a[..D].iter_mut().zip(z) {
        *av += zv;
    }
}

/// Runtime-`d` forward body: the same Horner scheme with a runtime channel
/// trip count. The innermost loops stay contiguous over the fastest axis,
/// so they vectorise for any `d`.
pub fn fused_mexp_generic<E: Elem>(spec: &SigSpec, a: &mut [E], z: &[E], ws: &mut Workspace<E>) {
    let d = spec.d();
    let n = spec.depth();
    debug_assert_eq!(a.len(), spec.sig_len());
    debug_assert_eq!(z.len(), d);
    stage_zdiv(spec, z, ws);
    for k in (2..=n).rev() {
        // B_1 = z/k + A_1.
        let b = &mut ws.h0[..d];
        let zk = &ws.zdiv[(k - 1) * d..k * d];
        for ((bv, &zv), &av) in b.iter_mut().zip(zk).zip(&a[..d]) {
            *bv = zv + av;
        }
        let mut cur_in_h0 = true;
        let mut cur_len = d;
        for i in 2..k {
            // B_i = B_{i-1} ⊗ (z / (k-i+1)) + A_i.
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (src, dst) = if cur_in_h0 {
                (&ws.h0[..cur_len], &mut ws.h1[..cur_len * d])
            } else {
                (&ws.h1[..cur_len], &mut ws.h0[..cur_len * d])
            };
            let zm = &ws.zdiv[(m - 1) * d..m * d];
            let ai = &a[oi..oi + li];
            for (p, &sp) in src.iter().enumerate() {
                let row = &mut dst[p * d..(p + 1) * d];
                let arow = &ai[p * d..(p + 1) * d];
                for q in 0..d {
                    row[q] = sp * zm[q] + arow[q];
                }
            }
            cur_in_h0 = !cur_in_h0;
            cur_len *= d;
        }
        // Final step writes into A_k in place: A_k += B_{k-1} ⊗ z.
        let ok = spec.off(k);
        let dst = &mut a[ok..ok + cur_len * d];
        let src = if cur_in_h0 { &ws.h0[..cur_len] } else { &ws.h1[..cur_len] };
        for (p, &sp) in src.iter().enumerate() {
            let row = &mut dst[p * d..(p + 1) * d];
            for (q, &zq) in z.iter().enumerate() {
                row[q] += sp * zq;
            }
        }
    }
    // Level 1: A_1 += z.
    for (av, &zv) in a[..d].iter_mut().zip(z) {
        *av += zv;
    }
}

/// In-place mirrored fused operation: `a ← exp(z) ⊠ a`, via
///
/// ```text
/// (exp(z) ⊠ A)_k = A_k + z ⊗ (A_{k-1} + (z/2) ⊗ (A_{k-2} + ... + (z/(k-1)) ⊗ (A_1 + z/k)))
/// ```
///
/// Here the ⊗ factor is on the *left*, so the inner loops already run over
/// the long (`cur_len`) axis contiguously and the generic version
/// vectorises as-is; no per-`d` monomorphisation needed (§Perf).
pub fn fused_mexp_left<E: Elem>(spec: &SigSpec, a: &mut [E], z: &[E], ws: &mut Workspace<E>) {
    let d = spec.d();
    let n = spec.depth();
    debug_assert_eq!(a.len(), spec.sig_len());
    debug_assert_eq!(z.len(), d);
    stage_zdiv(spec, z, ws);
    for k in (2..=n).rev() {
        // B_1 = A_1 + z/k.
        let b = &mut ws.h0[..d];
        let zk = &ws.zdiv[(k - 1) * d..k * d];
        for ((bv, &zv), &av) in b.iter_mut().zip(zk).zip(&a[..d]) {
            *bv = zv + av;
        }
        let mut cur_in_h0 = true;
        let mut cur_len = d;
        for i in 2..k {
            // B_i = A_i + (z/(k-i+1)) ⊗ B_{i-1}  (z factor on the left).
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (src, dst) = if cur_in_h0 {
                (&ws.h0[..cur_len], &mut ws.h1[..cur_len * d])
            } else {
                (&ws.h1[..cur_len], &mut ws.h0[..cur_len * d])
            };
            let zm = &ws.zdiv[(m - 1) * d..m * d];
            let ai = &a[oi..oi + li];
            for (q, &zq) in zm.iter().enumerate() {
                let row = &mut dst[q * cur_len..(q + 1) * cur_len];
                let arow = &ai[q * cur_len..(q + 1) * cur_len];
                for (p, &sp) in src.iter().enumerate() {
                    row[p] = zq * sp + arow[p];
                }
            }
            cur_in_h0 = !cur_in_h0;
            cur_len *= d;
        }
        // Final: A_k += z ⊗ B_{k-1}.
        let ok = spec.off(k);
        let dst = &mut a[ok..ok + cur_len * d];
        let src = if cur_in_h0 { &ws.h0[..cur_len] } else { &ws.h1[..cur_len] };
        for (q, &zq) in z.iter().enumerate() {
            let row = &mut dst[q * cur_len..(q + 1) * cur_len];
            for (p, &sp) in src.iter().enumerate() {
                row[p] += zq * sp;
            }
        }
    }
    for (av, &zv) in a[..d].iter_mut().zip(z) {
        *av += zv;
    }
}

/// Out-of-place fused multiply-exponentiate: `out = a ⊠ exp(z)`.
pub fn fused_mexp_into<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    out: &mut [E],
    ws: &mut Workspace<E>,
) {
    out.copy_from_slice(a);
    fused_mexp(spec, out, z, ws);
}

/// VJP of `C = A ⊠ exp(z)`: given `g = ∂L/∂C`, accumulates `∂L/∂A` into
/// `ga` and `∂L/∂z` into `gz`.
///
/// Reverse-mode through the Horner scheme itself (not through an explicit
/// `exp` + ⊠): per output level `k` the forward `B_i` chain is recomputed
/// (`O(d^{k-1})`) and unwound, so the whole VJP costs `O(d^N)` — the same
/// asymptotic order as the fused forward — instead of the `Θ(N d^N)` a
/// composition of ⊠-VJP and exp-VJP pays (App. C: the backward "can be
/// computed using the same subroutines, including the fused
/// multiply-exponentiate"). §Perf logs ~10× on the (7,7) backward.
///
/// Dispatch mirrors the forward: `const D` bodies for `d ≤ 8`
/// (benchmark-arbitrated crossover), [`fused_mexp_vjp_dyn`] — the same op
/// order with a runtime trip count — for every larger `d`. There is no
/// dimension at which the backward falls off the fast Horner path.
pub fn fused_mexp_vjp<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    g: &[E],
    ga: &mut [E],
    gz: &mut [E],
    ws: &mut Workspace<E>,
) {
    match spec.d() {
        1 => fused_mexp_vjp_mono::<E, 1>(spec, a, z, g, ga, gz, ws),
        2 => fused_mexp_vjp_mono::<E, 2>(spec, a, z, g, ga, gz, ws),
        3 => fused_mexp_vjp_mono::<E, 3>(spec, a, z, g, ga, gz, ws),
        4 => fused_mexp_vjp_mono::<E, 4>(spec, a, z, g, ga, gz, ws),
        5 => fused_mexp_vjp_mono::<E, 5>(spec, a, z, g, ga, gz, ws),
        6 => fused_mexp_vjp_mono::<E, 6>(spec, a, z, g, ga, gz, ws),
        7 => fused_mexp_vjp_mono::<E, 7>(spec, a, z, g, ga, gz, ws),
        8 => fused_mexp_vjp_mono::<E, 8>(spec, a, z, g, ga, gz, ws),
        _ => fused_mexp_vjp_dyn(spec, a, z, g, ga, gz, ws),
    }
}

fn fused_mexp_vjp_mono<E: Elem, const D: usize>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    g: &[E],
    ga: &mut [E],
    gz: &mut [E],
    ws: &mut Workspace<E>,
) {
    let n = spec.depth();
    let z: &[E; D] = z.try_into().expect("z has D entries");
    stage_zdiv(spec, z, ws);
    // Level 1: C_1 = A_1 + z.
    for q in 0..D {
        ga[q] += g[q];
        gz[q] += g[q];
    }
    for k in (2..=n).rev() {
        // Recompute the forward Horner chain for level k, storing B_i at
        // t2[off(i)..] (B_i has exactly level-i length).
        {
            let b1 = &mut ws.t2[..D];
            let zk = &ws.zdiv[(k - 1) * D..k * D];
            for ((bv, &zv), &av) in b1.iter_mut().zip(zk).zip(&a[..D]) {
                *bv = zv + av;
            }
        }
        let mut cur_len = D;
        for i in 2..k {
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (lo, hi) = ws.t2.split_at_mut(oi);
            let src = &lo[spec.off(i - 1)..spec.off(i - 1) + cur_len];
            let dst = &mut hi[..li];
            let zm: &[E; D] = (&ws.zdiv[(m - 1) * D..m * D]).try_into().unwrap();
            let ai = &a[oi..oi + li];
            for (p, &sp) in src.iter().enumerate() {
                let row: &mut [E; D] = (&mut dst[p * D..(p + 1) * D]).try_into().unwrap();
                let arow: &[E; D] = (&ai[p * D..(p + 1) * D]).try_into().unwrap();
                for q in 0..D {
                    row[q] = sp * zm[q] + arow[q];
                }
            }
            cur_len *= D;
        }
        // Unwind. Final step: C_k = B_{k-1} ⊗ z + A_k.
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let gk = &g[ok..ok + lk];
        for (x, &gv) in ga[ok..ok + lk].iter_mut().zip(gk) {
            *x += gv;
        }
        // gB_{k-1}[p] = Σ_q gk[p,q] z[q];  gz[q] += Σ_p B_{k-1}[p] gk[p,q].
        let bk1 = &ws.t2[spec.off(k - 1)..spec.off(k - 1) + cur_len];
        let gb = &mut ws.h0[..cur_len];
        for (p, gbp) in gb.iter_mut().enumerate() {
            let row: &[E; D] = (&gk[p * D..(p + 1) * D]).try_into().unwrap();
            let mut acc = E::ZERO;
            let bp = bk1[p];
            for q in 0..D {
                acc += row[q] * z[q];
                gz[q] += bp * row[q];
            }
            *gbp = acc;
        }
        // Middle steps: B_i = B_{i-1} ⊗ z/m + A_i, i = k-1 .. 2.
        let mut cur_in_h0 = true;
        let mut len_i = cur_len; // length of B_i for current i (= d^i)
        for i in (2..k).rev() {
            let m = k - i + 1;
            let inv_m = E::recip_usize(m);
            let zm: &[E; D] = (&ws.zdiv[(m - 1) * D..m * D]).try_into().unwrap();
            let oi = spec.off(i);
            let prev_len = len_i / D;
            let b_prev = &ws.t2[spec.off(i - 1)..spec.off(i - 1) + prev_len];
            let (gb_i, gb_prev) = if cur_in_h0 {
                let (h0, h1) = (&mut ws.h0, &mut ws.h1);
                (&h0[..len_i], &mut h1[..prev_len])
            } else {
                let (h0, h1) = (&mut ws.h0, &mut ws.h1);
                (&h1[..len_i], &mut h0[..prev_len])
            };
            // gA_i += gB_i.
            for (x, &gv) in ga[oi..oi + len_i].iter_mut().zip(gb_i) {
                *x += gv;
            }
            // gB_{i-1}[p] = Σ_q gB_i[p,q] zm[q];
            // gz[q] += inv_m * Σ_p B_{i-1}[p] gB_i[p,q].
            let mut gz_acc = [E::ZERO; D];
            for (p, gbp) in gb_prev.iter_mut().enumerate() {
                let row: &[E; D] = (&gb_i[p * D..(p + 1) * D]).try_into().unwrap();
                let bp = b_prev[p];
                let mut acc = E::ZERO;
                for q in 0..D {
                    acc += row[q] * zm[q];
                    gz_acc[q] += bp * row[q];
                }
                *gbp = acc;
            }
            for q in 0..D {
                gz[q] += inv_m * gz_acc[q];
            }
            cur_in_h0 = !cur_in_h0;
            len_i = prev_len;
        }
        // Innermost: B_1 = z/k + A_1.
        let gb1 = if cur_in_h0 { &ws.h0[..D] } else { &ws.h1[..D] };
        let inv_k = E::recip_usize(k);
        for q in 0..D {
            ga[q] += gb1[q];
            gz[q] += inv_k * gb1[q];
        }
    }
}

/// Runtime-`d` fast VJP: a line-for-line transcription of the mono body
/// with a runtime channel trip count. The only structural difference is
/// the per-step `gz` accumulator, which lives in `ws.t1[..d]` instead of a
/// `[E; D]` stack array — it is zeroed and drained at exactly the same
/// points, so the floating-point op order (and hence every rounding) is
/// identical to the mono body's. The innermost loops run contiguously over
/// the fastest (`q`) axis and vectorise for any `d`. This is what lets
/// `ExecPlanner` plan `LaneFused` backward at `d > 8`.
pub fn fused_mexp_vjp_dyn<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    g: &[E],
    ga: &mut [E],
    gz: &mut [E],
    ws: &mut Workspace<E>,
) {
    let d = spec.d();
    let n = spec.depth();
    debug_assert_eq!(z.len(), d);
    stage_zdiv(spec, z, ws);
    // Level 1: C_1 = A_1 + z.
    for q in 0..d {
        ga[q] += g[q];
        gz[q] += g[q];
    }
    for k in (2..=n).rev() {
        // Recompute the forward Horner chain for level k, storing B_i at
        // t2[off(i)..] (B_i has exactly level-i length).
        {
            let b1 = &mut ws.t2[..d];
            let zk = &ws.zdiv[(k - 1) * d..k * d];
            for ((bv, &zv), &av) in b1.iter_mut().zip(zk).zip(&a[..d]) {
                *bv = zv + av;
            }
        }
        let mut cur_len = d;
        for i in 2..k {
            let m = k - i + 1;
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (lo, hi) = ws.t2.split_at_mut(oi);
            let src = &lo[spec.off(i - 1)..spec.off(i - 1) + cur_len];
            let dst = &mut hi[..li];
            let zm = &ws.zdiv[(m - 1) * d..m * d];
            let ai = &a[oi..oi + li];
            for (p, &sp) in src.iter().enumerate() {
                let row = &mut dst[p * d..(p + 1) * d];
                let arow = &ai[p * d..(p + 1) * d];
                for q in 0..d {
                    row[q] = sp * zm[q] + arow[q];
                }
            }
            cur_len *= d;
        }
        // Unwind. Final step: C_k = B_{k-1} ⊗ z + A_k.
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let gk = &g[ok..ok + lk];
        for (x, &gv) in ga[ok..ok + lk].iter_mut().zip(gk) {
            *x += gv;
        }
        // gB_{k-1}[p] = Σ_q gk[p,q] z[q];  gz[q] += Σ_p B_{k-1}[p] gk[p,q].
        let bk1 = &ws.t2[spec.off(k - 1)..spec.off(k - 1) + cur_len];
        let gb = &mut ws.h0[..cur_len];
        for (p, gbp) in gb.iter_mut().enumerate() {
            let row = &gk[p * d..(p + 1) * d];
            let mut acc = E::ZERO;
            let bp = bk1[p];
            for q in 0..d {
                acc += row[q] * z[q];
                gz[q] += bp * row[q];
            }
            *gbp = acc;
        }
        // Middle steps: B_i = B_{i-1} ⊗ z/m + A_i, i = k-1 .. 2.
        let mut cur_in_h0 = true;
        let mut len_i = cur_len; // length of B_i for current i (= d^i)
        for i in (2..k).rev() {
            let m = k - i + 1;
            let inv_m = E::recip_usize(m);
            let oi = spec.off(i);
            let prev_len = len_i / d;
            let (gb_i, gb_prev) = if cur_in_h0 {
                let (h0, h1) = (&mut ws.h0, &mut ws.h1);
                (&h0[..len_i], &mut h1[..prev_len])
            } else {
                let (h0, h1) = (&mut ws.h0, &mut ws.h1);
                (&h1[..len_i], &mut h0[..prev_len])
            };
            let zm = &ws.zdiv[(m - 1) * d..m * d];
            let b_prev = &ws.t2[spec.off(i - 1)..spec.off(i - 1) + prev_len];
            // gA_i += gB_i.
            for (x, &gv) in ga[oi..oi + len_i].iter_mut().zip(gb_i) {
                *x += gv;
            }
            // gB_{i-1}[p] = Σ_q gB_i[p,q] zm[q];
            // gz[q] += inv_m * Σ_p B_{i-1}[p] gB_i[p,q].
            let gz_acc = &mut ws.t1[..d];
            gz_acc.fill(E::ZERO);
            for (p, gbp) in gb_prev.iter_mut().enumerate() {
                let row = &gb_i[p * d..(p + 1) * d];
                let bp = b_prev[p];
                let mut acc = E::ZERO;
                for q in 0..d {
                    acc += row[q] * zm[q];
                    gz_acc[q] += bp * row[q];
                }
                *gbp = acc;
            }
            for q in 0..d {
                gz[q] += inv_m * gz_acc[q];
            }
            cur_in_h0 = !cur_in_h0;
            len_i = prev_len;
        }
        // Innermost: B_1 = z/k + A_1.
        let gb1 = if cur_in_h0 { &ws.h0[..d] } else { &ws.h1[..d] };
        let inv_k = E::recip_usize(k);
        for q in 0..d {
            ga[q] += gb1[q];
            gz[q] += inv_k * gb1[q];
        }
    }
}

/// Reference VJP via explicit `exp` + ⊠-VJP composition (used by tests to
/// pin the fast paths; no longer on any dispatch route).
pub fn fused_mexp_vjp_reference<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    g: &[E],
    ga: &mut [E],
    gz: &mut [E],
    ws: &mut Workspace<E>,
) {
    // E = exp(z).
    exp_into(spec, z, &mut ws.t0);
    ws.t1.fill(E::ZERO);
    // Split borrows: mul_vjp(a, E, g) -> ga, gE(ws.t1).
    {
        let (e, ge) = (&ws.t0, &mut ws.t1);
        mul_vjp(spec, a, e, g, ga, ge);
    }
    exp_vjp(spec, z, &ws.t1, gz);
}

/// Convenience: `exp(z) ⊠ a` out of place via [`fused_mexp_left`].
pub fn fused_mexp_left_into<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    out: &mut [E],
    ws: &mut Workspace<E>,
) {
    out.copy_from_slice(a);
    fused_mexp_left(spec, out, z, ws);
}

/// Reference (non-fused) composition used by the baselines and the tests:
/// `out = a ⊠ exp(z)` via an explicit exponential then a full ⊠.
/// This is the "conventional way" of App. A.1.1, costing `C(d, N)`.
pub fn unfused_mexp_into<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    z: &[E],
    out: &mut [E],
    ws: &mut Workspace<E>,
) {
    exp_into(spec, z, &mut ws.t0);
    // out = a ⊠ E, written level-by-level (no fusion).
    let n = spec.depth();
    for k in 1..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let e = &ws.t0;
        let dst = &mut out[ok..ok + lk];
        for ((dv, &av), &ev) in dst.iter_mut().zip(&a[ok..ok + lk]).zip(&e[ok..ok + lk]) {
            *dv = av + ev;
        }
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            outer_add(&a[oi..oi + li], &e[oj..oj + lj], dst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::ta::{exp, mul};

    #[test]
    fn fused_equals_mul_exp() {
        // d ranges over the full monomorphisation window (dispatch
        // monomorphises through d = 8): the d ∈ {6, 7, 8} forward kernels
        // were previously never exercised.
        property("fused == A ⊠ exp(z)", 40, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 5 } else { 6 });
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = Workspace::new(&s);
            let a = g.normal_vec(s.sig_len(), 0.8);
            let z = g.normal_vec(d, 0.8);
            let expect = mul(&s, &a, &exp(&s, &z));
            let mut got = a.clone();
            fused_mexp(&s, &mut got, &z, &mut ws);
            assert_close(&got, &expect, 1e-4, 1e-6);
        });
    }

    #[test]
    fn fused_left_equals_exp_mul() {
        property("fused_left == exp(z) ⊠ A", 40, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 5 } else { 6 });
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = Workspace::new(&s);
            let a = g.normal_vec(s.sig_len(), 0.8);
            let z = g.normal_vec(d, 0.8);
            let expect = mul(&s, &exp(&s, &z), &a);
            let mut got = a.clone();
            fused_mexp_left(&s, &mut got, &z, &mut ws);
            assert_close(&got, &expect, 1e-4, 1e-6);
        });
    }

    #[test]
    fn fused_from_identity_is_exp() {
        let s = SigSpec::new(3, 4).unwrap();
        let mut ws = Workspace::new(&s);
        let z = [0.3f32, -0.2, 0.9];
        let mut a = s.zeros();
        fused_mexp(&s, &mut a, &z, &mut ws);
        assert_close(&a, &exp(&s, &z), 1e-5, 1e-7);
    }

    #[test]
    fn unfused_matches_fused() {
        property("unfused == fused", 20, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = Workspace::new(&s);
            let a = g.normal_vec(s.sig_len(), 0.8);
            let z = g.normal_vec(d, 0.8);
            let mut fused = a.clone();
            fused_mexp(&s, &mut fused, &z, &mut ws);
            let mut unfused = s.zeros();
            unfused_mexp_into(&s, &a, &z, &mut unfused, &mut ws);
            assert_close(&unfused, &fused, 1e-4, 1e-6);
        });
    }

    #[test]
    fn depth1_fused_is_vector_add() {
        let s = SigSpec::new(4, 1).unwrap();
        let mut ws = Workspace::new(&s);
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        fused_mexp(&s, &mut a, &[10.0, 20.0, 30.0, 40.0], &mut ws);
        assert_eq!(a, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn fused_vjp_matches_finite_differences() {
        property("fused vjp fd", 8, |gen| {
            let d = gen.usize_in(1, 3);
            let n = gen.usize_in(1, 4);
            gen.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = Workspace::new(&s);
            let a = gen.normal_vec(s.sig_len(), 0.5);
            let z = gen.normal_vec(d, 0.5);
            let g = gen.normal_vec(s.sig_len(), 1.0);
            let mut ga = s.zeros();
            let mut gz = vec![0.0; d];
            fused_mexp_vjp(&s, &a, &z, &g, &mut ga, &mut gz, &mut ws);

            let f = |av: &[f32], zv: &[f32]| {
                let mut out = av.to_vec();
                let mut w = Workspace::new(&s);
                fused_mexp(&s, &mut out, zv, &mut w);
                out
            };
            let h = 1e-2f32;
            for i in 0..a.len() {
                let mut ap = a.clone();
                ap[i] += h;
                let mut am = a.clone();
                am[i] -= h;
                let fd: f32 = f(&ap, &z)
                    .iter()
                    .zip(f(&am, &z).iter())
                    .zip(&g)
                    .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                    .sum();
                assert!((fd - ga[i]).abs() < 3e-2 * (1.0 + fd.abs()), "ga[{i}]: fd={fd} vjp={}", ga[i]);
            }
            for i in 0..d {
                let mut zp = z.clone();
                zp[i] += h;
                let mut zm = z.clone();
                zm[i] -= h;
                let fd: f32 = f(&a, &zp)
                    .iter()
                    .zip(f(&a, &zm).iter())
                    .zip(&g)
                    .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                    .sum();
                assert!((fd - gz[i]).abs() < 3e-2 * (1.0 + fd.abs()), "gz[{i}]: fd={fd} vjp={}", gz[i]);
            }
        });
    }

    #[test]
    fn fast_vjp_matches_reference_vjp() {
        property("fused vjp fast == reference", 30, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, 5);
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = Workspace::new(&s);
            let a = g.normal_vec(s.sig_len(), 0.6);
            let z = g.normal_vec(d, 0.6);
            let gv = g.normal_vec(s.sig_len(), 1.0);
            let mut ga_fast = s.zeros();
            let mut gz_fast = vec![0.0; d];
            fused_mexp_vjp(&s, &a, &z, &gv, &mut ga_fast, &mut gz_fast, &mut ws);
            let mut ga_ref = s.zeros();
            let mut gz_ref = vec![0.0; d];
            fused_mexp_vjp_reference(&s, &a, &z, &gv, &mut ga_ref, &mut gz_ref, &mut ws);
            assert_close(&ga_fast, &ga_ref, 1e-4, 1e-5);
            assert_close(&gz_fast, &gz_ref, 1e-3, 1e-4);
        });
    }

    #[test]
    fn dyn_vjp_is_bitwise_identical_to_mono_in_both_precisions() {
        // The dyn body is a transcription of the mono body: same op order,
        // same roundings, so inside the mono window (d ≤ 8) the two must
        // agree to the last bit — in f32 and in f64.
        property("dyn vjp ≡ mono vjp (bitwise)", 24, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 5 });
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let a = g.normal_vec(s.sig_len(), 0.6);
            let z = g.normal_vec(d, 0.6);
            let gv = g.normal_vec(s.sig_len(), 1.0);

            let mut ws = Workspace::new(&s);
            let mut ga_mono = s.zeros();
            let mut gz_mono = vec![0.0f32; d];
            fused_mexp_vjp(&s, &a, &z, &gv, &mut ga_mono, &mut gz_mono, &mut ws);
            let mut ga_dyn = s.zeros();
            let mut gz_dyn = vec![0.0f32; d];
            fused_mexp_vjp_dyn(&s, &a, &z, &gv, &mut ga_dyn, &mut gz_dyn, &mut ws);
            assert_eq!(ga_dyn, ga_mono, "f32 ga diverges at d={d} n={n}");
            assert_eq!(gz_dyn, gz_mono, "f32 gz diverges at d={d} n={n}");

            let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
            let z64: Vec<f64> = z.iter().map(|&v| v as f64).collect();
            let g64: Vec<f64> = gv.iter().map(|&v| v as f64).collect();
            let mut ws64 = Workspace::<f64>::new(&s);
            let mut ga_mono64 = s.zeros_elem::<f64>();
            let mut gz_mono64 = vec![0.0f64; d];
            fused_mexp_vjp(&s, &a64, &z64, &g64, &mut ga_mono64, &mut gz_mono64, &mut ws64);
            let mut ga_dyn64 = s.zeros_elem::<f64>();
            let mut gz_dyn64 = vec![0.0f64; d];
            fused_mexp_vjp_dyn(&s, &a64, &z64, &g64, &mut ga_dyn64, &mut gz_dyn64, &mut ws64);
            assert_eq!(ga_dyn64, ga_mono64, "f64 ga diverges at d={d} n={n}");
            assert_eq!(gz_dyn64, gz_mono64, "f64 gz diverges at d={d} n={n}");
        });
    }

    #[test]
    fn dyn_vjp_pins_the_mono_boundary_at_d8() {
        // d = 8 is the last monomorphised dimension — the exact boundary
        // the retirement decision (`bench::mono_dyn_crossover` over
        // `BENCH_batch.json`'s vjp_step records) hinges on. The generic
        // property above samples it; this pins it: at the boundary the
        // two bodies agree to the last bit across depths and precisions,
        // so retiring the mono bodies is purely a benchmark call, never
        // a numerics question.
        property("dyn vjp ≡ mono vjp at the d = 8 boundary", 16, |g| {
            let d = 8usize;
            let n = g.usize_in(1, 4);
            g.label(format!("n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let a = g.normal_vec(s.sig_len(), 0.6);
            let z = g.normal_vec(d, 0.6);
            let gv = g.normal_vec(s.sig_len(), 1.0);

            let mut ws = Workspace::new(&s);
            let mut ga_mono = s.zeros();
            let mut gz_mono = vec![0.0f32; d];
            fused_mexp_vjp(&s, &a, &z, &gv, &mut ga_mono, &mut gz_mono, &mut ws);
            let mut ga_dyn = s.zeros();
            let mut gz_dyn = vec![0.0f32; d];
            fused_mexp_vjp_dyn(&s, &a, &z, &gv, &mut ga_dyn, &mut gz_dyn, &mut ws);
            assert_eq!(ga_dyn, ga_mono, "f32 ga diverges at the boundary, n={n}");
            assert_eq!(gz_dyn, gz_mono, "f32 gz diverges at the boundary, n={n}");

            let a64: Vec<f64> = a.iter().map(|&v| f64::from(v)).collect();
            let z64: Vec<f64> = z.iter().map(|&v| f64::from(v)).collect();
            let g64: Vec<f64> = gv.iter().map(|&v| f64::from(v)).collect();
            let mut ws64 = Workspace::<f64>::new(&s);
            let mut ga_mono64 = s.zeros_elem::<f64>();
            let mut gz_mono64 = vec![0.0f64; d];
            fused_mexp_vjp(&s, &a64, &z64, &g64, &mut ga_mono64, &mut gz_mono64, &mut ws64);
            let mut ga_dyn64 = s.zeros_elem::<f64>();
            let mut gz_dyn64 = vec![0.0f64; d];
            fused_mexp_vjp_dyn(&s, &a64, &z64, &g64, &mut ga_dyn64, &mut gz_dyn64, &mut ws64);
            assert_eq!(ga_dyn64, ga_mono64, "f64 ga diverges at the boundary, n={n}");
            assert_eq!(gz_dyn64, gz_mono64, "f64 gz diverges at the boundary, n={n}");
        });
    }

    #[test]
    fn dyn_vjp_matches_reference_beyond_the_mono_window() {
        // d > 8 is dyn's home turf: check against the exp + ⊠ composition,
        // which takes a completely different computational route.
        for &(d, n) in &[(9usize, 3usize), (12, 3), (20, 2)] {
            let s = SigSpec::new(d, n).unwrap();
            let mut rng = crate::substrate::rng::Rng::new(17 + d as u64);
            let a = rng.normal_vec(s.sig_len(), 0.5);
            let z = rng.normal_vec(d, 0.5);
            let gv = rng.normal_vec(s.sig_len(), 1.0);
            let mut ws = Workspace::new(&s);
            let mut ga_dyn = s.zeros();
            let mut gz_dyn = vec![0.0f32; d];
            fused_mexp_vjp(&s, &a, &z, &gv, &mut ga_dyn, &mut gz_dyn, &mut ws);
            let mut ga_ref = s.zeros();
            let mut gz_ref = vec![0.0f32; d];
            fused_mexp_vjp_reference(&s, &a, &z, &gv, &mut ga_ref, &mut gz_ref, &mut ws);
            assert_close(&ga_dyn, &ga_ref, 1e-4, 1e-5);
            assert_close(&gz_dyn, &gz_ref, 1e-3, 1e-4);

            // And the f64 instantiation agrees with its own reference far
            // more tightly (double-precision accumulation).
            let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
            let z64: Vec<f64> = z.iter().map(|&v| v as f64).collect();
            let g64: Vec<f64> = gv.iter().map(|&v| v as f64).collect();
            let mut ws64 = Workspace::<f64>::new(&s);
            let mut ga_dyn64 = s.zeros_elem::<f64>();
            let mut gz_dyn64 = vec![0.0f64; d];
            fused_mexp_vjp(&s, &a64, &z64, &g64, &mut ga_dyn64, &mut gz_dyn64, &mut ws64);
            let mut ga_ref64 = s.zeros_elem::<f64>();
            let mut gz_ref64 = vec![0.0f64; d];
            fused_mexp_vjp_reference(&s, &a64, &z64, &g64, &mut ga_ref64, &mut gz_ref64, &mut ws64);
            for (x, y) in ga_dyn64.iter().zip(&ga_ref64) {
                assert!((x - y).abs() < 1e-10 * (1.0 + y.abs()), "d={d}: {x} vs {y}");
            }
            for (x, y) in gz_dyn64.iter().zip(&gz_ref64) {
                assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "d={d}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn generic_forward_is_bitwise_identical_to_mono() {
        property("generic fwd ≡ mono fwd (bitwise)", 20, |g| {
            let d = g.usize_in(1, 8);
            let n = g.usize_in(1, if d > 4 { 4 } else { 5 });
            g.label(format!("d={d} n={n}"));
            let s = SigSpec::new(d, n).unwrap();
            let mut ws = Workspace::new(&s);
            let a = g.normal_vec(s.sig_len(), 0.8);
            let z = g.normal_vec(d, 0.8);
            let mut mono = a.clone();
            fused_mexp(&s, &mut mono, &z, &mut ws);
            let mut gen_out = a.clone();
            fused_mexp_generic(&s, &mut gen_out, &z, &mut ws);
            assert_eq!(gen_out, mono);
        });
    }

    #[test]
    fn chen_via_fused_matches_two_segment_signature() {
        // exp(z1) ⊠ exp(z2) computed via fused on exp(z1).
        let s = SigSpec::new(2, 5).unwrap();
        let mut ws = Workspace::new(&s);
        let z1 = [0.5f32, -0.25];
        let z2 = [-0.1f32, 0.7];
        let mut sig = exp(&s, &z1);
        fused_mexp(&s, &mut sig, &z2, &mut ws);
        let expect = mul(&s, &exp(&s, &z1), &exp(&s, &z2));
        assert_close(&sig, &expect, 1e-5, 1e-7);
    }
}
