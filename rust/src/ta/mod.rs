//! The truncated tensor algebra `T^N(R^d) = prod_{k=1..N} (R^d)^{⊗k}`.
//!
//! Elements are stored as flat `[f32]` vectors: the depth-k level occupies
//! `d^k` contiguous entries, levels concatenated in increasing k. The
//! scalar (k = 0) term is *implicit* and equals 1 for group-like elements
//! (matching the paper's convention of omitting it, §2.1 fn. 2); operations
//! that need it handle it explicitly.
//!
//! Submodules implement the paper's operations:
//! - [`mul`] — the truncated tensor product ⊠ (Chen product, §2.2) and its
//!   handwritten VJP.
//! - [`exp`] — the tensor exponential and its VJP.
//! - [`fused`] — the **fused multiply-exponentiate** `A ⊠ exp(z)` via the
//!   Horner scheme of §4.1 / App. A.1 — the paper's key algorithmic
//!   improvement and this library's hot path — plus the mirrored
//!   `exp(z) ⊠ A` used for incremental inverted signatures.
//! - [`batch`] — the **batch-lane execution engine**: the fused kernels
//!   vectorised *across* `L` same-spec signatures in a lane-interleaved
//!   layout, so the innermost Horner loops run contiguously over the lanes
//!   and auto-vectorise regardless of `d` — the serving hot path (many
//!   short streams at small `d`), bitwise identical per lane to the scalar
//!   kernels.
//! - [`log`] — the tensor logarithm (Horner series) and its VJP.
//! - [`inverse`] — the group inverse (truncated Neumann series) and VJP.
//! - [`opcount`] — the closed-form multiplication counts `F(d,N)`, `C(d,N)`
//!   of App. A.1 plus instrumented counters validating them.

pub mod batch;
pub mod exp;
pub mod fused;
pub mod inverse;
pub mod log;
pub mod mul;
pub mod opcount;

pub use batch::{fused_mexp_batch, fused_mexp_left_batch, fused_mexp_vjp_batch, BatchWorkspace};
pub use exp::{exp, exp_vjp};
pub use fused::{fused_mexp, fused_mexp_left, fused_mexp_vjp};
pub use inverse::{inverse, inverse_vjp};
pub use log::{log, log_vjp};
pub use mul::{mul, mul_into, mul_vjp};

/// Shape metadata for signatures over `d` channels truncated at `depth`.
///
/// Precomputes level offsets/lengths so hot loops never recompute powers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SigSpec {
    d: usize,
    depth: usize,
    /// `level_off[k-1]` = flat offset of level k (k = 1..=depth), plus a
    /// trailing sentinel equal to `len`.
    level_off: Vec<usize>,
    len: usize,
}

impl SigSpec {
    /// `d >= 1` channels, `depth >= 1`. Errors if the flattened signature
    /// would overflow a reasonable memory bound (guards `d^depth`).
    pub fn new(d: usize, depth: usize) -> anyhow::Result<SigSpec> {
        anyhow::ensure!(d >= 1, "channels must be >= 1");
        anyhow::ensure!(depth >= 1, "depth must be >= 1");
        let mut level_off = Vec::with_capacity(depth + 1);
        let mut off = 0usize;
        let mut pw = 1usize;
        for _ in 0..depth {
            level_off.push(off);
            pw = pw
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("d^depth overflows"))?;
            off = off
                .checked_add(pw)
                .ok_or_else(|| anyhow::anyhow!("signature length overflows"))?;
            anyhow::ensure!(off <= 1 << 31, "signature of {} elements is too large", off);
        }
        level_off.push(off);
        Ok(SigSpec { d, depth, level_off, len: off })
    }

    /// Number of channels d.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Truncation depth N.
    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total flattened length `d + d^2 + ... + d^depth`
    /// (the paper's "signature channels").
    #[inline]
    pub fn sig_len(&self) -> usize {
        self.len
    }

    /// Flat offset of level `k` (1-based).
    #[inline]
    pub fn off(&self, k: usize) -> usize {
        debug_assert!((1..=self.depth).contains(&k));
        self.level_off[k - 1]
    }

    /// Length of level `k`, i.e. `d^k`.
    #[inline]
    pub fn level_len(&self, k: usize) -> usize {
        debug_assert!((1..=self.depth).contains(&k));
        self.level_off[k] - self.level_off[k - 1]
    }

    /// Borrow level `k` of a signature slice.
    #[inline]
    pub fn level<'a>(&self, sig: &'a [f32], k: usize) -> &'a [f32] {
        &sig[self.level_off[k - 1]..self.level_off[k]]
    }

    /// Mutably borrow level `k` of a signature slice.
    #[inline]
    pub fn level_mut<'a>(&self, sig: &'a mut [f32], k: usize) -> &'a mut [f32] {
        &mut sig[self.level_off[k - 1]..self.level_off[k]]
    }

    /// A zeroed signature buffer.
    pub fn zeros(&self) -> Vec<f32> {
        vec![0.0; self.len]
    }

    /// A spec for the same `d` at a shallower depth (used by log/inverse
    /// internals and tests).
    pub fn truncate(&self, depth: usize) -> SigSpec {
        assert!(depth >= 1 && depth <= self.depth);
        SigSpec {
            d: self.d,
            depth,
            level_off: self.level_off[..=depth].to_vec(),
            len: self.level_off[depth],
        }
    }
}

/// Reusable scratch space for the algebra kernels, sized for one `SigSpec`.
/// Hot loops (signature over a long stream) allocate one of these once.
pub struct Workspace {
    /// Ping/pong Horner buffers, each `d^(depth-1)` long.
    pub h0: Vec<f32>,
    pub h1: Vec<f32>,
    /// `z/m` staging, `d * depth` long (divided increments).
    pub zdiv: Vec<f32>,
    /// Signature-sized scratch buffers.
    pub t0: Vec<f32>,
    pub t1: Vec<f32>,
    pub t2: Vec<f32>,
}

impl Workspace {
    pub fn new(spec: &SigSpec) -> Workspace {
        let horner = if spec.depth >= 2 {
            spec.level_len(spec.depth) / spec.d
        } else {
            spec.d
        };
        Workspace {
            h0: vec![0.0; horner],
            h1: vec![0.0; horner],
            zdiv: vec![0.0; spec.d * spec.depth],
            t0: vec![0.0; spec.len],
            t1: vec![0.0; spec.len],
            t2: vec![0.0; spec.len],
        }
    }
}

/// Reciprocals 1/1, 1/2, ..., 1/N precomputed once (the paper's "divisions
/// cost one multiplication" assumption, App. A.1.1).
pub fn reciprocals(depth: usize) -> Vec<f32> {
    (1..=depth).map(|k| 1.0 / k as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_offsets_and_lengths() {
        let s = SigSpec::new(3, 4).unwrap();
        assert_eq!(s.sig_len(), 3 + 9 + 27 + 81);
        assert_eq!(s.off(1), 0);
        assert_eq!(s.off(2), 3);
        assert_eq!(s.off(3), 12);
        assert_eq!(s.off(4), 39);
        assert_eq!(s.level_len(1), 3);
        assert_eq!(s.level_len(4), 81);
    }

    #[test]
    fn spec_d1() {
        let s = SigSpec::new(1, 5).unwrap();
        assert_eq!(s.sig_len(), 5);
        for k in 1..=5 {
            assert_eq!(s.level_len(k), 1);
            assert_eq!(s.off(k), k - 1);
        }
    }

    #[test]
    fn spec_rejects_bad_and_huge() {
        assert!(SigSpec::new(0, 3).is_err());
        assert!(SigSpec::new(3, 0).is_err());
        assert!(SigSpec::new(10, 12).is_err()); // 10^12 elements
    }

    #[test]
    fn level_views() {
        let s = SigSpec::new(2, 3).unwrap();
        let mut sig: Vec<f32> = (0..s.sig_len()).map(|i| i as f32).collect();
        assert_eq!(s.level(&sig, 1), &[0.0, 1.0]);
        assert_eq!(s.level(&sig, 2), &[2.0, 3.0, 4.0, 5.0]);
        s.level_mut(&mut sig, 3)[0] = 99.0;
        assert_eq!(sig[6], 99.0);
    }

    #[test]
    fn truncate_spec() {
        let s = SigSpec::new(3, 5).unwrap();
        let t = s.truncate(2);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.sig_len(), 12);
        assert_eq!(t.off(2), 3);
    }

    #[test]
    fn reciprocals_values() {
        let r = reciprocals(4);
        assert_eq!(r, vec![1.0, 0.5, 1.0 / 3.0, 0.25]);
    }

    #[test]
    fn workspace_sizes() {
        let s = SigSpec::new(3, 4).unwrap();
        let w = Workspace::new(&s);
        assert_eq!(w.h0.len(), 27); // d^(N-1)
        assert_eq!(w.zdiv.len(), 12);
        assert_eq!(w.t0.len(), s.sig_len());
        let s1 = SigSpec::new(3, 1).unwrap();
        let w1 = Workspace::new(&s1);
        assert_eq!(w1.h0.len(), 3);
    }
}
