//! The truncated tensor algebra `T^N(R^d) = prod_{k=1..N} (R^d)^{⊗k}`.
//!
//! Elements are stored as flat scalar vectors: the depth-k level occupies
//! `d^k` contiguous entries, levels concatenated in increasing k. The
//! scalar (k = 0) term is *implicit* and equals 1 for group-like elements
//! (matching the paper's convention of omitting it, §2.1 fn. 2); operations
//! that need it handle it explicitly.
//!
//! The element type is a first-class axis: every kernel is generic over the
//! sealed [`Elem`] trait (`f32` or `f64`), with `f32` remaining the default
//! (all pre-existing `&[f32]` call sites infer it unchanged). The kernels
//! are also dimension-generic — the fused VJP has both `const D`
//! monomorphised bodies (`d ≤ 8`) and a runtime-`d` body
//! ([`fused::fused_mexp_vjp_dyn`]) replaying the identical op order, so the
//! lane-fused backward engages at any `d`.
//!
//! Submodules implement the paper's operations:
//! - [`mul`] — the truncated tensor product ⊠ (Chen product, §2.2) and its
//!   handwritten VJP.
//! - [`exp`] — the tensor exponential and its VJP.
//! - [`fused`] — the **fused multiply-exponentiate** `A ⊠ exp(z)` via the
//!   Horner scheme of §4.1 / App. A.1 — the paper's key algorithmic
//!   improvement and this library's hot path — plus the mirrored
//!   `exp(z) ⊠ A` used for incremental inverted signatures.
//! - [`batch`] — the **batch-lane execution engine**: the fused kernels
//!   *and* the Chen-combination family (⊠, no-unit ⊠, group inverse,
//!   tensor exp) vectorised *across* `L` same-spec signatures in a
//!   lane-interleaved layout, so the innermost loops run contiguously over
//!   the lanes and auto-vectorise regardless of `d` — the serving hot path
//!   (many short streams at small `d`, and batched window-slide
//!   advancement), bitwise identical per lane to the scalar kernels.
//! - [`log`] — the tensor logarithm (Horner series) and its VJP.
//! - [`inverse`] — the group inverse (truncated Neumann series) and VJP.
//! - [`opcount`] — the closed-form multiplication counts `F(d,N)`, `C(d,N)`
//!   of App. A.1 plus instrumented counters validating them (forward *and*
//!   fused-VJP, mono and runtime-`d` iteration spaces).

pub mod batch;
pub mod exp;
pub mod fused;
pub mod inverse;
pub mod log;
pub mod mul;
pub mod opcount;

pub use batch::{
    exp_batch_in_place, fused_mexp_batch, fused_mexp_left_batch, fused_mexp_vjp_batch,
    inverse_batch_into, mul_batch_into, mul_nounit_batch_into, BatchWorkspace,
};
pub use exp::{exp, exp_vjp};
pub use fused::{fused_mexp, fused_mexp_left, fused_mexp_vjp};
pub use inverse::{inverse, inverse_vjp};
pub use log::{log, log_vjp};
pub use mul::{mul, mul_into, mul_vjp};

/// Element precision of a signature computation — the dtype axis threaded
/// from the serving surface ([`crate::coordinator::Request`]) through the
/// planner ([`crate::exec::WorkShape`]) down to the kernels. `F32` is the
/// default everywhere and preserves the pre-dtype behavior bit-for-bit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    #[default]
    F32,
    F64,
}

impl Precision {
    /// Stable small integer tag (used in shape keys / batch-queue keys so
    /// f32 and f64 work never coalesces).
    #[inline]
    pub fn tag(self) -> u8 {
        match self {
            Precision::F32 => 0,
            Precision::F64 => 1,
        }
    }

    /// Bytes per element.
    #[inline]
    pub fn size_of(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }
}

/// A row buffer typed at its native element precision — the wire format of
/// the serving data plane. Where the coordinator used to carry
/// `Vec<f32>`-plus-a-`Precision`-tag (upcasting f64 work at the kernel
/// boundary, which capped end-to-end precision at the transport), it now
/// carries `Rows`: an f64 request's payload is `Vec<f64>` from request to
/// response, and the precision tag *is* the variant.
///
/// `Rows` is deliberately minimal — a tagged buffer with shape/precision
/// accessors and the padding/slicing operations the microbatcher needs.
/// Generic code crosses between `Rows` and `Vec<E>`/`&[E]` through the
/// [`Elem`] row hooks ([`Elem::rows_from`], [`Elem::rows_into`],
/// [`Elem::rows_as_slice`]), so precision is matched exactly once at the
/// dispatch boundary and never via element casts.
#[derive(Clone, Debug, PartialEq)]
pub enum Rows {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl Rows {
    /// The element precision of this buffer.
    #[inline]
    pub fn precision(&self) -> Precision {
        match self {
            Rows::F32(_) => Precision::F32,
            Rows::F64(_) => Precision::F64,
        }
    }

    /// Element count (not bytes).
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Rows::F32(v) => v.len(),
            Rows::F64(v) => v.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A zeroed buffer of `n` elements at the given precision.
    pub fn zeros(prec: Precision, n: usize) -> Rows {
        match prec {
            Precision::F32 => Rows::F32(vec![0.0; n]),
            Precision::F64 => Rows::F64(vec![0.0; n]),
        }
    }

    /// Borrow as `&[f32]`; errors on an f64 buffer (no silent downcast).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Rows::F32(v) => Ok(v),
            Rows::F64(_) => anyhow::bail!("expected f32 rows, got f64"),
        }
    }

    /// Borrow as `&[f64]`; errors on an f32 buffer (no silent upcast).
    pub fn as_f64(&self) -> anyhow::Result<&[f64]> {
        match self {
            Rows::F32(_) => anyhow::bail!("expected f64 rows, got f32"),
            Rows::F64(v) => Ok(v),
        }
    }

    /// Resize to `n` elements, zero-filling growth (microbatch padding).
    pub fn resize(&mut self, n: usize) {
        match self {
            Rows::F32(v) => v.resize(n, 0.0),
            Rows::F64(v) => v.resize(n, 0.0),
        }
    }

    /// Append another buffer of the *same* precision; errors on a dtype
    /// mismatch rather than converting (the never-coalesce-across-dtype
    /// invariant, enforced at the buffer level).
    pub fn extend_from(&mut self, other: &Rows) -> anyhow::Result<()> {
        match (self, other) {
            (Rows::F32(a), Rows::F32(b)) => a.extend_from_slice(b),
            (Rows::F64(a), Rows::F64(b)) => a.extend_from_slice(b),
            (a, b) => anyhow::bail!(
                "precision mismatch: cannot extend {} rows with {} rows",
                a.precision().label(),
                b.precision().label()
            ),
        }
        Ok(())
    }

    /// Copy out the element range `r` as a new buffer (microbatch scatter).
    pub fn slice(&self, r: std::ops::Range<usize>) -> Rows {
        match self {
            Rows::F32(v) => Rows::F32(v[r].to_vec()),
            Rows::F64(v) => Rows::F64(v[r].to_vec()),
        }
    }
}

impl Default for Rows {
    fn default() -> Rows {
        Rows::F32(Vec::new())
    }
}

impl From<Vec<f32>> for Rows {
    fn from(v: Vec<f32>) -> Rows {
        Rows::F32(v)
    }
}

impl From<Vec<f64>> for Rows {
    fn from(v: Vec<f64>) -> Rows {
        Rows::F64(v)
    }
}

impl PartialEq<Vec<f32>> for Rows {
    fn eq(&self, other: &Vec<f32>) -> bool {
        matches!(self, Rows::F32(v) if v == other)
    }
}

impl PartialEq<Vec<f64>> for Rows {
    fn eq(&self, other: &Vec<f64>) -> bool {
        matches!(self, Rows::F64(v) if v == other)
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// The scalar element type of the tensor algebra: `f32` or `f64`, sealed.
///
/// Generic kernel code uses only these operations (plus the arithmetic-op
/// bounds), never `as` casts, so an `f32` instantiation performs exactly
/// the operations the pre-generic `f32`-only code performed — the bitwise
/// per-lane identity between scalar and batched kernels survives the
/// genericisation, in both precisions.
pub trait Elem:
    sealed::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + std::iter::Sum<Self>
{
    const ZERO: Self;
    const ONE: Self;
    /// The dtype tag of this element type.
    const PRECISION: Precision;

    fn from_usize(v: usize) -> Self;
    fn from_f32(v: f32) -> Self;
    fn from_f64(v: f64) -> Self;
    fn to_f32(self) -> f32;
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;

    /// Wrap a native buffer as typed [`Rows`] (the variant is `Self`'s).
    fn rows_from(v: Vec<Self>) -> Rows;

    /// Unwrap typed [`Rows`] into a native buffer; errors on a precision
    /// mismatch rather than converting.
    fn rows_into(rows: Rows) -> anyhow::Result<Vec<Self>>;

    /// Borrow typed [`Rows`] as a native slice; errors on a precision
    /// mismatch rather than converting.
    fn rows_as_slice(rows: &Rows) -> anyhow::Result<&[Self]>;

    /// `1/k` computed *in this precision* (so the f32 instantiation keeps
    /// the exact `1.0f32 / k as f32` rounding the scalar kernels always
    /// used — load-bearing for the bitwise-parity invariant).
    #[inline]
    fn recip_usize(k: usize) -> Self {
        Self::ONE / Self::from_usize(k)
    }
}

impl Elem for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const PRECISION: Precision = Precision::F32;

    #[inline]
    fn from_usize(v: usize) -> f32 {
        v as f32
    }
    #[inline]
    fn from_f32(v: f32) -> f32 {
        v
    }
    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> f32 {
        f32::abs(self)
    }
    #[inline]
    fn rows_from(v: Vec<f32>) -> Rows {
        Rows::F32(v)
    }
    fn rows_into(rows: Rows) -> anyhow::Result<Vec<f32>> {
        match rows {
            Rows::F32(v) => Ok(v),
            Rows::F64(_) => anyhow::bail!("expected f32 rows, got f64"),
        }
    }
    fn rows_as_slice(rows: &Rows) -> anyhow::Result<&[f32]> {
        rows.as_f32()
    }
}

impl Elem for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const PRECISION: Precision = Precision::F64;

    #[inline]
    fn from_usize(v: usize) -> f64 {
        v as f64
    }
    #[inline]
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> f64 {
        f64::abs(self)
    }
    #[inline]
    fn rows_from(v: Vec<f64>) -> Rows {
        Rows::F64(v)
    }
    fn rows_into(rows: Rows) -> anyhow::Result<Vec<f64>> {
        match rows {
            Rows::F32(_) => anyhow::bail!("expected f64 rows, got f32"),
            Rows::F64(v) => Ok(v),
        }
    }
    fn rows_as_slice(rows: &Rows) -> anyhow::Result<&[f64]> {
        rows.as_f64()
    }
}

/// Shape metadata for signatures over `d` channels truncated at `depth`.
///
/// Precomputes level offsets/lengths so hot loops never recompute powers.
/// Carries the element [`Precision`] as metadata (defaulting to `F32`):
/// the kernels take whatever slice type they are instantiated at, but the
/// planning and serving layers key on `spec.dtype()` so mixed-precision
/// work never shares a plan or a microbatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SigSpec {
    d: usize,
    depth: usize,
    /// `level_off[k-1]` = flat offset of level k (k = 1..=depth), plus a
    /// trailing sentinel equal to `len`.
    level_off: Vec<usize>,
    len: usize,
    dtype: Precision,
}

impl SigSpec {
    /// `d >= 1` channels, `depth >= 1`, `f32` elements. Errors if the
    /// flattened signature would overflow a reasonable memory bound
    /// (guards `d^depth`).
    pub fn new(d: usize, depth: usize) -> anyhow::Result<SigSpec> {
        Self::with_dtype(d, depth, Precision::F32)
    }

    /// [`SigSpec::new`] with an explicit element precision.
    pub fn with_dtype(d: usize, depth: usize, dtype: Precision) -> anyhow::Result<SigSpec> {
        anyhow::ensure!(d >= 1, "channels must be >= 1");
        anyhow::ensure!(depth >= 1, "depth must be >= 1");
        let mut level_off = Vec::with_capacity(depth + 1);
        let mut off = 0usize;
        let mut pw = 1usize;
        for _ in 0..depth {
            level_off.push(off);
            pw = pw
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("d^depth overflows"))?;
            off = off
                .checked_add(pw)
                .ok_or_else(|| anyhow::anyhow!("signature length overflows"))?;
            anyhow::ensure!(off <= 1 << 31, "signature of {} elements is too large", off);
        }
        level_off.push(off);
        Ok(SigSpec { d, depth, level_off, len: off, dtype })
    }

    /// Number of channels d.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Truncation depth N.
    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Element precision (metadata; defaults to `F32`).
    #[inline]
    pub fn dtype(&self) -> Precision {
        self.dtype
    }

    /// Total flattened length `d + d^2 + ... + d^depth`
    /// (the paper's "signature channels").
    #[inline]
    pub fn sig_len(&self) -> usize {
        self.len
    }

    /// Flat offset of level `k` (1-based).
    #[inline]
    pub fn off(&self, k: usize) -> usize {
        debug_assert!((1..=self.depth).contains(&k));
        self.level_off[k - 1]
    }

    /// Length of level `k`, i.e. `d^k`.
    #[inline]
    pub fn level_len(&self, k: usize) -> usize {
        debug_assert!((1..=self.depth).contains(&k));
        self.level_off[k] - self.level_off[k - 1]
    }

    /// Borrow level `k` of a signature slice.
    #[inline]
    pub fn level<'a, E: Elem>(&self, sig: &'a [E], k: usize) -> &'a [E] {
        &sig[self.level_off[k - 1]..self.level_off[k]]
    }

    /// Mutably borrow level `k` of a signature slice.
    #[inline]
    pub fn level_mut<'a, E: Elem>(&self, sig: &'a mut [E], k: usize) -> &'a mut [E] {
        &mut sig[self.level_off[k - 1]..self.level_off[k]]
    }

    /// A zeroed `f32` signature buffer (the historical default; generic
    /// code uses [`SigSpec::zeros_elem`]).
    pub fn zeros(&self) -> Vec<f32> {
        vec![0.0; self.len]
    }

    /// A zeroed signature buffer of any element type.
    pub fn zeros_elem<E: Elem>(&self) -> Vec<E> {
        vec![E::ZERO; self.len]
    }

    /// A spec for the same `d` at a shallower depth (used by log/inverse
    /// internals and tests). Preserves the dtype.
    pub fn truncate(&self, depth: usize) -> SigSpec {
        assert!(depth >= 1 && depth <= self.depth);
        SigSpec {
            d: self.d,
            depth,
            level_off: self.level_off[..=depth].to_vec(),
            len: self.level_off[depth],
            dtype: self.dtype,
        }
    }
}

/// Reusable scratch space for the algebra kernels, sized for one `SigSpec`.
/// Hot loops (signature over a long stream) allocate one of these once.
/// Generic over the element type, defaulting to `f32`.
pub struct Workspace<E: Elem = f32> {
    /// Ping/pong Horner buffers, each `d^(depth-1)` long.
    pub h0: Vec<E>,
    pub h1: Vec<E>,
    /// `z/m` staging, `d * depth` long (divided increments).
    pub zdiv: Vec<E>,
    /// Signature-sized scratch buffers.
    pub t0: Vec<E>,
    pub t1: Vec<E>,
    pub t2: Vec<E>,
}

impl<E: Elem> Workspace<E> {
    pub fn new(spec: &SigSpec) -> Workspace<E> {
        let horner = if spec.depth >= 2 {
            spec.level_len(spec.depth) / spec.d
        } else {
            spec.d
        };
        Workspace {
            h0: vec![E::ZERO; horner],
            h1: vec![E::ZERO; horner],
            zdiv: vec![E::ZERO; spec.d * spec.depth],
            t0: vec![E::ZERO; spec.len],
            t1: vec![E::ZERO; spec.len],
            t2: vec![E::ZERO; spec.len],
        }
    }
}

/// Reciprocals 1/1, 1/2, ..., 1/N precomputed once (the paper's "divisions
/// cost one multiplication" assumption, App. A.1.1).
pub fn reciprocals(depth: usize) -> Vec<f32> {
    (1..=depth).map(|k| 1.0 / k as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_offsets_and_lengths() {
        let s = SigSpec::new(3, 4).unwrap();
        assert_eq!(s.sig_len(), 3 + 9 + 27 + 81);
        assert_eq!(s.off(1), 0);
        assert_eq!(s.off(2), 3);
        assert_eq!(s.off(3), 12);
        assert_eq!(s.off(4), 39);
        assert_eq!(s.level_len(1), 3);
        assert_eq!(s.level_len(4), 81);
    }

    #[test]
    fn spec_d1() {
        let s = SigSpec::new(1, 5).unwrap();
        assert_eq!(s.sig_len(), 5);
        for k in 1..=5 {
            assert_eq!(s.level_len(k), 1);
            assert_eq!(s.off(k), k - 1);
        }
    }

    #[test]
    fn spec_rejects_bad_and_huge() {
        assert!(SigSpec::new(0, 3).is_err());
        assert!(SigSpec::new(3, 0).is_err());
        assert!(SigSpec::new(10, 12).is_err()); // 10^12 elements
    }

    #[test]
    fn spec_dtype_metadata() {
        let a = SigSpec::new(3, 4).unwrap();
        assert_eq!(a.dtype(), Precision::F32);
        let b = SigSpec::with_dtype(3, 4, Precision::F64).unwrap();
        assert_eq!(b.dtype(), Precision::F64);
        // Same shape, different dtype: distinct specs (never share a plan).
        assert_ne!(a, b);
        // Geometry is dtype-independent.
        assert_eq!(a.sig_len(), b.sig_len());
        assert_eq!(b.truncate(2).dtype(), Precision::F64);
    }

    #[test]
    fn precision_tags_and_sizes() {
        assert_eq!(Precision::default(), Precision::F32);
        assert_ne!(Precision::F32.tag(), Precision::F64.tag());
        assert_eq!(Precision::F32.size_of(), 4);
        assert_eq!(Precision::F64.size_of(), 8);
        assert_eq!(<f32 as Elem>::PRECISION, Precision::F32);
        assert_eq!(<f64 as Elem>::PRECISION, Precision::F64);
    }

    #[test]
    fn rows_precision_and_shape() {
        let a = Rows::from(vec![1.0f32, 2.0]);
        let b = Rows::from(vec![1.0f64, 2.0, 3.0]);
        assert_eq!(a.precision(), Precision::F32);
        assert_eq!(b.precision(), Precision::F64);
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 3);
        assert!(!a.is_empty());
        assert!(Rows::default().is_empty());
        assert_eq!(Rows::default().precision(), Precision::F32);
        assert_eq!(Rows::zeros(Precision::F64, 4), vec![0.0f64; 4]);
    }

    #[test]
    fn rows_borrows_refuse_cross_precision() {
        let a = Rows::from(vec![1.0f32]);
        assert!(a.as_f32().is_ok());
        assert!(a.as_f64().is_err());
        let b = Rows::from(vec![1.0f64]);
        assert!(b.as_f64().is_ok());
        assert!(b.as_f32().is_err());
        assert!(<f32 as Elem>::rows_into(b.clone()).is_err());
        assert_eq!(<f64 as Elem>::rows_into(b).unwrap(), vec![1.0f64]);
    }

    #[test]
    fn rows_pad_extend_and_slice() {
        let mut pad = Rows::zeros(Precision::F64, 0);
        pad.extend_from(&Rows::from(vec![1.0f64, 2.0])).unwrap();
        pad.resize(4);
        assert_eq!(pad, vec![1.0f64, 2.0, 0.0, 0.0]);
        assert_eq!(pad.slice(1..3), vec![2.0f64, 0.0]);
        // Cross-dtype extension is a hard error, not a conversion.
        assert!(pad.extend_from(&Rows::from(vec![1.0f32])).is_err());
    }

    #[test]
    fn elem_row_hooks_round_trip() {
        let v = vec![1.0f32, -2.0];
        let rows = <f32 as Elem>::rows_from(v.clone());
        assert_eq!(<f32 as Elem>::rows_as_slice(&rows).unwrap(), &v[..]);
        assert_eq!(<f32 as Elem>::rows_into(rows).unwrap(), v);
        let w = vec![0.5f64];
        let rows = <f64 as Elem>::rows_from(w.clone());
        assert_eq!(<f64 as Elem>::rows_as_slice(&rows).unwrap(), &w[..]);
        assert!(<f32 as Elem>::rows_as_slice(&rows).is_err());
    }

    #[test]
    fn elem_recip_matches_native_rounding() {
        // The generic reciprocal must reproduce the historical per-dtype
        // rounding exactly: 1.0f32 / k as f32 for f32.
        for k in 1..=64usize {
            assert_eq!(<f32 as Elem>::recip_usize(k), 1.0f32 / k as f32);
            assert_eq!(<f64 as Elem>::recip_usize(k), 1.0f64 / k as f64);
        }
    }

    #[test]
    fn level_views() {
        let s = SigSpec::new(2, 3).unwrap();
        let mut sig: Vec<f32> = (0..s.sig_len()).map(|i| i as f32).collect();
        assert_eq!(s.level(&sig, 1), &[0.0, 1.0]);
        assert_eq!(s.level(&sig, 2), &[2.0, 3.0, 4.0, 5.0]);
        s.level_mut(&mut sig, 3)[0] = 99.0;
        assert_eq!(sig[6], 99.0);
    }

    #[test]
    fn truncate_spec() {
        let s = SigSpec::new(3, 5).unwrap();
        let t = s.truncate(2);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.sig_len(), 12);
        assert_eq!(t.off(2), 3);
    }

    #[test]
    fn reciprocals_values() {
        let r = reciprocals(4);
        assert_eq!(r, vec![1.0, 0.5, 1.0 / 3.0, 0.25]);
    }

    #[test]
    fn workspace_sizes() {
        let s = SigSpec::new(3, 4).unwrap();
        let w: Workspace = Workspace::new(&s);
        assert_eq!(w.h0.len(), 27); // d^(N-1)
        assert_eq!(w.zdiv.len(), 12);
        assert_eq!(w.t0.len(), s.sig_len());
        let s1 = SigSpec::new(3, 1).unwrap();
        let w1: Workspace<f64> = Workspace::new(&s1);
        assert_eq!(w1.h0.len(), 3);
    }
}
