//! The truncated tensor product ⊠ (§2.2, eq. (8)) and its handwritten VJP.
//!
//! For `A, B` with implicit unit scalar term,
//! `(A ⊠ B)_k = A_k + B_k + Σ_{i=1}^{k-1} A_i ⊗ B_{k-i}`.
//!
//! The inner `A_i ⊗ B_{k-i}` loops are plain outer products over flat
//! slices; written so the innermost loop is a contiguous FMA over `B`'s
//! trailing index (auto-vectorises well). All routines are generic over the
//! sealed element trait [`Elem`] (f32/f64); `f32` call sites infer as
//! before.

use super::{Elem, SigSpec};

/// `out += a_i ⊗ b_j` where `a_i` has `la` entries and `b_j` has `lb`
/// entries; `out` has `la * lb` entries.
#[inline]
pub(crate) fn outer_add<E: Elem>(a: &[E], b: &[E], out: &mut [E]) {
    debug_assert_eq!(out.len(), a.len() * b.len());
    let lb = b.len();
    for (p, &ap) in a.iter().enumerate() {
        let row = &mut out[p * lb..(p + 1) * lb];
        for (q, &bq) in b.iter().enumerate() {
            row[q] += ap * bq;
        }
    }
}

/// Full ⊠ with implicit units: `out = a ⊠ b`. `out` may not alias inputs.
pub fn mul_into<E: Elem>(spec: &SigSpec, a: &[E], b: &[E], out: &mut [E]) {
    let n = spec.depth();
    debug_assert_eq!(a.len(), spec.sig_len());
    debug_assert_eq!(b.len(), spec.sig_len());
    debug_assert_eq!(out.len(), spec.sig_len());
    for k in 1..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let dst = &mut out[ok..ok + lk];
        // A_0 ⊗ B_k + A_k ⊗ B_0 = A_k + B_k.
        for (d, (&x, &y)) in dst.iter_mut().zip(a[ok..ok + lk].iter().zip(&b[ok..ok + lk])) {
            *d = x + y;
        }
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            outer_add(&a[oi..oi + li], &b[oj..oj + lj], dst);
        }
    }
}

/// Allocating convenience wrapper around [`mul_into`].
pub fn mul<E: Elem>(spec: &SigSpec, a: &[E], b: &[E]) -> Vec<E> {
    let mut out = spec.zeros_elem::<E>();
    mul_into(spec, a, b, &mut out);
    out
}

/// In-place right-multiplication `a = a ⊠ b`.
///
/// Valid because `(a ⊠ b)_k` reads only `a_i` for `i <= k`: computing levels
/// from `k = depth` downward never reads an already-overwritten level.
pub fn mul_assign<E: Elem>(spec: &SigSpec, a: &mut [E], b: &[E]) {
    let n = spec.depth();
    for k in (1..=n).rev() {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        // Split so we can read lower levels of `a` while writing level k.
        let (alow, arest) = a.split_at_mut(ok);
        let dst = &mut arest[..lk];
        // A_k + B_k (A_k already in place).
        for (d, &y) in dst.iter_mut().zip(&b[ok..ok + lk]) {
            *d += y;
        }
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            outer_add(&alow[oi..oi + li], &b[oj..oj + lj], dst);
        }
    }
}

/// Like [`mul_into`] but treating both inputs as having *zero* scalar term
/// (used by the log/inverse series): `out_k = Σ_{i=1}^{k-1} a_i ⊗ b_{k-i}`.
/// Note `out_1 = 0`.
pub fn mul_nounit_into<E: Elem>(spec: &SigSpec, a: &[E], b: &[E], out: &mut [E]) {
    let n = spec.depth();
    for k in 1..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let dst = &mut out[ok..ok + lk];
        dst.fill(E::ZERO);
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            outer_add(&a[oi..oi + li], &b[oj..oj + lj], dst);
        }
    }
}

/// `ga_i[α] += Σ_β g[α,β] * b[β]` — contraction of the gradient of an outer
/// product against the right factor. `g` is `(la, lb)` row-major.
#[inline]
pub(crate) fn contract_right_add<E: Elem>(g: &[E], b: &[E], ga: &mut [E]) {
    let lb = b.len();
    debug_assert_eq!(g.len(), ga.len() * lb);
    for (p, gap) in ga.iter_mut().enumerate() {
        let row = &g[p * lb..(p + 1) * lb];
        let mut acc = E::ZERO;
        for (q, &bq) in b.iter().enumerate() {
            acc += row[q] * bq;
        }
        *gap += acc;
    }
}

/// `gb[β] += Σ_α g[α,β] * a[α]` — contraction against the left factor.
#[inline]
pub(crate) fn contract_left_add<E: Elem>(g: &[E], a: &[E], gb: &mut [E]) {
    let lb = gb.len();
    debug_assert_eq!(g.len(), a.len() * lb);
    for (p, &ap) in a.iter().enumerate() {
        let row = &g[p * lb..(p + 1) * lb];
        for (q, gbq) in gb.iter_mut().enumerate() {
            *gbq += ap * row[q];
        }
    }
}

/// VJP of `out = a ⊠ b`: accumulates `∂L/∂a` into `ga` and `∂L/∂b` into
/// `gb`, given `g = ∂L/∂out`.
pub fn mul_vjp<E: Elem>(spec: &SigSpec, a: &[E], b: &[E], g: &[E], ga: &mut [E], gb: &mut [E]) {
    let n = spec.depth();
    for k in 1..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let gk = &g[ok..ok + lk];
        // Unit terms: out_k += a_k and out_k += b_k.
        for (x, &gv) in ga[ok..ok + lk].iter_mut().zip(gk) {
            *x += gv;
        }
        for (x, &gv) in gb[ok..ok + lk].iter_mut().zip(gk) {
            *x += gv;
        }
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            contract_right_add(gk, &b[oj..oj + lj], &mut ga[oi..oi + li]);
            contract_left_add(gk, &a[oi..oi + li], &mut gb[oj..oj + lj]);
        }
    }
}

/// VJP of [`mul_nounit_into`] (no unit terms).
pub fn mul_nounit_vjp<E: Elem>(
    spec: &SigSpec,
    a: &[E],
    b: &[E],
    g: &[E],
    ga: &mut [E],
    gb: &mut [E],
) {
    let n = spec.depth();
    for k in 2..=n {
        let ok = spec.off(k);
        let lk = spec.level_len(k);
        let gk = &g[ok..ok + lk];
        for i in 1..k {
            let (oi, li) = (spec.off(i), spec.level_len(i));
            let (oj, lj) = (spec.off(k - i), spec.level_len(k - i));
            contract_right_add(gk, &b[oj..oj + lj], &mut ga[oi..oi + li]);
            contract_left_add(gk, &a[oi..oi + li], &mut gb[oj..oj + lj]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};

    fn spec(d: usize, n: usize) -> SigSpec {
        SigSpec::new(d, n).unwrap()
    }

    #[test]
    fn mul_depth1_is_addition() {
        let s = spec(3, 1);
        let out = mul(&s, &[1.0f32, 2.0, 3.0], &[10.0, 20.0, 30.0]);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn mul_d1_n2_by_hand() {
        // a = (a1, a2), b = (b1, b2): (a ⊠ b) = (a1+b1, a2+b2+a1*b1).
        let s = spec(1, 2);
        let out = mul(&s, &[2.0f32, 3.0], &[5.0, 7.0]);
        assert_eq!(out, vec![7.0, 3.0 + 7.0 + 10.0]);
    }

    #[test]
    fn mul_d2_n2_by_hand() {
        let s = spec(2, 2);
        // a1 = [1,2], a2 = zeros; b1 = [3,4], b2 = zeros.
        let a = [1.0f32, 2.0, 0.0, 0.0, 0.0, 0.0];
        let b = [3.0f32, 4.0, 0.0, 0.0, 0.0, 0.0];
        let out = mul(&s, &a, &b);
        // Level 2 = a1 ⊗ b1 = [[3,4],[6,8]].
        assert_eq!(out, vec![4.0, 6.0, 3.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn mul_f64_matches_by_hand() {
        // The f64 instantiation performs the same algebra (exactly, on
        // integer-valued inputs).
        let s = spec(2, 2);
        let a = [1.0f64, 2.0, 0.0, 0.0, 0.0, 0.0];
        let b = [3.0f64, 4.0, 0.0, 0.0, 0.0, 0.0];
        let out = mul(&s, &a, &b);
        assert_eq!(out, vec![4.0f64, 6.0, 3.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn mul_is_associative() {
        property("mul associative", 25, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            g.label(format!("d={d} n={n}"));
            let s = spec(d, n);
            let a = g.normal_vec(s.sig_len(), 0.5);
            let b = g.normal_vec(s.sig_len(), 0.5);
            let c = g.normal_vec(s.sig_len(), 0.5);
            let ab_c = mul(&s, &mul(&s, &a, &b), &c);
            let a_bc = mul(&s, &a, &mul(&s, &b, &c));
            assert_close(&ab_c, &a_bc, 1e-4, 1e-5);
        });
    }

    #[test]
    fn unit_is_identity() {
        // The implicit-unit zero vector is the group identity.
        property("unit identity", 20, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 4);
            let s = spec(d, n);
            let a = g.normal_vec(s.sig_len(), 1.0);
            let e = s.zeros();
            assert_close(&mul(&s, &a, &e), &a, 1e-6, 1e-7);
            assert_close(&mul(&s, &e, &a), &a, 1e-6, 1e-7);
        });
    }

    #[test]
    fn mul_assign_matches_mul_into() {
        property("mul_assign == mul_into", 30, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let s = spec(d, n);
            let mut a = g.normal_vec(s.sig_len(), 1.0);
            let b = g.normal_vec(s.sig_len(), 1.0);
            let expect = mul(&s, &a, &b);
            mul_assign(&s, &mut a, &b);
            assert_close(&a, &expect, 1e-6, 1e-7);
        });
    }

    #[test]
    fn mul_nounit_drops_unit_terms() {
        let s = spec(2, 3);
        let mut g = crate::substrate::rng::Rng::new(4);
        let a = g.normal_vec(s.sig_len(), 1.0);
        let b = g.normal_vec(s.sig_len(), 1.0);
        let full = mul(&s, &a, &b);
        let mut nounit = s.zeros();
        mul_nounit_into(&s, &a, &b, &mut nounit);
        for i in 0..s.sig_len() {
            let diff = full[i] - nounit[i];
            assert!((diff - (a[i] + b[i])).abs() < 1e-5);
        }
    }

    /// Finite-difference check of a VJP: <g, f(x+h e_i) - f(x-h e_i)>/(2h)
    /// should equal grad_i for every i.
    fn fd_check<F>(x: &[f32], g_out: &[f32], grad: &[f32], f: F, tol: f32)
    where
        F: Fn(&[f32]) -> Vec<f32>,
    {
        let h = 1e-2f32;
        for i in 0..x.len() {
            let mut xp = x.to_vec();
            xp[i] += h;
            let mut xm = x.to_vec();
            xm[i] -= h;
            let fp = f(&xp);
            let fm = f(&xm);
            let dirderiv: f32 = fp
                .iter()
                .zip(&fm)
                .zip(g_out)
                .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                .sum();
            assert!(
                (dirderiv - grad[i]).abs() <= tol * (1.0 + dirderiv.abs().max(grad[i].abs())),
                "grad mismatch at {i}: fd={dirderiv} vjp={}",
                grad[i]
            );
        }
    }

    #[test]
    fn mul_vjp_matches_finite_differences() {
        property("mul vjp fd", 8, |gen| {
            let d = gen.usize_in(1, 3);
            let n = gen.usize_in(1, 4);
            gen.label(format!("d={d} n={n}"));
            let s = spec(d, n);
            let a = gen.normal_vec(s.sig_len(), 0.5);
            let b = gen.normal_vec(s.sig_len(), 0.5);
            let g = gen.normal_vec(s.sig_len(), 1.0);
            let mut ga = s.zeros();
            let mut gb = s.zeros();
            mul_vjp(&s, &a, &b, &g, &mut ga, &mut gb);
            fd_check(&a, &g, &ga, |x| mul(&s, x, &b), 2e-2);
            fd_check(&b, &g, &gb, |x| mul(&s, &a, x), 2e-2);
        });
    }

    #[test]
    fn mul_nounit_vjp_matches_finite_differences() {
        let s = spec(2, 3);
        let mut rng = crate::substrate::rng::Rng::new(77);
        let a = rng.normal_vec(s.sig_len(), 0.5);
        let b = rng.normal_vec(s.sig_len(), 0.5);
        let g = rng.normal_vec(s.sig_len(), 1.0);
        let mut ga = s.zeros();
        let mut gb = s.zeros();
        mul_nounit_vjp(&s, &a, &b, &g, &mut ga, &mut gb);
        let f_a = |x: &[f32]| {
            let mut out = s.zeros();
            mul_nounit_into(&s, x, &b, &mut out);
            out
        };
        let f_b = |x: &[f32]| {
            let mut out = s.zeros();
            mul_nounit_into(&s, &a, x, &mut out);
            out
        };
        fd_check(&a, &g, &ga, f_a, 2e-2);
        fd_check(&b, &g, &gb, f_b, 2e-2);
    }

    #[test]
    fn vjp_accumulates_rather_than_overwrites() {
        let s = spec(2, 2);
        let a = [1.0f32, 0.0, 0.0, 0.0, 0.0, 0.0];
        let b = [0.0f32, 1.0, 0.0, 0.0, 0.0, 0.0];
        let g = [0.0f32; 6];
        let mut ga = vec![7.0f32; 6];
        let mut gb = vec![9.0f32; 6];
        mul_vjp(&s, &a, &b, &g, &mut ga, &mut gb);
        assert_eq!(ga, vec![7.0; 6]);
        assert_eq!(gb, vec![9.0; 6]);
    }
}
