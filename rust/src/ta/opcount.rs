//! Multiplication-count model of App. A.1: the closed forms `F(d, N)`
//! (fused, eq. (11)) and `C(d, N)` (conventional, eq. (9)), plus
//! instrumented counters that validate the closed forms against the actual
//! loop structure. These back the `tables --table opcount` harness entry
//! and the paper's claims `F ≤ C` uniformly and `F = O(d^N)` vs
//! `C = Θ(N d^N)`.

/// Binomial coefficient with u128 accumulation (exact for our ranges).
pub fn binomial(n: u64, k: u64) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u128 = 1;
    for i in 0..k {
        num = num * (n - i) as u128 / (i + 1) as u128;
    }
    num
}

/// `C(d, N)` — scalar multiplications of the conventional
/// exponential-then-⊠ (App. A.1.1, eq. (9)):
/// `Σ_{k=2..N} (d + C(d+k-1, k)) + Σ_{k=1..N} (k-1) d^k`.
pub fn conventional_muls(d: u64, n: u64) -> u128 {
    let mut total: u128 = 0;
    for k in 2..=n {
        total += d as u128 + binomial(d + k - 1, k);
    }
    for k in 1..=n {
        total += (k - 1) as u128 * (d as u128).pow(k as u32);
    }
    total
}

/// `F(d, N)` — scalar multiplications of the fused multiply-exponentiate
/// (App. A.1.2, eq. (11)): `d (N-1) + Σ_{k=1..N} Σ_{i=2..k} d^i`.
pub fn fused_muls(d: u64, n: u64) -> u128 {
    let mut total: u128 = d as u128 * (n - 1) as u128;
    for k in 1..=n {
        for i in 2..=k {
            total += (d as u128).pow(i as u32);
        }
    }
    total
}

/// Count the multiplications the *actual* fused loop performs, by walking
/// the same iteration space as `fused::fused_mexp` symbolically.
pub fn fused_muls_instrumented(d: u64, n: u64) -> u128 {
    let mut muls: u128 = 0;
    // stage_zdiv computes z/m for m = 2..=N (z/1 is z itself: in the closed
    // form of the paper this is the d(N-1) term).
    muls += d as u128 * (n - 1) as u128;
    for k in (2..=n).rev() {
        // B_1 = z/k + A_1: no multiplications (z/k staged already).
        let mut cur_len = d as u128;
        for _i in 2..k {
            // B_i = B_{i-1} ⊗ z/(k-i+1) + A_i: cur_len * d multiplications.
            muls += cur_len * d as u128;
            cur_len *= d as u128;
        }
        // Final A_k += B_{k-1} ⊗ z: cur_len * d multiplications.
        muls += cur_len * d as u128;
    }
    muls
}

/// Closed form of eq. (12): `F(d,N) = (d^{N+2} - d^3 - (N-1)d^2 + (N-1)d) /
/// (d-1)^2` for `d ≥ 2`.
pub fn fused_muls_closed(d: u64, n: u64) -> u128 {
    assert!(d >= 2);
    let d = d as i128;
    let n = n as i128;
    let num = d.pow((n + 2) as u32) - d.pow(3) - (n - 1) * d * d + (n - 1) * d;
    (num / ((d - 1) * (d - 1))) as u128
}

/// `Fv(d, N)` — scalar multiplications of the fused Horner **VJP**
/// (App. C), in the dimension-uniform accounting of iisignature's cost
/// model: the backward replays each level-`k` forward chain and unwinds it
/// with two multiplications per chain entry, so
///
/// ```text
/// Fv(d,N) = d(N-1) + Σ_{k=2..N} [ Σ_{i=2..k-1} (3 d^i + d) + 2 d^k + d ]
/// ```
///
/// (recompute `Σ d^i`, unwind `2 d^i + d` per middle step, `2 d^k` for the
/// final step, `d` for the innermost `gz` drain). Like the forward count
/// this is uniform in `d` — there is no term that depends on whether the
/// kernel is monomorphised — which is what justifies dispatching the
/// runtime-`d` body beyond the mono window.
pub fn fused_vjp_muls(d: u64, n: u64) -> u128 {
    let d128 = d as u128;
    let mut total: u128 = d128 * (n - 1) as u128;
    for k in 2..=n {
        for i in 2..k {
            // Recompute (1 mul per entry) + unwind middle step (2 muls per
            // entry + d for the inv_m drain).
            total += 3 * d128.pow(i as u32) + d128;
        }
        // Final unwind step: 2 muls per level-k entry.
        total += 2 * d128.pow(k as u32);
        // Innermost gz drain: d muls.
        total += d128;
    }
    total
}

/// Count the multiplications the **monomorphised** VJP body
/// (`fused::fused_mexp_vjp_mono::<D>`) performs, by walking its iteration
/// space symbolically (stack `[E; D]` accumulator variant).
pub fn fused_vjp_muls_mono_instrumented(d: u64, n: u64) -> u128 {
    let d128 = d as u128;
    let mut muls: u128 = d128 * (n - 1) as u128; // stage_zdiv
    for k in (2..=n).rev() {
        // Recompute chain: B_i = B_{i-1} ⊗ zm + A_i for i = 2..k-1.
        let mut cur_len = d128;
        for _i in 2..k {
            muls += cur_len * d128;
            cur_len *= d128;
        }
        // Final step: per p in d^{k-1}, per q in D: acc += row*z (1),
        // gz += bp*row (1).
        muls += 2 * cur_len * d128;
        // Middle steps i = k-1..2: gb/gz_acc accumulate (2 muls per entry
        // of gB_i), then gz += inv_m * gz_acc (d muls; the stack [E; D]
        // accumulator drains with one multiply per channel).
        let mut len_i = cur_len;
        for _i in (2..k).rev() {
            let prev_len = len_i / d128;
            muls += 2 * prev_len * d128;
            muls += d128;
            len_i = prev_len;
        }
        // Innermost: gz += inv_k * gb1 (d muls).
        muls += d128;
    }
    muls
}

/// Count the multiplications the **runtime-`d`** VJP body
/// (`fused::fused_mexp_vjp_dyn`) performs, walking its iteration space
/// (heap `ws.t1[..d]` accumulator variant — zero-fills are not counted,
/// matching the mono walker's treatment of its stack zero-init).
pub fn fused_vjp_muls_dyn_instrumented(d: u64, n: u64) -> u128 {
    let d128 = d as u128;
    let mut muls: u128 = d128 * (n - 1) as u128; // stage_zdiv
    for k in (2..=n).rev() {
        let mut cur_len = d128;
        for _i in 2..k {
            // lane-contiguous recompute: cur_len rows × d channels.
            muls += cur_len * d128;
            cur_len *= d128;
        }
        // Final unwind: 2 muls per (p, q) pair.
        muls += 2 * cur_len * d128;
        let mut len_i = cur_len;
        for _i in (2..k).rev() {
            let prev_len = len_i / d128;
            // gb_prev/gz_acc accumulation: 2 muls per entry of gB_i.
            muls += 2 * prev_len * d128;
            // inv_m drain of the heap accumulator: d muls.
            muls += d128;
            len_i = prev_len;
        }
        muls += d128; // inv_k drain
    }
    muls
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(5, 2), 10);
        assert_eq!(binomial(10, 0), 1);
        assert_eq!(binomial(4, 7), 0);
        assert_eq!(binomial(13, 7), 1716);
    }

    #[test]
    fn instrumented_matches_closed_form() {
        // The actual loop performs exactly F(d, N) multiplications.
        for d in 1..=7u64 {
            for n in 1..=9u64 {
                assert_eq!(
                    fused_muls_instrumented(d, n),
                    fused_muls(d, n),
                    "d={d} n={n}"
                );
            }
        }
    }

    #[test]
    fn eq12_closed_form_matches_sum() {
        for d in 2..=7u64 {
            for n in 1..=9u64 {
                assert_eq!(fused_muls_closed(d, n), fused_muls(d, n), "d={d} n={n}");
            }
        }
    }

    #[test]
    fn fused_never_exceeds_conventional() {
        // App. A.1.3: F(d, N) ≤ C(d, N) uniformly over d ≥ 1, N ≥ 1.
        for d in 1..=10u64 {
            for n in 1..=10u64 {
                assert!(
                    fused_muls(d, n) <= conventional_muls(d, n),
                    "F > C at d={d} n={n}: {} > {}",
                    fused_muls(d, n),
                    conventional_muls(d, n)
                );
            }
        }
    }

    #[test]
    fn boundary_cases_from_appendix() {
        // N = 1: F = C = 0.
        for d in 1..=8u64 {
            assert_eq!(fused_muls(d, 1), 0);
            assert_eq!(conventional_muls(d, 1), 0);
        }
        // N = 2: F = d + d^2, C = d + C(d+1,2) + d^2.
        for d in 1..=8u64 {
            assert_eq!(fused_muls(d, 2), (d + d * d) as u128);
            assert_eq!(
                conventional_muls(d, 2),
                d as u128 + binomial(d + 1, 2) + (d * d) as u128
            );
        }
    }

    #[test]
    fn asymptotic_gap_grows_linearly_in_n() {
        // C / F ≈ Θ(N): check the ratio is monotone increasing in N and
        // exceeds N/2 for d = 4.
        let d = 4u64;
        let mut prev_ratio = 0.0;
        for n in 3..=9u64 {
            let ratio = conventional_muls(d, n) as f64 / fused_muls(d, n) as f64;
            assert!(ratio > prev_ratio, "ratio not increasing at n={n}");
            assert!(ratio > n as f64 / 2.0 - 1.0, "ratio too small at n={n}: {ratio}");
            prev_ratio = ratio;
        }
    }

    #[test]
    fn dyn_vjp_opcount_matches_mono_in_the_mono_window() {
        // The runtime-d backward performs exactly as many multiplications
        // as the monomorphised one wherever both exist (d ≤ 8): switching
        // bodies at the crossover trades instruction selection, never work.
        for d in 1..=8u64 {
            for n in 1..=7u64 {
                assert_eq!(
                    fused_vjp_muls_dyn_instrumented(d, n),
                    fused_vjp_muls_mono_instrumented(d, n),
                    "d={d} n={n}"
                );
            }
        }
    }

    #[test]
    fn vjp_walkers_match_the_closed_accounting() {
        // Both walkers agree with Fv(d, N) — including beyond the mono
        // window, where only the dyn body exists.
        for &d in &[1u64, 2, 3, 4, 5, 6, 7, 8, 9, 12, 20] {
            for n in 1..=6u64 {
                assert_eq!(fused_vjp_muls_mono_instrumented(d, n), fused_vjp_muls(d, n), "mono d={d} n={n}");
                assert_eq!(fused_vjp_muls_dyn_instrumented(d, n), fused_vjp_muls(d, n), "dyn d={d} n={n}");
            }
        }
    }

    #[test]
    fn vjp_cost_is_same_order_as_forward() {
        // App. C: the Horner backward is O(d^N), the same order as the
        // fused forward — the ratio stays bounded (< 4) instead of growing
        // with N like the exp/⊠ composition's Θ(N d^N).
        for &d in &[2u64, 4, 9, 12, 20] {
            for n in 2..=6u64 {
                let ratio = fused_vjp_muls(d, n) as f64 / fused_muls(d, n) as f64;
                assert!(ratio < 4.0, "VJP/forward ratio {ratio} too large at d={d} n={n}");
            }
        }
    }

    #[test]
    fn paper_headline_point() {
        // d = N = 7: the regime of the paper's headline 5.5× CPU speedup.
        let f = fused_muls(7, 7) as f64;
        let c = conventional_muls(7, 7) as f64;
        assert!(c / f > 4.0, "expected a large multiplication-count gap, got {}", c / f);
    }
}
