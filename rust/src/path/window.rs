//! Server-maintained rolling windows over a [`Path`] — the paper's
//! headline inference optimisation (§5.5) turned into a serving feature.
//!
//! A [`WindowSpec`] names a sliding interval family: window `k` covers
//! absolute points `[k * stride, k * stride + len - 1]`. As the path
//! grows, [`RollingWindow::advance`] emits each newly-complete window's
//! signature (or logsignature) via the stored-inverse trick — one
//! `I_i ⊠ S_j` through the allocation-free [`Path::query_into`] /
//! [`Path::logsig_query_into`] hot paths — so a slide costs **O(1)**
//! amortised instead of the O(len) recompute a client-side re-query loop
//! pays.
//!
//! `advance` also owns the bounded-memory half of the contract: once the
//! dead prefix (points strictly before the next unemitted window) reaches
//! half the retained storage it is dropped through
//! [`Path::truncate_front`] — a geometric policy, so truncation cost is
//! O(1) amortised per fed point and retained storage stays O(len + stride)
//! per session instead of O(history). Because truncation never touches a
//! retained `S_j` / `I_i` row, rolling outputs are **bitwise identical**
//! to per-query [`Path::query`] / [`Path::logsig_query`] over the same
//! intervals on an untruncated control (pinned by property tests below).
//!
//! Emitted-but-unpolled rows live in the `pending` buffer, which is part
//! of the durable state (the points they were computed from may already
//! be truncated, so they cannot be recomputed): the state codec persists
//! it alongside the path buffers, and WAL replay re-delivers exactly the
//! undelivered suffix.

use crate::logsignature::{LogSigBasis, LogSigPlan, LogSigWorkspace};
use crate::path::Path;
use crate::ta::{Elem, SigSpec};

/// A sliding-window family over a session's stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSpec {
    /// Points per window (`>= 2`: a window is an interval query).
    pub len: usize,
    /// Points between successive window starts (`>= 1`).
    pub stride: usize,
    /// `None` emits signatures (`sig_len` values per slide); `Some(basis)`
    /// emits logsignatures in that basis (`plan.dim()` values per slide).
    pub logsig: Option<LogSigBasis>,
}

impl WindowSpec {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.len >= 2, "window len must be >= 2, got {}", self.len);
        anyhow::ensure!(self.stride >= 1, "window stride must be >= 1, got {}", self.stride);
        Ok(())
    }
}

/// Rolling-window state attached to a session's [`Path`]. The durable
/// fields are the spec, the emission cursor (`next_end`), the
/// emitted/delivered counters, and the undelivered `pending` rows; the
/// logsignature plan and workspace are transient and rebuilt on reload,
/// like the path's own [`crate::ta::Workspace`].
pub struct RollingWindow<E: Elem> {
    spec: WindowSpec,
    /// Output width per slide: `sig_len` or the basis dimension.
    out_dim: usize,
    /// Absolute index of the right endpoint of the next window to emit
    /// (`len - 1 + emitted * stride`).
    next_end: usize,
    /// Total windows emitted into `pending` over the session's lifetime.
    emitted: u64,
    /// Windows already handed back by [`RollingWindow::poll`].
    delivered: u64,
    /// Undelivered rows, `(emitted - delivered, out_dim)` row-major.
    pending: Vec<E>,
    plan: Option<LogSigPlan>,
    ws: Option<LogSigWorkspace<E>>,
}

impl<E: Elem> RollingWindow<E> {
    /// Fresh window state for a new session (nothing emitted yet).
    pub fn new(path_spec: &SigSpec, spec: WindowSpec) -> anyhow::Result<RollingWindow<E>> {
        RollingWindow::from_raw(path_spec, spec, (spec.len - 1) as u64, 0, 0, Vec::new())
    }

    /// Reassemble from persisted fields (the codec's constructor): checks
    /// the counters' mutual invariants, then rebuilds the transient
    /// plan/workspace. `pending` is adopted verbatim — reload is bitwise.
    pub(crate) fn from_raw(
        path_spec: &SigSpec,
        spec: WindowSpec,
        next_end: u64,
        emitted: u64,
        delivered: u64,
        pending: Vec<E>,
    ) -> anyhow::Result<RollingWindow<E>> {
        spec.validate()?;
        let (plan, ws) = match spec.logsig {
            Some(basis) => (
                Some(LogSigPlan::new(path_spec, basis)?),
                Some(LogSigWorkspace::new(path_spec)),
            ),
            None => (None, None),
        };
        let out_dim = match &plan {
            Some(p) => p.dim(),
            None => path_spec.sig_len(),
        };
        anyhow::ensure!(
            next_end == (spec.len - 1) as u64 + emitted * spec.stride as u64,
            "window cursor {next_end} inconsistent with {emitted} emissions"
        );
        anyhow::ensure!(delivered <= emitted, "delivered {delivered} > emitted {emitted}");
        anyhow::ensure!(
            pending.len() as u64 == (emitted - delivered) * out_dim as u64,
            "pending buffer has {} values, expected {} rows of {out_dim}",
            pending.len(),
            emitted - delivered
        );
        Ok(RollingWindow {
            spec,
            out_dim,
            next_end: next_end as usize,
            emitted,
            delivered,
            pending,
            plan,
            ws,
        })
    }

    /// The persisted fields, by reference: `(spec, next_end, emitted,
    /// delivered, pending)`.
    pub(crate) fn raw_parts(&self) -> (WindowSpec, u64, u64, u64, &[E]) {
        (self.spec, self.next_end as u64, self.emitted, self.delivered, &self.pending)
    }

    pub fn spec(&self) -> WindowSpec {
        self.spec
    }

    /// Values per emitted slide.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Undelivered slides currently buffered.
    pub fn pending_rows(&self) -> usize {
        (self.emitted - self.delivered) as usize
    }

    /// Bytes of buffered undelivered output (counted into the session's
    /// byte budget alongside the path's own storage).
    pub fn pending_bytes(&self) -> usize {
        self.pending.len() * std::mem::size_of::<E>()
    }

    /// Emit every newly-complete window, then apply the retention policy.
    /// O(1) amortised per slide (one ⊠ each) and per fed point (geometric
    /// truncation). Returns the number of slides emitted. Deterministic in
    /// the fed points alone — feed chunking and truncation history never
    /// change the emitted bits.
    pub fn advance(&mut self, path: &mut Path<E>) -> anyhow::Result<usize> {
        let WindowSpec { len, stride, .. } = self.spec;
        let mut emitted_now = 0usize;
        while self.next_end < path.len() {
            let j = self.next_end;
            let i = j + 1 - len;
            let off = self.pending.len();
            self.pending.resize(off + self.out_dim, E::ZERO);
            match (&self.plan, &mut self.ws) {
                (Some(plan), Some(ws)) => {
                    path.logsig_query_into(i, j, plan, ws, &mut self.pending[off..])?
                }
                _ => path.query_into(i, j, &mut self.pending[off..])?,
            }
            self.emitted += 1;
            emitted_now += 1;
            self.next_end += stride;
        }
        // Retention: points strictly before the next window's start are
        // dead. Truncate only once the dead prefix reaches half the
        // retained storage, so each point is moved O(1) times overall and
        // storage stays within 2x the live horizon.
        let target = (self.next_end + 1).saturating_sub(len);
        let dead = target.saturating_sub(path.base());
        if dead > 0 && 2 * dead >= path.stored_len() {
            path.truncate_front(target);
        }
        Ok(emitted_now)
    }

    /// Hand back every undelivered slide: `(index of the first returned
    /// slide, rows)` — row `r` is slide `first + r`, covering points
    /// `[(first + r) * stride, (first + r) * stride + len - 1]`. Empty rows
    /// (with `first` = the next future slide) when nothing is pending.
    pub fn poll(&mut self) -> (u64, Vec<E>) {
        let first = self.delivered;
        self.delivered = self.emitted;
        (first, std::mem::take(&mut self.pending))
    }

    /// Replay a logged poll: drop the rows a pre-crash client already
    /// received, so a warm restart re-delivers exactly the undelivered
    /// suffix instead of double-delivering.
    pub(crate) fn mark_delivered(&mut self, upto: u64) {
        let upto = upto.min(self.emitted);
        if upto <= self.delivered {
            return;
        }
        let drop_rows = (upto - self.delivered) as usize;
        self.pending.drain(..drop_rows * self.out_dim);
        self.delivered = upto;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::property;
    use crate::substrate::rng::Rng;

    fn random_walk<E: Elem>(rng: &mut Rng, stream: usize, d: usize) -> Vec<E> {
        let mut p = vec![E::ZERO; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] =
                    p[(i - 1) * d + c] + E::from_f64(rng.normal_f32() as f64) * E::from_f64(0.3);
            }
        }
        p
    }

    /// Feed `pts` into a windowed path in the given ragged chunks,
    /// advancing + polling after each, and check every emitted slide
    /// bitwise against per-query results on an untruncated control.
    fn check_rolling<E: Elem>(spec: &SigSpec, wspec: WindowSpec, pts: &[E], chunks: &[usize]) {
        let d = spec.d();
        let total: usize = chunks.iter().sum();
        assert_eq!(pts.len(), total * d);
        let control = Path::<E>::new(spec, pts, total).unwrap();
        let first = chunks[0];
        let mut path = Path::<E>::new(spec, &pts[..first * d], first).unwrap();
        let mut win = RollingWindow::<E>::new(spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        let mut slides: Vec<(u64, Vec<E>)> = Vec::new();
        let drain = |w: &mut RollingWindow<E>, out: &mut Vec<(u64, Vec<E>)>| {
            let (mut k, rows) = w.poll();
            for row in rows.chunks(w.out_dim()) {
                out.push((k, row.to_vec()));
                k += 1;
            }
        };
        drain(&mut win, &mut slides);
        let mut fed = first;
        for &c in &chunks[1..] {
            path.update(&pts[fed * d..(fed + c) * d], c).unwrap();
            fed += c;
            win.advance(&mut path).unwrap();
            drain(&mut win, &mut slides);
        }
        // Every complete window emitted exactly once, in order.
        let expect = if total >= wspec.len { (total - wspec.len) / wspec.stride + 1 } else { 0 };
        assert_eq!(slides.len(), expect, "slide count");
        let lplan = wspec.logsig.map(|b| LogSigPlan::new(spec, b).unwrap());
        for (k, row) in &slides {
            let i = *k as usize * wspec.stride;
            let j = i + wspec.len - 1;
            let want = match &lplan {
                Some(plan) => control.logsig_query(i, j, plan).unwrap(),
                None => control.query(i, j).unwrap(),
            };
            assert_eq!(row, &want, "slide {k} [{i}, {j}]");
        }
        // Bounded memory: retained storage stays within 2x the live
        // horizon (plus the last feed chunk, which lands before retention
        // runs).
        let live = wspec.len + wspec.stride + chunks.iter().copied().max().unwrap();
        assert!(
            path.stored_len() <= 2 * live,
            "stored {} points for a live horizon of {live}",
            path.stored_len()
        );
    }

    #[test]
    fn rolling_matches_per_query_bitwise() {
        // The tentpole contract, both precisions: windowed emission over
        // ragged feeds + truncation == per-query on the full history,
        // bit for bit, across specs, strides, window lengths and bases.
        property("rolling == per-query bitwise", 14, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let len = g.usize_in(2, 9);
            let stride = g.usize_in(1, 4);
            let n_chunks = g.usize_in(1, 10);
            let logsig = match g.usize_in(0, 3) {
                0 => None,
                1 => Some(LogSigBasis::Expanded),
                2 => Some(LogSigBasis::Lyndon),
                _ => Some(LogSigBasis::Words),
            };
            let f64_lane = g.usize_in(0, 1) == 1;
            g.label(format!(
                "d={d} n={n} len={len} stride={stride} chunks={n_chunks} logsig={logsig:?} f64={f64_lane}"
            ));
            let spec = SigSpec::new(d, n).unwrap();
            let mut chunks: Vec<usize> = vec![g.usize_in(2, 6)];
            for _ in 1..n_chunks {
                chunks.push(g.usize_in(1, 6)); // ragged on purpose
            }
            let total: usize = chunks.iter().sum();
            let wspec = WindowSpec { len, stride, logsig };
            if f64_lane {
                let pts = random_walk::<f64>(g.rng(), total, d);
                let spec64 = SigSpec::with_dtype(d, n, crate::ta::Precision::F64).unwrap();
                check_rolling(&spec64, wspec, &pts, &chunks);
            } else {
                let pts = random_walk::<f32>(g.rng(), total, d);
                check_rolling(&spec, wspec, &pts, &chunks);
            }
        });
    }

    #[test]
    fn long_stream_memory_is_bounded() {
        // O(window), not O(history): after a long stream in small chunks,
        // retained storage is a small multiple of the window horizon.
        let spec = SigSpec::new(2, 3).unwrap();
        let wspec = WindowSpec { len: 16, stride: 4, logsig: None };
        let mut rng = Rng::new(41);
        let seed: Vec<f32> = random_walk(&mut rng, 2, 2);
        let mut path = Path::<f32>::new(&spec, &seed, 2).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        for _ in 0..500 {
            let chunk: Vec<f32> = rng.normal_vec(3 * 2, 0.3);
            path.update(&chunk, 3).unwrap();
            win.advance(&mut path).unwrap();
            win.poll();
        }
        assert_eq!(path.len(), 2 + 500 * 3);
        let live = wspec.len + wspec.stride + 3;
        assert!(
            path.stored_len() <= 2 * live,
            "stored {} points; live horizon {live}",
            path.stored_len()
        );
    }

    #[test]
    fn poll_and_mark_delivered_agree() {
        let spec = SigSpec::new(2, 3).unwrap();
        let wspec = WindowSpec { len: 4, stride: 2, logsig: None };
        let mut rng = Rng::new(42);
        let pts: Vec<f32> = random_walk(&mut rng, 20, 2);
        let mut path = Path::<f32>::new(&spec, &pts, 20).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        assert_eq!(win.pending_rows(), 9); // ends 3,5,..,19
        // Replaying a poll of the first 4 slides leaves slides 4.. pending.
        win.mark_delivered(4);
        assert_eq!(win.pending_rows(), 5);
        let (first, rows) = win.poll();
        assert_eq!(first, 4);
        assert_eq!(rows.len(), 5 * win.out_dim());
        // Idempotent / stale marks are no-ops; empty poll reports the next
        // future slide.
        win.mark_delivered(3);
        assert_eq!(win.pending_rows(), 0);
        let (first, rows) = win.poll();
        assert_eq!((first, rows.len()), (9, 0));
    }

    #[test]
    fn raw_roundtrip_resumes_bitwise() {
        // from_raw(raw_parts()) mid-stream must continue exactly like the
        // original — the codec-level durability contract in miniature.
        let spec = SigSpec::new(2, 4).unwrap();
        let wspec = WindowSpec { len: 6, stride: 3, logsig: Some(LogSigBasis::Words) };
        let mut rng = Rng::new(43);
        let pts: Vec<f32> = random_walk(&mut rng, 40, 2);
        let mut path = Path::<f32>::new(&spec, &pts[..14 * 2], 14).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        win.mark_delivered(1); // partially delivered on purpose
        let (s, ne, em, de, pending) = win.raw_parts();
        let mut revived =
            RollingWindow::<f32>::from_raw(&spec, s, ne, em, de, pending.to_vec()).unwrap();
        let mut control_path = Path::<f32>::new(&spec, &pts[..14 * 2], 14).unwrap();
        control_path.truncate_front(path.base());
        path.update(&pts[14 * 2..], 26).unwrap();
        control_path.update(&pts[14 * 2..], 26).unwrap();
        win.advance(&mut path).unwrap();
        revived.advance(&mut control_path).unwrap();
        assert_eq!(win.poll(), revived.poll());
    }

    #[test]
    fn invalid_specs_are_errors() {
        let spec = SigSpec::new(2, 3).unwrap();
        assert!(RollingWindow::<f32>::new(&spec, WindowSpec { len: 1, stride: 1, logsig: None })
            .is_err());
        assert!(RollingWindow::<f32>::new(&spec, WindowSpec { len: 4, stride: 0, logsig: None })
            .is_err());
        // Inconsistent persisted counters are clean decode errors.
        assert!(RollingWindow::<f32>::from_raw(
            &spec,
            WindowSpec { len: 4, stride: 2, logsig: None },
            3,
            1, // says one emission, but cursor still at the first window
            0,
            vec![0.0; spec.sig_len()],
        )
        .is_err());
        assert!(RollingWindow::<f32>::from_raw(
            &spec,
            WindowSpec { len: 4, stride: 2, logsig: None },
            5,
            1,
            2, // delivered > emitted
            Vec::new(),
        )
        .is_err());
    }
}
