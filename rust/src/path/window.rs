//! Server-maintained rolling windows over a [`Path`] — the paper's
//! headline inference optimisation (§5.5) turned into a serving feature.
//!
//! A [`WindowSpec`] names a sliding interval family: window `k` covers
//! absolute points `[k * stride, k * stride + len - 1]`. As the path
//! grows, [`RollingWindow::advance`] emits each newly-complete window's
//! signature (or logsignature) via the stored-inverse trick — one
//! `I_i ⊠ S_j` through the allocation-free [`Path::query_into`] /
//! [`Path::logsig_query_into`] hot paths — so a slide costs **O(1)**
//! amortised instead of the O(len) recompute a client-side re-query loop
//! pays.
//!
//! `advance` also owns the bounded-memory half of the contract: once the
//! dead prefix (points strictly before the next unemitted window) reaches
//! half the retained storage it is dropped through
//! [`Path::truncate_front`] — a geometric policy, so truncation cost is
//! O(1) amortised per fed point and retained storage stays O(len + stride)
//! per session instead of O(history). Because truncation never touches a
//! retained `S_j` / `I_i` row, rolling outputs are **bitwise identical**
//! to per-query [`Path::query`] / [`Path::logsig_query`] over the same
//! intervals on an untruncated control (pinned by property tests below).
//!
//! Emitted-but-unpolled rows live in the `pending` buffer, which is part
//! of the durable state (the points they were computed from may already
//! be truncated, so they cannot be recomputed): the state codec persists
//! it alongside the path buffers, and WAL replay re-delivers exactly the
//! undelivered suffix.

use crate::logsignature::batch::project_sigs_into;
use crate::logsignature::{LogSigBasis, LogSigPlan, LogSigWorkspace};
use crate::path::Path;
use crate::ta::batch::{exp_batch_in_place, mul_batch_into, unpack_lane, BatchWorkspace};
use crate::ta::{Elem, SigSpec};

/// A sliding-window family over a session's stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSpec {
    /// Points per window (`>= 2`: a window is an interval query).
    pub len: usize,
    /// Points between successive window starts (`>= 1`).
    pub stride: usize,
    /// `None` emits signatures (`sig_len` values per slide); `Some(basis)`
    /// emits logsignatures in that basis (`plan.dim()` values per slide).
    pub logsig: Option<LogSigBasis>,
}

impl WindowSpec {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.len >= 2, "window len must be >= 2, got {}", self.len);
        anyhow::ensure!(self.stride >= 1, "window stride must be >= 1, got {}", self.stride);
        Ok(())
    }
}

/// Rolling-window state attached to a session's [`Path`]. The durable
/// fields are the spec, the emission cursor (`next_end`), the
/// emitted/delivered counters, and the undelivered `pending` rows; the
/// logsignature plan and workspace are transient and rebuilt on reload,
/// like the path's own [`crate::ta::Workspace`].
pub struct RollingWindow<E: Elem> {
    spec: WindowSpec,
    /// Output width per slide: `sig_len` or the basis dimension.
    out_dim: usize,
    /// Absolute index of the right endpoint of the next window to emit
    /// (`len - 1 + emitted * stride`).
    next_end: usize,
    /// Total windows emitted into `pending` over the session's lifetime.
    emitted: u64,
    /// Windows already handed back by [`RollingWindow::poll`].
    delivered: u64,
    /// Undelivered rows, `(emitted - delivered, out_dim)` row-major.
    pending: Vec<E>,
    plan: Option<LogSigPlan>,
    ws: Option<LogSigWorkspace<E>>,
    /// Reusable per-slide emission row (`out_dim` values). Transient like
    /// `plan`/`ws`: excluded from `raw_parts` and from `pending_bytes`, and
    /// fully overwritten before every use, so hoisting it out of the slide
    /// loop changes no emitted bits — it only removes a per-slide
    /// reallocation from the hot path.
    scratch: Vec<E>,
}

impl<E: Elem> RollingWindow<E> {
    /// Fresh window state for a new session (nothing emitted yet).
    pub fn new(path_spec: &SigSpec, spec: WindowSpec) -> anyhow::Result<RollingWindow<E>> {
        RollingWindow::from_raw(path_spec, spec, (spec.len - 1) as u64, 0, 0, Vec::new())
    }

    /// Reassemble from persisted fields (the codec's constructor): checks
    /// the counters' mutual invariants, then rebuilds the transient
    /// plan/workspace. `pending` is adopted verbatim — reload is bitwise.
    pub(crate) fn from_raw(
        path_spec: &SigSpec,
        spec: WindowSpec,
        next_end: u64,
        emitted: u64,
        delivered: u64,
        pending: Vec<E>,
    ) -> anyhow::Result<RollingWindow<E>> {
        spec.validate()?;
        let (plan, ws) = match spec.logsig {
            Some(basis) => (
                Some(LogSigPlan::new(path_spec, basis)?),
                Some(LogSigWorkspace::new(path_spec)),
            ),
            None => (None, None),
        };
        let out_dim = match &plan {
            Some(p) => p.dim(),
            None => path_spec.sig_len(),
        };
        anyhow::ensure!(
            next_end == (spec.len - 1) as u64 + emitted * spec.stride as u64,
            "window cursor {next_end} inconsistent with {emitted} emissions"
        );
        anyhow::ensure!(delivered <= emitted, "delivered {delivered} > emitted {emitted}");
        anyhow::ensure!(
            pending.len() as u64 == (emitted - delivered) * out_dim as u64,
            "pending buffer has {} values, expected {} rows of {out_dim}",
            pending.len(),
            emitted - delivered
        );
        Ok(RollingWindow {
            spec,
            out_dim,
            next_end: next_end as usize,
            emitted,
            delivered,
            pending,
            plan,
            ws,
            scratch: vec![E::ZERO; out_dim],
        })
    }

    /// The persisted fields, by reference: `(spec, next_end, emitted,
    /// delivered, pending)`.
    pub(crate) fn raw_parts(&self) -> (WindowSpec, u64, u64, u64, &[E]) {
        (self.spec, self.next_end as u64, self.emitted, self.delivered, &self.pending)
    }

    pub fn spec(&self) -> WindowSpec {
        self.spec
    }

    /// Values per emitted slide.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Undelivered slides currently buffered.
    pub fn pending_rows(&self) -> usize {
        (self.emitted - self.delivered) as usize
    }

    /// Bytes of buffered undelivered output (counted into the session's
    /// byte budget alongside the path's own storage).
    pub fn pending_bytes(&self) -> usize {
        self.pending.len() * std::mem::size_of::<E>()
    }

    /// Emit every newly-complete window, then apply the retention policy.
    /// O(1) amortised per slide (one ⊠ each) and per fed point (geometric
    /// truncation). Returns the number of slides emitted. Deterministic in
    /// the fed points alone — feed chunking and truncation history never
    /// change the emitted bits.
    pub fn advance(&mut self, path: &mut Path<E>) -> anyhow::Result<usize> {
        let WindowSpec { len, stride, .. } = self.spec;
        let mut emitted_now = 0usize;
        while self.next_end < path.len() {
            let j = self.next_end;
            let i = j + 1 - len;
            match (&self.plan, &mut self.ws) {
                (Some(plan), Some(ws)) => {
                    path.logsig_query_into(i, j, plan, ws, &mut self.scratch)?
                }
                _ => path.query_into(i, j, &mut self.scratch)?,
            }
            self.pending.extend_from_slice(&self.scratch);
            self.emitted += 1;
            emitted_now += 1;
            self.next_end += stride;
        }
        self.retain(path);
        Ok(emitted_now)
    }

    /// Retention: points strictly before the next window's start are dead.
    /// Truncate only once the dead prefix reaches half the retained
    /// storage, so each point is moved O(1) times overall and storage
    /// stays within 2x the live horizon. Shared by the scalar and batched
    /// sweeps — truncation never touches a retained `S_j` / `I_i` row, so
    /// it cannot change emitted bits.
    fn retain(&self, path: &mut Path<E>) {
        let target = (self.next_end + 1).saturating_sub(self.spec.len);
        let dead = target.saturating_sub(path.base());
        if dead > 0 && 2 * dead >= path.stored_len() {
            path.truncate_front(target);
        }
    }

    /// Advance N windowed sessions of the **same path spec** (same `(d,
    /// depth, dtype)` — window geometries may differ per lane) through the
    /// lane-interleaved Chen kernels in one sweep. Returns the total
    /// slides emitted across all lanes.
    ///
    /// Per sweep step, each lane with a still-unemitted window contributes
    /// one slide; lanes are partitioned by [`Path::query_into`]'s case
    /// analysis — adjacent windows (`len == 2`) stage `x_j - x_i` and run
    /// [`exp_batch_in_place`], prefix windows (`i == 0`) are a copy with
    /// no floating-point work, and the general case gathers the stored
    /// `(I_i, S_j)` rows via [`Path::chen_operands`] into
    /// [`mul_batch_into`]. Because lanes emit different slide counts, the
    /// active group shrinks mid-sweep and the packed buffers repack to the
    /// surviving width (the `Path::update_batch` ragged pattern). Each
    /// batched kernel replays the scalar op order per lane and the logsig
    /// epilogue is the shared [`project_sigs_into`] sequence, so every
    /// lane's emissions are **bitwise identical** to running
    /// [`RollingWindow::advance`] per session — the lane-engine contract,
    /// pinned by property tests below.
    pub fn advance_batch(
        paths: &mut [&mut Path<E>],
        windows: &mut [&mut RollingWindow<E>],
    ) -> anyhow::Result<usize> {
        anyhow::ensure!(
            paths.len() == windows.len(),
            "advance_batch: {} paths vs {} windows",
            paths.len(),
            windows.len()
        );
        let lanes = paths.len();
        if lanes == 0 {
            return Ok(0);
        }
        if lanes == 1 {
            return windows[0].advance(&mut *paths[0]);
        }
        let spec = paths[0].spec().clone();
        let sig_len = spec.sig_len();
        let d = spec.d();
        // All-or-nothing validation before any lane mutates: spec
        // uniformity (dtype included — f32/f64 never coalesce) and the
        // slide count each lane will emit this sweep.
        let mut slides = vec![0usize; lanes];
        for l in 0..lanes {
            anyhow::ensure!(
                paths[l].spec() == &spec,
                "advance_batch lane {l}: path spec mismatch (group lanes by (d, depth, dtype))"
            );
            let w = &windows[l];
            if let Some(plan) = &w.plan {
                plan.check_compatible(&spec)?;
            }
            let plen = paths[l].len();
            if plen > w.next_end {
                slides[l] = (plen - 1 - w.next_end) / w.spec.stride + 1;
                let first_i = w.next_end + 1 - w.spec.len;
                anyhow::ensure!(
                    first_i >= paths[l].base(),
                    "advance_batch lane {l}: window start {first_i} below retention watermark {}",
                    paths[l].base()
                );
            }
        }
        let max_steps = slides.iter().copied().max().unwrap_or(0);
        // Packed operand/output buffers plus one workspace per kernel
        // shape, rebuilt only when the surviving group width changes.
        let mut ws_mul: Option<BatchWorkspace<E>> = None;
        let mut ws_exp: Option<BatchWorkspace<E>> = None;
        let mut packed_a: Vec<E> = Vec::new();
        let mut packed_b: Vec<E> = Vec::new();
        let mut packed_out: Vec<E> = Vec::new();
        let mut row = vec![E::ZERO; sig_len];
        // Logsig lanes stage raw signature rows here and project in one
        // per-lane epilogue; plain lanes append to `pending` directly.
        let mut sig_rows: Vec<Vec<E>> = (0..lanes).map(|_| Vec::new()).collect();
        let mut mul_group: Vec<(usize, usize, usize)> = Vec::new();
        let mut exp_group: Vec<(usize, usize, usize)> = Vec::new();
        for step in 0..max_steps {
            mul_group.clear();
            exp_group.clear();
            for l in 0..lanes {
                if slides[l] <= step {
                    continue;
                }
                let w = &windows[l];
                let j = w.next_end + step * w.spec.stride;
                let i = j + 1 - w.spec.len;
                if j == i + 1 {
                    exp_group.push((l, i, j));
                } else if i == 0 {
                    // Prefix window: the stored row verbatim, no FP work.
                    if windows[l].plan.is_some() {
                        sig_rows[l].extend_from_slice(paths[l].sig_row(j));
                    } else {
                        let srow = paths[l].sig_row(j);
                        windows[l].pending.extend_from_slice(srow);
                    }
                } else {
                    mul_group.push((l, i, j));
                }
            }
            let g = mul_group.len();
            if g > 0 {
                if ws_mul.as_ref().map(|w| w.lanes()) != Some(g) {
                    ws_mul = Some(BatchWorkspace::new(&spec, g));
                }
                packed_a.resize(sig_len * g, E::ZERO);
                packed_b.resize(sig_len * g, E::ZERO);
                packed_out.resize(sig_len * g, E::ZERO);
                for (s, &(l, i, j)) in mul_group.iter().enumerate() {
                    let (inv_i, s_j) = paths[l].chen_operands(i, j);
                    for e in 0..sig_len {
                        packed_a[e * g + s] = inv_i[e];
                        packed_b[e * g + s] = s_j[e];
                    }
                }
                mul_batch_into(
                    &spec,
                    &packed_a[..sig_len * g],
                    &packed_b[..sig_len * g],
                    &mut packed_out[..sig_len * g],
                    ws_mul.as_mut().expect("workspace just ensured"),
                );
                for (s, &(l, _, _)) in mul_group.iter().enumerate() {
                    unpack_lane(sig_len, g, &packed_out[..sig_len * g], s, &mut row);
                    if windows[l].plan.is_some() {
                        sig_rows[l].extend_from_slice(&row);
                    } else {
                        windows[l].pending.extend_from_slice(&row);
                    }
                }
            }
            let g = exp_group.len();
            if g > 0 {
                if ws_exp.as_ref().map(|w| w.lanes()) != Some(g) {
                    ws_exp = Some(BatchWorkspace::new(&spec, g));
                }
                packed_out.resize(sig_len * g, E::ZERO);
                for (s, &(l, i, j)) in exp_group.iter().enumerate() {
                    let pi = paths[l].point_row(i);
                    let pj = paths[l].point_row(j);
                    for c in 0..d {
                        packed_out[c * g + s] = pj[c] - pi[c];
                    }
                }
                exp_batch_in_place(
                    &spec,
                    &mut packed_out[..sig_len * g],
                    ws_exp.as_mut().expect("workspace just ensured"),
                );
                for (s, &(l, _, _)) in exp_group.iter().enumerate() {
                    unpack_lane(sig_len, g, &packed_out[..sig_len * g], s, &mut row);
                    if windows[l].plan.is_some() {
                        sig_rows[l].extend_from_slice(&row);
                    } else {
                        windows[l].pending.extend_from_slice(&row);
                    }
                }
            }
        }
        // Per-lane epilogue: project staged logsig rows through the shared
        // op sequence, bump cursors, then apply the scalar retention
        // policy — identical to what `advance` would have done.
        let mut total = 0usize;
        for l in 0..lanes {
            let w = &mut *windows[l];
            if slides[l] > 0 {
                if let Some(plan) = &w.plan {
                    let off = w.pending.len();
                    w.pending.resize(off + slides[l] * w.out_dim, E::ZERO);
                    project_sigs_into(&spec, plan, &sig_rows[l], slides[l], &mut w.pending[off..]);
                }
                w.emitted += slides[l] as u64;
                w.next_end += slides[l] * w.spec.stride;
                total += slides[l];
            }
            w.retain(&mut *paths[l]);
        }
        Ok(total)
    }

    /// Hand back every undelivered slide: `(index of the first returned
    /// slide, rows)` — row `r` is slide `first + r`, covering points
    /// `[(first + r) * stride, (first + r) * stride + len - 1]`. Empty rows
    /// (with `first` = the next future slide) when nothing is pending.
    pub fn poll(&mut self) -> (u64, Vec<E>) {
        let first = self.delivered;
        self.delivered = self.emitted;
        (first, std::mem::take(&mut self.pending))
    }

    /// [`RollingWindow::poll`] with a page cap: hand back at most
    /// `max_slides` undelivered slides (all of them when `max_slides`
    /// covers the backlog — then this is exactly `poll`). Later slides
    /// stay pending, so a slow poller drains a deep backlog in
    /// bounded-size pages; the continuation cursor is simply
    /// `first + rows.len() / out_dim`, and [`RollingWindow::pending_rows`]
    /// afterwards tells whether another page is waiting.
    pub fn poll_limited(&mut self, max_slides: usize) -> (u64, Vec<E>) {
        if max_slides >= self.pending_rows() {
            return self.poll();
        }
        let first = self.delivered;
        let rows: Vec<E> = self.pending.drain(..max_slides * self.out_dim).collect();
        self.delivered += max_slides as u64;
        (first, rows)
    }

    /// Replay a logged poll: drop the rows a pre-crash client already
    /// received, so a warm restart re-delivers exactly the undelivered
    /// suffix instead of double-delivering.
    pub(crate) fn mark_delivered(&mut self, upto: u64) {
        let upto = upto.min(self.emitted);
        if upto <= self.delivered {
            return;
        }
        let drop_rows = (upto - self.delivered) as usize;
        self.pending.drain(..drop_rows * self.out_dim);
        self.delivered = upto;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::property;
    use crate::substrate::rng::Rng;

    fn random_walk<E: Elem>(rng: &mut Rng, stream: usize, d: usize) -> Vec<E> {
        let mut p = vec![E::ZERO; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] =
                    p[(i - 1) * d + c] + E::from_f64(rng.normal_f32() as f64) * E::from_f64(0.3);
            }
        }
        p
    }

    /// Feed `pts` into a windowed path in the given ragged chunks,
    /// advancing + polling after each, and check every emitted slide
    /// bitwise against per-query results on an untruncated control.
    fn check_rolling<E: Elem>(spec: &SigSpec, wspec: WindowSpec, pts: &[E], chunks: &[usize]) {
        let d = spec.d();
        let total: usize = chunks.iter().sum();
        assert_eq!(pts.len(), total * d);
        let control = Path::<E>::new(spec, pts, total).unwrap();
        let first = chunks[0];
        let mut path = Path::<E>::new(spec, &pts[..first * d], first).unwrap();
        let mut win = RollingWindow::<E>::new(spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        let mut slides: Vec<(u64, Vec<E>)> = Vec::new();
        let drain = |w: &mut RollingWindow<E>, out: &mut Vec<(u64, Vec<E>)>| {
            let (mut k, rows) = w.poll();
            for row in rows.chunks(w.out_dim()) {
                out.push((k, row.to_vec()));
                k += 1;
            }
        };
        drain(&mut win, &mut slides);
        let mut fed = first;
        for &c in &chunks[1..] {
            path.update(&pts[fed * d..(fed + c) * d], c).unwrap();
            fed += c;
            win.advance(&mut path).unwrap();
            drain(&mut win, &mut slides);
        }
        // Every complete window emitted exactly once, in order.
        let expect = if total >= wspec.len { (total - wspec.len) / wspec.stride + 1 } else { 0 };
        assert_eq!(slides.len(), expect, "slide count");
        let lplan = wspec.logsig.map(|b| LogSigPlan::new(spec, b).unwrap());
        for (k, row) in &slides {
            let i = *k as usize * wspec.stride;
            let j = i + wspec.len - 1;
            let want = match &lplan {
                Some(plan) => control.logsig_query(i, j, plan).unwrap(),
                None => control.query(i, j).unwrap(),
            };
            assert_eq!(row, &want, "slide {k} [{i}, {j}]");
        }
        // Bounded memory: retained storage stays within 2x the live
        // horizon (plus the last feed chunk, which lands before retention
        // runs).
        let live = wspec.len + wspec.stride + chunks.iter().copied().max().unwrap();
        assert!(
            path.stored_len() <= 2 * live,
            "stored {} points for a live horizon of {live}",
            path.stored_len()
        );
    }

    #[test]
    fn rolling_matches_per_query_bitwise() {
        // The tentpole contract, both precisions: windowed emission over
        // ragged feeds + truncation == per-query on the full history,
        // bit for bit, across specs, strides, window lengths and bases.
        property("rolling == per-query bitwise", 14, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let len = g.usize_in(2, 9);
            let stride = g.usize_in(1, 4);
            let n_chunks = g.usize_in(1, 10);
            let logsig = match g.usize_in(0, 3) {
                0 => None,
                1 => Some(LogSigBasis::Expanded),
                2 => Some(LogSigBasis::Lyndon),
                _ => Some(LogSigBasis::Words),
            };
            let f64_lane = g.usize_in(0, 1) == 1;
            g.label(format!(
                "d={d} n={n} len={len} stride={stride} chunks={n_chunks} logsig={logsig:?} f64={f64_lane}"
            ));
            let spec = SigSpec::new(d, n).unwrap();
            let mut chunks: Vec<usize> = vec![g.usize_in(2, 6)];
            for _ in 1..n_chunks {
                chunks.push(g.usize_in(1, 6)); // ragged on purpose
            }
            let total: usize = chunks.iter().sum();
            let wspec = WindowSpec { len, stride, logsig };
            if f64_lane {
                let pts = random_walk::<f64>(g.rng(), total, d);
                let spec64 = SigSpec::with_dtype(d, n, crate::ta::Precision::F64).unwrap();
                check_rolling(&spec64, wspec, &pts, &chunks);
            } else {
                let pts = random_walk::<f32>(g.rng(), total, d);
                check_rolling(&spec, wspec, &pts, &chunks);
            }
        });
    }

    #[test]
    fn long_stream_memory_is_bounded() {
        // O(window), not O(history): after a long stream in small chunks,
        // retained storage is a small multiple of the window horizon.
        let spec = SigSpec::new(2, 3).unwrap();
        let wspec = WindowSpec { len: 16, stride: 4, logsig: None };
        let mut rng = Rng::new(41);
        let seed: Vec<f32> = random_walk(&mut rng, 2, 2);
        let mut path = Path::<f32>::new(&spec, &seed, 2).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        for _ in 0..500 {
            let chunk: Vec<f32> = rng.normal_vec(3 * 2, 0.3);
            path.update(&chunk, 3).unwrap();
            win.advance(&mut path).unwrap();
            win.poll();
        }
        assert_eq!(path.len(), 2 + 500 * 3);
        let live = wspec.len + wspec.stride + 3;
        assert!(
            path.stored_len() <= 2 * live,
            "stored {} points; live horizon {live}",
            path.stored_len()
        );
    }

    #[test]
    fn poll_and_mark_delivered_agree() {
        let spec = SigSpec::new(2, 3).unwrap();
        let wspec = WindowSpec { len: 4, stride: 2, logsig: None };
        let mut rng = Rng::new(42);
        let pts: Vec<f32> = random_walk(&mut rng, 20, 2);
        let mut path = Path::<f32>::new(&spec, &pts, 20).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        assert_eq!(win.pending_rows(), 9); // ends 3,5,..,19
        // Replaying a poll of the first 4 slides leaves slides 4.. pending.
        win.mark_delivered(4);
        assert_eq!(win.pending_rows(), 5);
        let (first, rows) = win.poll();
        assert_eq!(first, 4);
        assert_eq!(rows.len(), 5 * win.out_dim());
        // Idempotent / stale marks are no-ops; empty poll reports the next
        // future slide.
        win.mark_delivered(3);
        assert_eq!(win.pending_rows(), 0);
        let (first, rows) = win.poll();
        assert_eq!((first, rows.len()), (9, 0));
    }

    #[test]
    fn raw_roundtrip_resumes_bitwise() {
        // from_raw(raw_parts()) mid-stream must continue exactly like the
        // original — the codec-level durability contract in miniature.
        let spec = SigSpec::new(2, 4).unwrap();
        let wspec = WindowSpec { len: 6, stride: 3, logsig: Some(LogSigBasis::Words) };
        let mut rng = Rng::new(43);
        let pts: Vec<f32> = random_walk(&mut rng, 40, 2);
        let mut path = Path::<f32>::new(&spec, &pts[..14 * 2], 14).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        win.mark_delivered(1); // partially delivered on purpose
        let (s, ne, em, de, pending) = win.raw_parts();
        let mut revived =
            RollingWindow::<f32>::from_raw(&spec, s, ne, em, de, pending.to_vec()).unwrap();
        let mut control_path = Path::<f32>::new(&spec, &pts[..14 * 2], 14).unwrap();
        control_path.truncate_front(path.base());
        path.update(&pts[14 * 2..], 26).unwrap();
        control_path.update(&pts[14 * 2..], 26).unwrap();
        win.advance(&mut path).unwrap();
        revived.advance(&mut control_path).unwrap();
        assert_eq!(win.poll(), revived.poll());
    }

    /// Drive `lanes` same-path-spec sessions (heterogeneous window
    /// geometry) through ragged feed rounds: one group advances through
    /// `advance_batch`, a per-lane scalar control through `advance`. After
    /// every round the durable window state (cursor, counters, pending
    /// bits) and the retention outcome (base, stored points) must match
    /// exactly — the batched sweep is observationally the scalar loop.
    fn check_advance_batch<E: Elem>(spec: &SigSpec, wspecs: &[WindowSpec], feeds: &[Vec<Vec<E>>]) {
        let lanes = wspecs.len();
        let d = spec.d();
        let mut paths: Vec<Path<E>> = Vec::new();
        let mut wins: Vec<RollingWindow<E>> = Vec::new();
        let mut cpaths: Vec<Path<E>> = Vec::new();
        let mut cwins: Vec<RollingWindow<E>> = Vec::new();
        for l in 0..lanes {
            let seed = &feeds[0][l];
            let rows = seed.len() / d;
            paths.push(Path::new(spec, seed, rows).unwrap());
            cpaths.push(Path::new(spec, seed, rows).unwrap());
            wins.push(RollingWindow::new(spec, wspecs[l]).unwrap());
            cwins.push(RollingWindow::new(spec, wspecs[l]).unwrap());
        }
        for round in 0..feeds.len() {
            if round > 0 {
                for l in 0..lanes {
                    let chunk = &feeds[round][l];
                    if !chunk.is_empty() {
                        paths[l].update(chunk, chunk.len() / d).unwrap();
                        cpaths[l].update(chunk, chunk.len() / d).unwrap();
                    }
                }
            }
            let batched = {
                let mut pr: Vec<&mut Path<E>> = paths.iter_mut().collect();
                let mut wr: Vec<&mut RollingWindow<E>> = wins.iter_mut().collect();
                RollingWindow::advance_batch(&mut pr, &mut wr).unwrap()
            };
            let mut scalar = 0usize;
            for l in 0..lanes {
                scalar += cwins[l].advance(&mut cpaths[l]).unwrap();
            }
            assert_eq!(batched, scalar, "round {round}: total slides");
            for l in 0..lanes {
                let (_, ne, em, de, pend) = wins[l].raw_parts();
                let (_, cne, cem, cde, cpend) = cwins[l].raw_parts();
                assert_eq!((ne, em, de), (cne, cem, cde), "round {round} lane {l}: counters");
                assert_eq!(pend, cpend, "round {round} lane {l}: pending bits");
                assert_eq!(
                    (paths[l].base(), paths[l].stored_len()),
                    (cpaths[l].base(), cpaths[l].stored_len()),
                    "round {round} lane {l}: retention"
                );
            }
            // Poll some rounds so delivered/pending offsets vary mid-run.
            if round % 2 == 1 {
                for l in 0..lanes {
                    assert_eq!(wins[l].poll(), cwins[l].poll(), "round {round} lane {l}: poll");
                }
            }
        }
    }

    #[test]
    fn advance_batch_matches_scalar_bitwise() {
        // The tentpole contract: specs x strides x bases x {f32, f64} x
        // ragged feed groups x mid-sweep repack boundaries (lanes emit
        // different slide counts, so the active group shrinks mid-sweep).
        property("advance_batch == per-lane advance bitwise", 12, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let lanes = g.usize_in(1, 6); // 1 covers the scalar delegation
            let rounds = g.usize_in(2, 6);
            let f64_lane = g.usize_in(0, 1) == 1;
            g.label(format!("d={d} n={n} lanes={lanes} rounds={rounds} f64={f64_lane}"));
            let wspecs: Vec<WindowSpec> = (0..lanes)
                .map(|_| WindowSpec {
                    len: g.usize_in(2, 7), // len == 2 exercises the exp case
                    stride: g.usize_in(1, 3),
                    logsig: match g.usize_in(0, 3) {
                        0 => None,
                        1 => Some(LogSigBasis::Expanded),
                        2 => Some(LogSigBasis::Lyndon),
                        _ => Some(LogSigBasis::Words),
                    },
                })
                .collect();
            // Ragged per-lane chunk plan: a seed then rounds of 0..=4
            // points (0 = lane idles that round, so slide counts diverge).
            let mut chunk_plan: Vec<Vec<usize>> = vec![Vec::new(); rounds];
            let mut totals = vec![0usize; lanes];
            for l in 0..lanes {
                for r in 0..rounds {
                    let c = if r == 0 { g.usize_in(2, 5) } else { g.usize_in(0, 4) };
                    chunk_plan[r].push(c);
                    totals[l] += c;
                }
            }
            macro_rules! run {
                ($e:ty, $spec:expr) => {{
                    let streams: Vec<Vec<$e>> =
                        (0..lanes).map(|l| random_walk::<$e>(g.rng(), totals[l], d)).collect();
                    let mut fed = vec![0usize; lanes];
                    let feeds: Vec<Vec<Vec<$e>>> = chunk_plan
                        .iter()
                        .map(|row| {
                            (0..lanes)
                                .map(|l| {
                                    let c = row[l];
                                    let s = streams[l][fed[l] * d..(fed[l] + c) * d].to_vec();
                                    fed[l] += c;
                                    s
                                })
                                .collect()
                        })
                        .collect();
                    check_advance_batch::<$e>(&$spec, &wspecs, &feeds);
                }};
            }
            if f64_lane {
                let spec = SigSpec::with_dtype(d, n, crate::ta::Precision::F64).unwrap();
                run!(f64, spec);
            } else {
                let spec = SigSpec::new(d, n).unwrap();
                run!(f32, spec);
            }
        });
    }

    #[test]
    fn advance_batch_rejects_malformed_groups() {
        let spec2 = SigSpec::new(2, 3).unwrap();
        let spec3 = SigSpec::new(3, 3).unwrap();
        let wspec = WindowSpec { len: 4, stride: 2, logsig: None };
        let mut rng = Rng::new(44);
        let p2: Vec<f32> = random_walk(&mut rng, 8, 2);
        let p3: Vec<f32> = random_walk(&mut rng, 8, 3);
        let mut a = Path::<f32>::new(&spec2, &p2, 8).unwrap();
        let mut b = Path::<f32>::new(&spec3, &p3, 8).unwrap();
        let mut wa = RollingWindow::<f32>::new(&spec2, wspec).unwrap();
        let mut wb = RollingWindow::<f32>::new(&spec3, wspec).unwrap();
        // Arity mismatch.
        assert!(RollingWindow::advance_batch(&mut [&mut a], &mut []).is_err());
        // Mixed path specs never coalesce.
        assert!(
            RollingWindow::advance_batch(&mut [&mut a, &mut b], &mut [&mut wa, &mut wb]).is_err()
        );
        // Empty group is a no-op.
        assert_eq!(RollingWindow::<f32>::advance_batch(&mut [], &mut []).unwrap(), 0);
    }

    #[test]
    fn poll_limited_pages_cover_poll() {
        let spec = SigSpec::new(2, 3).unwrap();
        let wspec = WindowSpec { len: 4, stride: 2, logsig: None };
        let mut rng = Rng::new(45);
        let pts: Vec<f32> = random_walk(&mut rng, 20, 2);
        let mut path = Path::<f32>::new(&spec, &pts, 20).unwrap();
        let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        let mut cpath = Path::<f32>::new(&spec, &pts, 20).unwrap();
        let mut cwin = RollingWindow::<f32>::new(&spec, wspec).unwrap();
        win.advance(&mut path).unwrap();
        cwin.advance(&mut cpath).unwrap();
        assert_eq!(win.pending_rows(), 9);
        // Pages of 4 + 0 + 4 + 100 reassemble the one-shot poll exactly.
        let (f0, r0) = win.poll_limited(4);
        assert_eq!((f0, r0.len()), (0, 4 * win.out_dim()));
        let (f1, r1) = win.poll_limited(0); // zero-size page is a no-op
        assert_eq!((f1, r1.len()), (4, 0));
        assert_eq!(win.pending_rows(), 5);
        let (f2, r2) = win.poll_limited(4);
        assert_eq!(f2, 4);
        let (f3, r3) = win.poll_limited(100); // cap above backlog == poll
        assert_eq!(f3, 8);
        let (cf, crows) = cwin.poll();
        assert_eq!(cf, 0);
        let paged: Vec<f32> = [r0, r1, r2, r3].concat();
        assert_eq!(paged, crows);
        // Draining by pages is replay-compatible with mark_delivered.
        assert_eq!(win.pending_rows(), 0);
        assert_eq!(win.poll(), (9, Vec::new()));
    }

    #[test]
    fn invalid_specs_are_errors() {
        let spec = SigSpec::new(2, 3).unwrap();
        assert!(RollingWindow::<f32>::new(&spec, WindowSpec { len: 1, stride: 1, logsig: None })
            .is_err());
        assert!(RollingWindow::<f32>::new(&spec, WindowSpec { len: 4, stride: 0, logsig: None })
            .is_err());
        // Inconsistent persisted counters are clean decode errors.
        assert!(RollingWindow::<f32>::from_raw(
            &spec,
            WindowSpec { len: 4, stride: 2, logsig: None },
            3,
            1, // says one emission, but cursor still at the first window
            0,
            vec![0.0; spec.sig_len()],
        )
        .is_err());
        assert!(RollingWindow::<f32>::from_raw(
            &spec,
            WindowSpec { len: 4, stride: 2, logsig: None },
            5,
            1,
            2, // delivered > emitted
            Vec::new(),
        )
        .is_err());
    }
}
