//! The `Path` class (§4.2, §5.5 "arbitrary intervals"): O(L) precomputation
//! and storage giving **O(1)-in-L signature queries over arbitrary
//! intervals**, improving on the O(log L) / O(L log L) of Chafai & Lyons
//! (2005).
//!
//! Precomputes, via one fused-multiply-exponentiate sweep each,
//!
//! - `S_j   = Sig(x_0 .. x_j)`        (expanding signatures, eq. 6)
//! - `I_j   = InvertSig(x_0 .. x_j) = S_j^{-1}` — maintained incrementally
//!   as `I_j = exp(-z_j) ⊠ I_{j-1}` (one *left* fused op per step, never a
//!   generic group inversion).
//!
//! Then `Sig(x_i .. x_j) = I_i ⊠ S_j` — a single ⊠ at query time.
//!
//! As the paper cautions, `I_i ⊠ S_j` cancels large terms for distant
//! `i, j`; [`Path::query`] is exact in exact arithmetic but can lose
//! relative precision for extreme inputs. [`Path::query_recompute`] is the
//! slow exact fallback used by tests and benchmarks.

pub mod window;

pub use window::{RollingWindow, WindowSpec};

use crate::logsignature::{logsignature_from_sig, LogSigPlan, LogSigWorkspace};
use crate::signature::forward::{signature_with, two_point_signature_into};
use crate::signature::SigConfig;
use crate::ta::batch::{fused_mexp_batch, fused_mexp_left_batch, unpack_lane, BatchWorkspace};
use crate::ta::fused::{fused_mexp, fused_mexp_left};
use crate::ta::mul::mul_into;
use crate::ta::{Elem, SigSpec, Workspace};

/// Precomputed path with O(1) interval signature queries and streaming
/// updates (Signatory's `Path` class).
///
/// Generic over the sealed element precision [`Elem`] (`f32` default, so
/// bare `Path` call sites are unchanged); the f64 instantiation runs the
/// same fused sweeps in double precision. The precomputed buffers —
/// `points`, expanding signatures, inverted signatures — *are* the state:
/// [`Path::serialize_into`] / [`Path::deserialize`] (in [`crate::state`])
/// round-trip them bitwise, and the transient [`Workspace`] is rebuilt on
/// load.
pub struct Path<E: Elem = f32> {
    spec: SigSpec,
    /// Retention watermark: number of leading points dropped from the
    /// front by [`Path::truncate_front`]. Indices handed to the query
    /// surface stay **absolute** (counted from the original x_0) — the
    /// stored buffers are merely a suffix view. 0 for an untruncated path,
    /// which keeps every pre-watermark layout bit-identical.
    base: usize,
    /// Retained points, `(stored, d)` row-major; absolute point `p` lives
    /// at row `p - base`.
    points: Vec<E>,
    /// Expanding signatures `Sig(x_0..x_j)` for prefix-ends
    /// `j in [max(base, 1), len)`, each `sig_len` long; absolute `j` lives
    /// at row `j - max(base, 1)` (which is the classic `j - 1` when
    /// `base == 0`). Truncation only drops rows — the retained values are
    /// still prefixes from x_0, so `I_i ⊠ S_j` stays bitwise what it was.
    sigs: Vec<E>,
    /// `Sig(x_0..x_j)^{-1}`, same layout as `sigs`.
    inv_sigs: Vec<E>,
    ws: Workspace<E>,
}

impl<E: Elem> Path<E> {
    /// Build from a `(stream, d)` buffer with `stream >= 2`. O(L) work.
    pub fn new(spec: &SigSpec, points: &[E], stream: usize) -> anyhow::Result<Path<E>> {
        anyhow::ensure!(stream >= 2, "need at least two points");
        anyhow::ensure!(points.len() == stream * spec.d(), "bad point buffer length");
        let mut path = Path {
            spec: spec.clone(),
            base: 0,
            points: Vec::with_capacity(points.len()),
            sigs: Vec::new(),
            inv_sigs: Vec::new(),
            ws: Workspace::new(spec),
        };
        path.extend_points(points, stream);
        Ok(path)
    }

    /// Reassemble a `Path` from its serialized buffers (the codec's
    /// constructor): validates the mutual shape invariants, then rebuilds
    /// the transient workspace. The buffers are adopted verbatim, which is
    /// what makes a reload bitwise — no recomputation happens here.
    pub(crate) fn from_raw_parts(
        spec: SigSpec,
        base: usize,
        points: Vec<E>,
        sigs: Vec<E>,
        inv_sigs: Vec<E>,
    ) -> anyhow::Result<Path<E>> {
        let d = spec.d();
        let len = spec.sig_len();
        anyhow::ensure!(d > 0 && points.len() % d == 0, "bad point buffer length");
        let stored = points.len() / d;
        anyhow::ensure!(stored >= 2, "need at least two points");
        // Prefix-ends j in [max(base, 1), base + stored): `stored` rows
        // when truncated, the classic `stored - 1` when base == 0.
        let sig_rows = stored - usize::from(base == 0);
        anyhow::ensure!(
            sigs.len() == sig_rows * len && inv_sigs.len() == sigs.len(),
            "signature buffers ({} / {}) do not match {} points (base {base}) of sig_len {len}",
            sigs.len(),
            inv_sigs.len(),
            stored
        );
        let ws = Workspace::new(&spec);
        Ok(Path { spec, base, points, sigs, inv_sigs, ws })
    }

    /// The persistent state, by reference: `(spec, base, points, sigs,
    /// inv_sigs)` — everything [`Path::from_raw_parts`] needs back.
    pub(crate) fn raw_parts(&self) -> (&SigSpec, usize, &[E], &[E], &[E]) {
        (&self.spec, self.base, &self.points, &self.sigs, &self.inv_sigs)
    }

    /// Row offset of absolute prefix-end `j` in `sigs` / `inv_sigs`.
    /// Callers guarantee `j >= max(base, 1)`.
    fn sig_off(&self, j: usize) -> usize {
        j - self.base.max(1)
    }

    fn extend_points(&mut self, new_points: &[E], count: usize) {
        let d = self.spec.d();
        let len = self.spec.sig_len();
        let had = self.len();
        // Pre-reserve the whole extension: one `reserve` per buffer
        // instead of per-step `extend_from_slice` growth churn.
        let start = had.max(1);
        let grown = had + count - start;
        self.points.reserve(count * d);
        self.sigs.reserve(grown * len);
        self.inv_sigs.reserve(grown * len);
        self.points.extend_from_slice(&new_points[..count * d]);
        let total = self.len();
        // Running state: the last expanding signature / inverted signature.
        // A truncated path always retains >= 2 points, so `sigs` is
        // non-empty exactly when a prior sweep already ran.
        let mut cur = if !self.sigs.is_empty() {
            self.sigs[self.sigs.len() - len..].to_vec()
        } else {
            self.spec.zeros_elem::<E>()
        };
        let mut cur_inv = if !self.inv_sigs.is_empty() {
            self.inv_sigs[self.inv_sigs.len() - len..].to_vec()
        } else {
            self.spec.zeros_elem::<E>()
        };
        let mut z = vec![E::ZERO; d];
        let mut neg_z = vec![E::ZERO; d];
        let base = self.base;
        for j in start..total {
            for c in 0..d {
                z[c] = self.points[(j - base) * d + c] - self.points[(j - 1 - base) * d + c];
                neg_z[c] = -z[c];
            }
            // S_j = S_{j-1} ⊠ exp(z_j)   (eq. 6, fused).
            fused_mexp(&self.spec, &mut cur, &z, &mut self.ws);
            // I_j = exp(-z_j) ⊠ I_{j-1}  (mirrored fused op).
            fused_mexp_left(&self.spec, &mut cur_inv, &neg_z, &mut self.ws);
            self.sigs.extend_from_slice(&cur);
            self.inv_sigs.extend_from_slice(&cur_inv);
        }
    }

    /// Drop retained state strictly before absolute point `new_base` — the
    /// bounded-memory half of rolling-window serving. Keeps at least two
    /// stored points (the running-state seed and the `prev` row the next
    /// update differences against), so `new_base` is clamped to
    /// `len() - 2`. Queries with `i >= new_base` are untouched — the
    /// retained `S_j` / `I_i` rows are still exact prefixes from x_0, so
    /// post-truncation results are **bitwise** what they were; queries
    /// reaching below the watermark become clean errors.
    pub fn truncate_front(&mut self, new_base: usize) {
        let new_base = new_base.min(self.len().saturating_sub(2));
        if new_base <= self.base {
            return;
        }
        let d = self.spec.d();
        let len = self.spec.sig_len();
        let drop_rows = new_base.max(1) - self.base.max(1);
        self.points.drain(..(new_base - self.base) * d);
        self.sigs.drain(..drop_rows * len);
        self.inv_sigs.drain(..drop_rows * len);
        self.base = new_base;
    }

    /// Append new points ("keeping the signature up-to-date", §5.5;
    /// Signatory's `Path.update`). O(new points) work.
    pub fn update(&mut self, new_points: &[E], count: usize) -> anyhow::Result<()> {
        anyhow::ensure!(count >= 1, "no points to add");
        anyhow::ensure!(new_points.len() == count * self.spec.d(), "bad buffer length");
        self.extend_points(new_points, count);
        Ok(())
    }

    /// Number of points fed so far, **including** any truncated away by
    /// [`Path::truncate_front`] — indices stay absolute for the path's
    /// whole lifetime, so clients never observe the retention policy.
    pub fn len(&self) -> usize {
        self.base + self.points.len() / self.spec.d()
    }

    /// The retention watermark: queries require `i >= base()`
    /// (`base() == 0` until [`Path::truncate_front`] is used).
    pub fn base(&self) -> usize {
        self.base
    }

    /// Number of points physically retained (`len() - base()`).
    pub fn stored_len(&self) -> usize {
        self.points.len() / self.spec.d()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn spec(&self) -> &SigSpec {
        &self.spec
    }

    /// `Sig(x_i .. x_j)` (0-based, inclusive endpoints, `i < j`).
    /// **O(1) in the path length**: one ⊠ (or a copy when `i == 0`).
    pub fn query(&self, i: usize, j: usize) -> anyhow::Result<Vec<E>> {
        let mut out = vec![E::ZERO; self.spec.sig_len()];
        self.query_into(i, j, &mut out)?;
        Ok(out)
    }

    /// [`Path::query`] into a caller-owned buffer of `sig_len` values —
    /// the allocation-free variant the serving hot path uses (one scratch
    /// buffer per response instead of fresh `Vec`s per step).
    ///
    /// Adjacent intervals (`j == i + 1`) skip the `I_i ⊠ S_j` product
    /// entirely: the signature of a two-point path is `exp` of the
    /// increment (§2.2), which is both cheaper than a full ⊠ and immune to
    /// the distant-interval cancellation the paper cautions about.
    pub fn query_into(&self, i: usize, j: usize, out: &mut [E]) -> anyhow::Result<()> {
        anyhow::ensure!(i < j && j < self.len(), "invalid interval [{i}, {j}] of {}", self.len());
        anyhow::ensure!(
            i >= self.base,
            "interval start {i} is below the retention watermark {}",
            self.base
        );
        let len = self.spec.sig_len();
        anyhow::ensure!(
            out.len() == len,
            "output buffer has {} values, expected sig_len {len}",
            out.len()
        );
        let d = self.spec.d();
        let b = self.base;
        if j == i + 1 {
            return two_point_signature_into(
                &self.points[(i - b) * d..(i - b + 1) * d],
                &self.points[(j - b) * d..(j - b + 1) * d],
                &self.spec,
                out,
            );
        }
        let s_j = &self.sigs[self.sig_off(j) * len..(self.sig_off(j) + 1) * len];
        if i == 0 {
            out.copy_from_slice(s_j);
            return Ok(());
        }
        let inv_i = &self.inv_sigs[self.sig_off(i) * len..(self.sig_off(i) + 1) * len];
        mul_into(&self.spec, inv_i, s_j, out);
        Ok(())
    }

    /// `LogSig(x_i .. x_j)` in the plan's basis: the O(1) query followed by
    /// a log (§4.2). Errors if `plan` was built for a different `SigSpec`.
    pub fn logsig_query(&self, i: usize, j: usize, plan: &LogSigPlan) -> anyhow::Result<Vec<E>> {
        let sig = self.query(i, j)?;
        logsignature_from_sig(&sig, &self.spec, plan)
    }

    /// [`Path::logsig_query`] into a caller buffer of `plan.dim()` values,
    /// threading a reusable [`LogSigWorkspace`] — **allocation-free** (the
    /// mirror of [`Path::query_into`] for the logsignature surface). The
    /// interval signature is staged in the workspace via
    /// [`Path::query_into`], so adjacent intervals (`j == i + 1`) ride the
    /// exp-of-increment fast path — cheaper than the `I_i ⊠ S_j` product
    /// and immune to distant-interval cancellation — before the log +
    /// projection epilogue runs in place. Bitwise identical to
    /// [`Path::logsig_query`].
    pub fn logsig_query_into(
        &self,
        i: usize,
        j: usize,
        plan: &LogSigPlan,
        ws: &mut LogSigWorkspace<E>,
        out: &mut [E],
    ) -> anyhow::Result<()> {
        plan.check_compatible(&self.spec)?;
        ws.check_spec(&self.spec)?;
        anyhow::ensure!(
            out.len() == plan.dim(),
            "output buffer has {} values, expected basis dimension {}",
            out.len(),
            plan.dim()
        );
        self.query_into(i, j, ws.sig_mut())?;
        ws.project_sig_into(&self.spec, plan, out);
        Ok(())
    }

    /// Operand rows for one stored-inverse Chen combination
    /// `Sig(x_i..x_j) = I_i ⊠ S_j` (§5.5): `(I_i, S_j)` by reference — the
    /// gather the batched window sweep packs into its lane-interleaved
    /// buffers. Callers guarantee `base() <= i`, `0 < i`, `i + 1 < j` and
    /// `j < len()` (the general [`Path::query_into`] case).
    pub(crate) fn chen_operands(&self, i: usize, j: usize) -> (&[E], &[E]) {
        let len = self.spec.sig_len();
        let (oi, oj) = (self.sig_off(i), self.sig_off(j));
        (&self.inv_sigs[oi * len..(oi + 1) * len], &self.sigs[oj * len..(oj + 1) * len])
    }

    /// The stored expanding-signature row `S_j = Sig(x_0..x_j)` — the
    /// `i == 0` window-slide case, a plain copy with no floating-point ops.
    /// Callers guarantee `max(base(), 1) <= j < len()`.
    pub(crate) fn sig_row(&self, j: usize) -> &[E] {
        let len = self.spec.sig_len();
        let o = self.sig_off(j);
        &self.sigs[o * len..(o + 1) * len]
    }

    /// The retained point row at absolute index `p` (`base() <= p < len()`)
    /// — the adjacent-interval slide stages `x_{i+1} - x_i` from these.
    pub(crate) fn point_row(&self, p: usize) -> &[E] {
        let d = self.spec.d();
        let r = p - self.base;
        &self.points[r * d..(r + 1) * d]
    }

    /// The signature of the whole path so far.
    pub fn signature(&self) -> Vec<E> {
        let len = self.spec.sig_len();
        self.sigs[self.sigs.len() - len..].to_vec()
    }

    /// [`Path::signature`] into a caller-owned buffer of `sig_len` values,
    /// for callers that poll the running signature into a reused buffer.
    pub fn signature_into(&self, out: &mut [E]) -> anyhow::Result<()> {
        let len = self.spec.sig_len();
        anyhow::ensure!(
            out.len() == len,
            "output buffer has {} values, expected sig_len {len}",
            out.len()
        );
        out.copy_from_slice(&self.sigs[self.sigs.len() - len..]);
        Ok(())
    }

    /// The retained expanding-signature stream — Signatory's
    /// `signature(..., stream=True)` view of the Path (`(len-1, sig_len)`
    /// on an untruncated path; after [`Path::truncate_front`], the rows for
    /// prefix-ends `j >= max(base, 1)`).
    pub fn stream(&self) -> &[E] {
        &self.sigs
    }

    /// Slow-path oracle: recompute `Sig(x_i..x_j)` directly from the points
    /// (O(j - i) work). Used by tests and the §4.2 benchmark baseline.
    pub fn query_recompute(&self, i: usize, j: usize) -> anyhow::Result<Vec<E>> {
        anyhow::ensure!(i < j && j < self.len(), "invalid interval");
        anyhow::ensure!(
            i >= self.base,
            "interval start {i} is below the retention watermark {}",
            self.base
        );
        let d = self.spec.d();
        let b = self.base;
        signature_with(
            &self.points[(i - b) * d..(j + 1 - b) * d],
            j - i + 1,
            &self.spec,
            &SigConfig::serial(),
        )
    }

    /// Bytes of precomputed storage (the O(L) cost the paper trades for
    /// O(1) queries); used by the memory benchmark and the session-table
    /// byte budget. This is exactly what the state codec persists, so it
    /// also sizes spill files.
    pub fn storage_bytes(&self) -> usize {
        (self.sigs.len() + self.inv_sigs.len() + self.points.len()) * std::mem::size_of::<E>()
    }

    /// Advance several **same-spec** paths together through one
    /// lane-fused sweep — [`Path::update`] batched across paths, the
    /// stateful analogue of [`crate::signature::signature_batch`].
    ///
    /// Lane `k` appends `counts[k]` points from `new_points[k]`; counts
    /// may be ragged (each step repacks the still-active lanes, which
    /// changes only which lanes share a sweep, never any lane's op
    /// sequence). Both per-step fused ops — `S_j = S_{j-1} ⊠ exp(z_j)`
    /// and `I_j = exp(-z_j) ⊠ I_{j-1}` — run through the lane-interleaved
    /// kernels of [`crate::ta::batch`], which perform each lane's
    /// operations in the scalar order, so every path ends up **bitwise
    /// identical** to a scalar [`Path::update`] with the same points
    /// (pinned by property tests, and relied on by the serving feed lane:
    /// coalescing feeds must not change any session's bits).
    ///
    /// Validation is all-or-nothing: on `Err`, no path has been modified.
    pub fn update_batch(
        paths: &mut [&mut Path<E>],
        new_points: &[&[E]],
        counts: &[usize],
    ) -> anyhow::Result<()> {
        let lanes = paths.len();
        anyhow::ensure!(
            new_points.len() == lanes && counts.len() == lanes,
            "update_batch arity mismatch: {} paths, {} buffers, {} counts",
            lanes,
            new_points.len(),
            counts.len()
        );
        if lanes == 0 {
            return Ok(());
        }
        let spec = paths[0].spec.clone();
        let d = spec.d();
        for (k, p) in paths.iter().enumerate() {
            anyhow::ensure!(
                p.spec == spec,
                "update_batch lane {k} has spec (d={}, depth={}), expected (d={}, depth={})",
                p.spec.d(),
                p.spec.depth(),
                d,
                spec.depth()
            );
            anyhow::ensure!(counts[k] >= 1, "no points to add for lane {k}");
            anyhow::ensure!(
                new_points[k].len() == counts[k] * d,
                "lane {k} buffer has {} values, expected count({}) * channels({d})",
                new_points[k].len(),
                counts[k]
            );
        }
        if lanes == 1 {
            return paths[0].update(new_points[0], counts[0]);
        }
        let len = spec.sig_len();
        // Lane-interleaved running states, seeded from each path's stored
        // tail — exactly what a scalar update resumes from.
        let mut active: Vec<usize> = (0..lanes).collect();
        let mut sig_state = vec![E::ZERO; len * lanes];
        let mut inv_state = vec![E::ZERO; len * lanes];
        for (a, &l) in active.iter().enumerate() {
            let p = &paths[l];
            for i in 0..len {
                sig_state[i * lanes + a] = p.sigs[p.sigs.len() - len + i];
                inv_state[i * lanes + a] = p.inv_sigs[p.inv_sigs.len() - len + i];
            }
        }
        let mut ws = BatchWorkspace::new(&spec, lanes);
        let mut z = vec![E::ZERO; d * lanes];
        let mut neg_z = vec![E::ZERO; d * lanes];
        let mut row = vec![E::ZERO; len];
        let mut step = 0usize;
        while !active.is_empty() {
            // Retire lanes whose feed is exhausted, compacting the
            // interleaved states to the survivors.
            let still: Vec<usize> = active.iter().copied().filter(|&l| counts[l] > step).collect();
            if still.len() != active.len() {
                if still.is_empty() {
                    break;
                }
                let old_n = active.len();
                let new_n = still.len();
                let mut packed_sig = vec![E::ZERO; len * new_n];
                let mut packed_inv = vec![E::ZERO; len * new_n];
                for (na, &l) in still.iter().enumerate() {
                    let oa = active.iter().position(|&x| x == l).expect("survivor");
                    for i in 0..len {
                        packed_sig[i * new_n + na] = sig_state[i * old_n + oa];
                        packed_inv[i * new_n + na] = inv_state[i * old_n + oa];
                    }
                }
                sig_state = packed_sig;
                inv_state = packed_inv;
                active = still;
                ws = BatchWorkspace::new(&spec, new_n);
            }
            let a_n = active.len();
            for (a, &l) in active.iter().enumerate() {
                let p = &paths[l];
                // The previous point is always the last one stored: the
                // old tail for the first step, last appended after that.
                let prev = &p.points[p.points.len() - d..];
                let cur = &new_points[l][step * d..(step + 1) * d];
                for c in 0..d {
                    let zc = cur[c] - prev[c];
                    z[c * a_n + a] = zc;
                    neg_z[c * a_n + a] = -zc;
                }
            }
            // S_j = S_{j-1} ⊠ exp(z_j); I_j = exp(-z_j) ⊠ I_{j-1} — the
            // scalar update's two fused ops, lane-interleaved.
            fused_mexp_batch(&spec, &mut sig_state[..len * a_n], &z[..d * a_n], &mut ws);
            fused_mexp_left_batch(&spec, &mut inv_state[..len * a_n], &neg_z[..d * a_n], &mut ws);
            for (a, &l) in active.iter().enumerate() {
                unpack_lane(len, a_n, &sig_state[..len * a_n], a, &mut row);
                paths[l].sigs.extend_from_slice(&row);
                unpack_lane(len, a_n, &inv_state[..len * a_n], a, &mut row);
                paths[l].inv_sigs.extend_from_slice(&row);
                paths[l].points.extend_from_slice(&new_points[l][step * d..(step + 1) * d]);
            }
            step += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(deprecated)] // scalar logsignature() stays the oracle until removed
mod tests {
    use super::*;
    use crate::logsignature::{logsignature, LogSigBasis};
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn queries_match_direct_recomputation() {
        property("path query == recompute", 12, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(4, 24);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path(g.rng(), stream, d);
            let path = Path::new(&spec, &pts, stream).unwrap();
            for _ in 0..6 {
                let i = g.usize_in(0, stream - 2);
                let j = g.usize_in(i + 1, stream - 1);
                let fast = path.query(i, j).unwrap();
                let slow = path.query_recompute(i, j).unwrap();
                assert_close(&fast, &slow, 5e-3, 5e-4);
            }
        });
    }

    #[test]
    fn full_interval_query_is_whole_signature() {
        let spec = SigSpec::new(2, 4).unwrap();
        let mut rng = Rng::new(1);
        let pts = random_path(&mut rng, 12, 2);
        let path = Path::new(&spec, &pts, 12).unwrap();
        let q = path.query(0, 11).unwrap();
        assert_close(&q, &signature(&pts, 12, &spec), 1e-6, 1e-7);
        assert_close(&path.signature(), &q, 1e-6, 1e-7);
    }

    #[test]
    fn adjacent_point_query_is_exponential() {
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(2);
        let pts = random_path(&mut rng, 8, 3);
        let path = Path::new(&spec, &pts, 8).unwrap();
        for i in 0..7 {
            let q = path.query(i, i + 1).unwrap();
            let direct =
                crate::signature::forward::two_point_signature(&pts[i * 3..(i + 1) * 3], &pts[(i + 1) * 3..(i + 2) * 3], &spec);
            assert_close(&q, &direct, 2e-3, 2e-4);
        }
    }

    #[test]
    fn update_matches_fresh_construction() {
        property("update == rebuild", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let first = g.usize_in(2, 10);
            let extra = g.usize_in(1, 8);
            g.label(format!("d={d} n={n} first={first} extra={extra}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path(g.rng(), first + extra, d);
            let mut incremental = Path::new(&spec, &pts[..first * d], first).unwrap();
            incremental.update(&pts[first * d..], extra).unwrap();
            let fresh = Path::new(&spec, &pts, first + extra).unwrap();
            assert_eq!(incremental.len(), fresh.len());
            assert_close(&incremental.signature(), &fresh.signature(), 2e-3, 1e-4);
            let i = g.usize_in(0, first + extra - 2);
            let j = g.usize_in(i + 1, first + extra - 1);
            assert_close(
                &incremental.query(i, j).unwrap(),
                &fresh.query(i, j).unwrap(),
                2e-3,
                1e-4,
            );
        });
    }

    #[test]
    fn update_matches_fresh_bit_for_bit() {
        // Resumption is *exact*: extend_points continues from the stored
        // running state, so an incrementally-extended Path must reproduce
        // the same sequence of fused ops — and therefore identical bits —
        // on both `sigs` and `inv_sigs`, even across several updates.
        property("update == rebuild bitwise", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let first = g.usize_in(2, 10);
            let second = g.usize_in(1, 8);
            let third = g.usize_in(1, 8);
            g.label(format!("d={d} n={n} first={first} +{second} +{third}"));
            let spec = SigSpec::new(d, n).unwrap();
            let total = first + second + third;
            let pts = random_path(g.rng(), total, d);
            let mut incremental = Path::new(&spec, &pts[..first * d], first).unwrap();
            incremental.update(&pts[first * d..(first + second) * d], second).unwrap();
            incremental.update(&pts[(first + second) * d..], third).unwrap();
            let fresh = Path::new(&spec, &pts, total).unwrap();
            assert_eq!(incremental.len(), fresh.len());
            // Private fields are visible to this child test module: compare
            // the full precomputed buffers, not just derived views.
            assert_eq!(incremental.sigs, fresh.sigs, "expanding signatures differ");
            assert_eq!(incremental.inv_sigs, fresh.inv_sigs, "inverted signatures differ");
            assert_eq!(incremental.points, fresh.points);
        });
    }

    #[test]
    fn distant_interval_query_precision() {
        // The paper cautions that I_i ⊠ S_j cancels large terms for
        // distant (i, j); pin the realised precision with a property test
        // over intervals spanning at least half the stream. Bounds are
        // looser than the short-interval test above, reflecting the
        // cancellation, but must stay within the documented envelope.
        property("distant query precision", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(64, 160);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            // Gentler increments than random_path: distant-interval
            // cancellation compounds with signature magnitude.
            let mut pts = vec![0.0f32; stream * d];
            for i in 1..stream {
                for c in 0..d {
                    pts[i * d + c] = pts[(i - 1) * d + c] + g.rng().normal_f32() * 0.1;
                }
            }
            let path = Path::new(&spec, &pts, stream).unwrap();
            for _ in 0..4 {
                let i = g.usize_in(0, stream / 2 - 1);
                let j = g.usize_in(i + stream / 2, stream - 1);
                let fast = path.query(i, j).unwrap();
                let slow = path.query_recompute(i, j).unwrap();
                assert_close(&fast, &slow, 1e-2, 1e-3);
                assert!(
                    crate::substrate::propcheck::rel_l2(&fast, &slow) < 1e-2,
                    "rel l2 blowup on [{i}, {j}]"
                );
            }
        });
    }

    #[test]
    fn logsig_queries_match_direct() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(9);
        let pts = random_path(&mut rng, 10, 2);
        let path = Path::new(&spec, &pts, 10).unwrap();
        for basis in [LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let q = path.logsig_query(2, 7, &plan).unwrap();
            let direct = logsignature(&pts[2 * 2..8 * 2], 6, &spec, &plan);
            assert_close(&q, &direct, 5e-3, 5e-4);
        }
    }

    #[test]
    fn logsig_query_into_matches_allocating_query_bitwise() {
        // The allocation-free variant must agree bit-for-bit with
        // logsig_query across bases and intervals — including adjacent
        // intervals, which take the exp-of-increment fast path, and a
        // dirty, reused workspace/out buffer.
        let spec = SigSpec::new(2, 4).unwrap();
        let mut rng = Rng::new(24);
        let pts = random_path(&mut rng, 10, 2);
        let path = Path::new(&spec, &pts, 10).unwrap();
        let mut ws = LogSigWorkspace::new(&spec);
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let mut out = vec![f32::NAN; plan.dim()]; // dirty on purpose
            for (i, j) in [(0, 9), (2, 7), (3, 4), (0, 1), (8, 9)] {
                path.logsig_query_into(i, j, &plan, &mut ws, &mut out).unwrap();
                assert_eq!(
                    out,
                    path.logsig_query(i, j, &plan).unwrap(),
                    "{basis:?} interval [{i}, {j}]"
                );
            }
        }
        // Validation is an error, never a panic: bad interval, wrong out
        // width, mismatched plan, and a workspace sized for another spec.
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut out = vec![0.0f32; plan.dim()];
        assert!(path.logsig_query_into(3, 3, &plan, &mut ws, &mut out).is_err());
        assert!(path
            .logsig_query_into(0, 3, &plan, &mut ws, &mut out[..1])
            .is_err());
        let wrong = LogSigPlan::new(&SigSpec::new(3, 4).unwrap(), LogSigBasis::Words).unwrap();
        assert!(path.logsig_query_into(0, 3, &wrong, &mut ws, &mut out).is_err());
        let mut wrong_ws = LogSigWorkspace::new(&SigSpec::new(3, 4).unwrap());
        assert!(path.logsig_query_into(0, 3, &plan, &mut wrong_ws, &mut out).is_err());
    }

    #[test]
    fn logsig_query_rejects_mismatched_plan() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(10);
        let pts = random_path(&mut rng, 8, 2);
        let path = Path::new(&spec, &pts, 8).unwrap();
        let wrong = LogSigPlan::new(&SigSpec::new(3, 3).unwrap(), LogSigBasis::Words).unwrap();
        assert!(path.logsig_query(1, 5, &wrong).is_err());
    }

    #[test]
    fn stream_view_matches_signature_stream() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(4);
        let pts = random_path(&mut rng, 9, 2);
        let path = Path::new(&spec, &pts, 9).unwrap();
        let st = crate::signature::signature_stream(&pts, 9, &spec);
        assert_close(path.stream(), &st, 1e-6, 1e-7);
    }

    #[test]
    fn into_variants_match_allocating_queries() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(23);
        let pts = random_path(&mut rng, 10, 2);
        let path = Path::new(&spec, &pts, 10).unwrap();
        let mut buf = vec![f32::NAN; spec.sig_len()]; // dirty: must be fully overwritten
        for (i, j) in [(0, 9), (2, 3), (3, 8), (0, 1)] {
            path.query_into(i, j, &mut buf).unwrap();
            assert_eq!(buf, path.query(i, j).unwrap(), "interval [{i}, {j}]");
        }
        path.signature_into(&mut buf).unwrap();
        assert_eq!(buf, path.signature());
        // Buffer-shape and interval validation are errors, not panics.
        assert!(path.query_into(0, 3, &mut buf[..2]).is_err());
        assert!(path.signature_into(&mut buf[..2]).is_err());
        assert!(path.query_into(3, 3, &mut buf).is_err());
    }

    #[test]
    fn invalid_intervals_error() {
        let spec = SigSpec::new(2, 2).unwrap();
        let pts = vec![0.0f32; 6];
        let path = Path::new(&spec, &pts, 3).unwrap();
        assert!(path.query(1, 1).is_err());
        assert!(path.query(2, 1).is_err());
        assert!(path.query(0, 3).is_err());
        assert!(Path::new(&spec, &pts[..2], 1).is_err());
    }

    #[test]
    fn update_batch_matches_scalar_update_bitwise() {
        // The feed-lane contract: advancing several same-spec paths
        // through one lane-fused sweep must reproduce scalar per-path
        // `update` bit-for-bit on every stored buffer — including ragged
        // feed counts, which force mid-sweep lane repacking.
        property("update_batch == update bitwise", 12, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let lanes = g.usize_in(2, 6);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let spec = SigSpec::new(d, n).unwrap();
            let mut fused: Vec<Path> = vec![];
            let mut scalar: Vec<Path> = vec![];
            let mut feeds: Vec<Vec<f32>> = vec![];
            let mut counts: Vec<usize> = vec![];
            for _ in 0..lanes {
                let seed_len = g.usize_in(2, 8);
                let pts = random_path(g.rng(), seed_len, d);
                fused.push(Path::new(&spec, &pts, seed_len).unwrap());
                scalar.push(Path::new(&spec, &pts, seed_len).unwrap());
                let count = g.usize_in(1, 7); // ragged on purpose
                feeds.push(g.normal_vec(count * d, 0.3));
                counts.push(count);
            }
            {
                let mut refs: Vec<&mut Path> = fused.iter_mut().collect();
                let slices: Vec<&[f32]> = feeds.iter().map(|f| f.as_slice()).collect();
                Path::update_batch(&mut refs, &slices, &counts).unwrap();
            }
            for k in 0..lanes {
                scalar[k].update(&feeds[k], counts[k]).unwrap();
                assert_eq!(fused[k].sigs, scalar[k].sigs, "lane {k} sigs");
                assert_eq!(fused[k].inv_sigs, scalar[k].inv_sigs, "lane {k} inv_sigs");
                assert_eq!(fused[k].points, scalar[k].points, "lane {k} points");
            }
        });
    }

    #[test]
    fn update_batch_repeated_feeds_stay_bitwise() {
        // Several successive batched feeds (the serving steady state) must
        // keep every lane bit-identical to its scalar twin.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(71);
        let lanes = 3;
        let mut fused: Vec<Path> = vec![];
        let mut scalar: Vec<Path> = vec![];
        for _ in 0..lanes {
            let pts = random_path(&mut rng, 4, 2);
            fused.push(Path::new(&spec, &pts, 4).unwrap());
            scalar.push(Path::new(&spec, &pts, 4).unwrap());
        }
        for round in 0..4 {
            let counts: Vec<usize> = (0..lanes).map(|k| 1 + (round + k) % 4).collect();
            let feeds: Vec<Vec<f32>> =
                counts.iter().map(|&c| rng.normal_vec(c * 2, 0.3)).collect();
            {
                let mut refs: Vec<&mut Path> = fused.iter_mut().collect();
                let slices: Vec<&[f32]> = feeds.iter().map(|f| f.as_slice()).collect();
                Path::update_batch(&mut refs, &slices, &counts).unwrap();
            }
            for k in 0..lanes {
                scalar[k].update(&feeds[k], counts[k]).unwrap();
            }
        }
        for k in 0..lanes {
            assert_eq!(fused[k].sigs, scalar[k].sigs);
            assert_eq!(fused[k].inv_sigs, scalar[k].inv_sigs);
            assert_eq!(fused[k].points, scalar[k].points);
        }
    }

    #[test]
    fn update_batch_validates_before_touching_anything() {
        let spec = SigSpec::new(2, 2).unwrap();
        let other = SigSpec::new(3, 2).unwrap();
        let mut rng = Rng::new(72);
        let mut a = Path::new(&spec, &random_path(&mut rng, 3, 2), 3).unwrap();
        let mut b = Path::new(&spec, &random_path(&mut rng, 3, 2), 3).unwrap();
        let mut c = Path::new(&other, &random_path(&mut rng, 3, 3), 3).unwrap();
        let before_a = a.sigs.clone();
        let feed = vec![0.1f32, 0.2, 0.3, 0.4];
        // Mismatched spec in the group.
        {
            let mut refs: Vec<&mut Path> = vec![&mut a, &mut c];
            assert!(Path::update_batch(&mut refs, &[&feed, &feed], &[2, 2]).is_err());
        }
        // Zero count / wrong buffer length.
        {
            let mut refs: Vec<&mut Path> = vec![&mut a, &mut b];
            assert!(Path::update_batch(&mut refs, &[&feed, &feed], &[2, 0]).is_err());
            assert!(Path::update_batch(&mut refs, &[&feed, &feed[..3]], &[2, 2]).is_err());
            assert!(Path::update_batch(&mut refs, &[&feed], &[2, 2]).is_err());
        }
        assert_eq!(a.sigs, before_a, "failed validation must not modify any path");
        assert_eq!(a.len(), 3);
        // A single lane delegates to the scalar update.
        {
            let mut refs: Vec<&mut Path> = vec![&mut a];
            Path::update_batch(&mut refs, &[&feed], &[2]).unwrap();
        }
        let mut twin = Path::new(&spec, &a.points[..3 * 2].to_vec(), 3).unwrap();
        twin.update(&feed, 2).unwrap();
        assert_eq!(a.sigs, twin.sigs);
    }

    #[test]
    fn truncate_front_keeps_queries_bitwise() {
        // The rolling-window memory contract: dropping the dead prefix
        // must not move a single bit of any still-answerable query, and
        // indices stay absolute.
        property("truncate keeps queries bitwise", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(8, 24);
            let cut = g.usize_in(1, stream - 2);
            g.label(format!("d={d} n={n} stream={stream} cut={cut}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path(g.rng(), stream, d);
            let control = Path::new(&spec, &pts, stream).unwrap();
            let mut path = Path::new(&spec, &pts, stream).unwrap();
            path.truncate_front(cut);
            assert_eq!(path.base(), cut);
            assert_eq!(path.len(), stream, "len stays absolute");
            assert_eq!(path.stored_len(), stream - cut);
            assert_eq!(path.signature(), control.signature());
            for _ in 0..6 {
                let i = g.usize_in(cut, stream - 2);
                let j = g.usize_in(i + 1, stream - 1);
                assert_eq!(path.query(i, j).unwrap(), control.query(i, j).unwrap());
                assert_eq!(
                    path.query_recompute(i, j).unwrap(),
                    control.query_recompute(i, j).unwrap()
                );
            }
            // Below-watermark queries are clean errors, not wrong answers.
            if cut >= 1 {
                assert!(path.query(cut - 1, stream - 1).is_err());
                assert!(path.query_recompute(cut - 1, stream - 1).is_err());
            }
        });
    }

    #[test]
    fn extend_after_truncate_stays_bitwise() {
        // Feeding a truncated path resumes from the stored running state,
        // so growth after truncation must match an untruncated control
        // bit-for-bit — this is what makes window retention invisible to
        // rolling outputs.
        let spec = SigSpec::new(2, 4).unwrap();
        let mut rng = Rng::new(77);
        let pts = random_path(&mut rng, 30, 2);
        let control = Path::new(&spec, &pts, 30).unwrap();
        let mut path = Path::new(&spec, &pts[..12 * 2], 12).unwrap();
        path.truncate_front(7);
        path.update(&pts[12 * 2..20 * 2], 8).unwrap();
        path.truncate_front(15); // repeated truncation mid-stream
        path.truncate_front(3); // regressions are no-ops
        assert_eq!(path.base(), 15);
        path.update(&pts[20 * 2..], 10).unwrap();
        assert_eq!(path.len(), 30);
        assert_eq!(path.signature(), control.signature());
        for (i, j) in [(15, 29), (20, 21), (16, 25), (28, 29)] {
            assert_eq!(path.query(i, j).unwrap(), control.query(i, j).unwrap(), "[{i}, {j}]");
        }
        // Storage reflects only the retained suffix.
        assert!(path.storage_bytes() < control.storage_bytes() / 2 + 64);
    }

    #[test]
    fn truncate_clamps_to_keep_two_points() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(78);
        let pts = random_path(&mut rng, 6, 2);
        let mut path = Path::new(&spec, &pts, 6).unwrap();
        path.truncate_front(usize::MAX); // clamped to len - 2
        assert_eq!(path.base(), 4);
        assert_eq!(path.stored_len(), 2);
        assert_eq!(path.query(4, 5).unwrap(), {
            let control = Path::new(&spec, &pts, 6).unwrap();
            control.query(4, 5).unwrap()
        });
        // Still feedable after maximal truncation.
        path.update(&[1.0, -0.5], 1).unwrap();
        let control = {
            let mut c = Path::new(&spec, &pts, 6).unwrap();
            c.update(&[1.0, -0.5], 1).unwrap();
            c
        };
        assert_eq!(path.signature(), control.signature());
    }

    #[test]
    fn truncated_update_batch_matches_scalar() {
        // The feed lane advances truncated window sessions too: lanes with
        // differing watermarks must still be bitwise equal to their scalar
        // twins.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(79);
        let mut fused: Vec<Path> = vec![];
        let mut scalar: Vec<Path> = vec![];
        for k in 0..3usize {
            let pts = random_path(&mut rng, 8, 2);
            let mut a = Path::new(&spec, &pts, 8).unwrap();
            let mut b = Path::new(&spec, &pts, 8).unwrap();
            a.truncate_front(2 * k); // watermarks 0, 2, 4
            b.truncate_front(2 * k);
            fused.push(a);
            scalar.push(b);
        }
        let feeds: Vec<Vec<f32>> = (0..3).map(|k| rng.normal_vec((k + 1) * 2, 0.3)).collect();
        let counts = vec![1usize, 2, 3];
        {
            let mut refs: Vec<&mut Path> = fused.iter_mut().collect();
            let slices: Vec<&[f32]> = feeds.iter().map(|f| f.as_slice()).collect();
            Path::update_batch(&mut refs, &slices, &counts).unwrap();
        }
        for k in 0..3 {
            scalar[k].update(&feeds[k], counts[k]).unwrap();
            assert_eq!(fused[k].sigs, scalar[k].sigs, "lane {k} sigs");
            assert_eq!(fused[k].inv_sigs, scalar[k].inv_sigs, "lane {k} inv_sigs");
            assert_eq!(fused[k].points, scalar[k].points, "lane {k} points");
            assert_eq!(fused[k].base, scalar[k].base);
        }
    }

    #[test]
    fn storage_is_linear_in_length() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(6);
        let p1 = Path::new(&spec, &random_path(&mut rng, 10, 2), 10).unwrap();
        let p2 = Path::new(&spec, &random_path(&mut rng, 20, 2), 20).unwrap();
        let per_point1 = p1.storage_bytes() as f64 / 10.0;
        let per_point2 = p2.storage_bytes() as f64 / 20.0;
        assert!((per_point1 - per_point2).abs() / per_point1 < 0.2);
    }
}
