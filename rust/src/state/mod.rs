//! Durable session state: the persistence layer under the coordinator.
//!
//! Signatory's serving trick is precomputation — a [`crate::path::Path`]
//! carries expanding and inverse signatures so interval queries are O(1)
//! Chen combinations — which makes session state the most valuable thing
//! the server holds. This layer makes that state durable and movable:
//!
//! - [`codec`]: a compact versioned binary codec for `Path`
//!   ([`crate::path::Path::serialize_into`] /
//!   [`crate::path::Path::deserialize`]) — spec, element precision, and
//!   the three precomputed buffers (`storage_bytes` measures exactly what
//!   it writes), round-tripping **bitwise** in both precisions.
//! - [`store`]: the [`store::SessionStore`] abstraction (in-memory and
//!   on-disk backends) that LRU eviction *spills* into instead of
//!   destroying state, so the next touch transparently reloads.
//! - [`wal`]: an append-only feed-delta log, write-behind and
//!   fsync-batched by the session sweeper, replayed on startup so
//!   `signax serve-stream --state-dir` warm-restarts with every session
//!   recovered — replay is bitwise because `Path` extension is exactly
//!   resumable (pinned by `update_matches_fresh_bit_for_bit`). Records
//!   frame point rows at their native element width (typed
//!   [`crate::ta::Rows`]), so f64 sessions recover through f64 kernels.
//! - [`placement`]: hash-sharding of session ids across N logical
//!   coordinator instances with spec-aware assignment, so feed lanes
//!   still find same-spec peers after sharding
//!   ([`crate::coordinator::ShardedCoordinator`]).
//!
//! The session table itself stays in [`crate::coordinator::session`];
//! this layer owns only representation and durability, so a replication
//! target (warm standby) is one more consumer of the same codec + log.

pub mod codec;
pub mod placement;
pub mod store;
pub mod wal;

pub(crate) use codec::{deserialize_session, serialize_session_into, session_serialized_len};
pub use placement::Placement;
pub use store::{DiskStore, MemStore, SessionStore, SpillConfig};
pub use wal::{FeedLog, WalRecord};
