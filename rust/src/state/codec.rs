//! A compact versioned binary codec for [`Path`].
//!
//! The persistent state of a `Path` is its spec plus the three precomputed
//! buffers — points, expanding signatures, inverse signatures
//! ([`Path::storage_bytes`] measures exactly these); the fused-op
//! workspace is transient and rebuilt on load. Layout (little-endian):
//!
//! ```text
//! magic    b"SGXP"           4 bytes
//! version  u16               currently 3 (v1/v2 blobs still decode; v3
//!                            adds the retention watermark `base` to the
//!                            header and a flag-gated rolling-window
//!                            section)
//! prec     u8                Precision::tag() of the element type
//! flags    u8                bit 0x1: a rolling-window section follows
//!                            the element buffers (window sessions only);
//!                            all other bits reserved (0)
//! d        u32
//! depth    u32
//! stream   u32               number of *stored* points
//! base     u32               v3 only: points truncated from the front
//!                            ([`Path::base`]); v1/v2 headers stop at
//!                            `stream` and decode with base = 0
//! points   stream * d        raw element bits
//! sigs     sig_rows * sig_len   (sig_rows = stream - 1 when base == 0,
//! inv_sigs sig_rows * sig_len    stream otherwise)
//! window   (flag 0x1 only)   len u32, stride u32, basis u8 (0 = sig,
//!                            1/2/3 = Expanded/Lyndon/Words logsig),
//!                            next_end u64, emitted u64, delivered u64,
//!                            pending (emitted - delivered) * out_dim
//! checksum u64               FNV-1a over every preceding byte
//! ```
//!
//! Elements are written as their raw IEEE bits (via the identity
//! `to_f32`/`to_f64` conversions at the matching width), so a
//! serialize → deserialize round trip is **bitwise** — the property the
//! spill/reload path and warm restart rely on, pinned by property tests
//! in both precisions. The checksum turns torn or corrupted spill files
//! into clean errors instead of silently wrong signatures.
//!
//! [`Path::serialize_into`] / [`Path::deserialize`] handle bare paths
//! (flags 0); window sessions spill through
//! [`serialize_session_into`] / [`deserialize_session`], which carry the
//! undelivered pending rows too — those may cover already-truncated
//! points, so they are state, not cache.

use crate::path::{Path, RollingWindow, WindowSpec};
use crate::logsignature::LogSigBasis;
use crate::ta::{Elem, Precision, SigSpec};

const MAGIC: &[u8; 4] = b"SGXP";
/// Version written by [`Path::serialize_into`]. v1 and v2 share one
/// layout (20-byte header, base = 0); v3 widens the header with the
/// retention watermark and introduces the window flag. All three decode.
const VERSION: u16 = 3;
const MIN_VERSION: u16 = 1;

/// Flag bit: a rolling-window section follows the element buffers.
const FLAG_WINDOW: u8 = 0x1;

/// FNV-1a, 64-bit: cheap, dependency-free torn-write detection (this is
/// an integrity check against partial writes, not an adversarial MAC).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn write_elems<E: Elem>(out: &mut Vec<u8>, xs: &[E]) {
    match E::PRECISION {
        // `to_f32` / `to_f64` are the identity at the matching width, so
        // these are the raw stored bits.
        Precision::F32 => {
            for &x in xs {
                out.extend_from_slice(&x.to_f32().to_le_bytes());
            }
        }
        Precision::F64 => {
            for &x in xs {
                out.extend_from_slice(&x.to_f64().to_le_bytes());
            }
        }
    }
}

fn read_elems<E: Elem>(buf: &[u8], n: usize) -> anyhow::Result<(Vec<E>, &[u8])> {
    let width = E::PRECISION.size_of();
    anyhow::ensure!(
        buf.len() >= n * width,
        "truncated Path record: needed {} element bytes, found {}",
        n * width,
        buf.len()
    );
    let (raw, rest) = buf.split_at(n * width);
    let mut xs = Vec::with_capacity(n);
    match E::PRECISION {
        Precision::F32 => {
            for c in raw.chunks_exact(4) {
                xs.push(E::from_f32(f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
            }
        }
        Precision::F64 => {
            for c in raw.chunks_exact(8) {
                xs.push(E::from_f64(f64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ])));
            }
        }
    }
    Ok((xs, rest))
}

fn read_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([buf[at], buf[at + 1], buf[at + 2], buf[at + 3]])
}

/// Fixed part of a v1/v2 record before the element buffers.
const HEADER_LEN_V2: usize = 4 + 2 + 1 + 1 + 4 + 4 + 4;
/// v3 adds the 4-byte retention watermark.
const HEADER_LEN: usize = HEADER_LEN_V2 + 4;
/// Fixed part of the window section before the pending elements.
const WINDOW_FIXED_LEN: usize = 4 + 4 + 1 + 8 + 8 + 8;

fn basis_tag(logsig: Option<LogSigBasis>) -> u8 {
    match logsig {
        None => 0,
        Some(LogSigBasis::Expanded) => 1,
        Some(LogSigBasis::Lyndon) => 2,
        Some(LogSigBasis::Words) => 3,
    }
}

fn basis_from_tag(tag: u8) -> anyhow::Result<Option<LogSigBasis>> {
    Ok(match tag {
        0 => None,
        1 => Some(LogSigBasis::Expanded),
        2 => Some(LogSigBasis::Lyndon),
        3 => Some(LogSigBasis::Words),
        t => anyhow::bail!("unknown window basis tag {t}"),
    })
}

fn encode_record<E: Elem>(path: &Path<E>, window: Option<&RollingWindow<E>>, out: &mut Vec<u8>) {
    let (spec, base, points, sigs, inv_sigs) = path.raw_parts();
    let start = out.len();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(E::PRECISION.tag());
    out.push(if window.is_some() { FLAG_WINDOW } else { 0 });
    out.extend_from_slice(&(spec.d() as u32).to_le_bytes());
    out.extend_from_slice(&(spec.depth() as u32).to_le_bytes());
    out.extend_from_slice(&(path.stored_len() as u32).to_le_bytes());
    out.extend_from_slice(&(base as u32).to_le_bytes());
    write_elems(out, points);
    write_elems(out, sigs);
    write_elems(out, inv_sigs);
    if let Some(win) = window {
        let (wspec, next_end, emitted, delivered, pending) = win.raw_parts();
        out.extend_from_slice(&(wspec.len as u32).to_le_bytes());
        out.extend_from_slice(&(wspec.stride as u32).to_le_bytes());
        out.push(basis_tag(wspec.logsig));
        out.extend_from_slice(&next_end.to_le_bytes());
        out.extend_from_slice(&emitted.to_le_bytes());
        out.extend_from_slice(&delivered.to_le_bytes());
        write_elems(out, pending);
    }
    let sum = fnv1a(&out[start..]);
    out.extend_from_slice(&sum.to_le_bytes());
}

fn decode_record<E: Elem>(bytes: &[u8]) -> anyhow::Result<(Path<E>, Option<RollingWindow<E>>)> {
    anyhow::ensure!(
        bytes.len() >= HEADER_LEN_V2 + 8,
        "Path record too short ({} bytes)",
        bytes.len()
    );
    anyhow::ensure!(&bytes[..4] == MAGIC, "bad Path record magic");
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported Path codec version {version}"
    );
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(sum_bytes.try_into().expect("8 checksum bytes"));
    anyhow::ensure!(fnv1a(body) == want, "Path record checksum mismatch (torn write?)");
    let prec = bytes[6];
    anyhow::ensure!(
        prec == E::PRECISION.tag(),
        "Path record is precision tag {prec}, requested {}",
        E::PRECISION.label()
    );
    let flags = bytes[7];
    anyhow::ensure!(flags & !FLAG_WINDOW == 0, "unknown Path record flags {flags:#x}");
    let has_window = flags & FLAG_WINDOW != 0;
    anyhow::ensure!(
        version >= 3 || !has_window,
        "window flag on a v{version} Path record"
    );
    let d = read_u32(bytes, 8) as usize;
    let depth = read_u32(bytes, 12) as usize;
    let stream = read_u32(bytes, 16) as usize;
    // v3 headers carry the retention watermark; v1/v2 stop at `stream`
    // (and decode identically since base was always 0 then).
    let (base, header_len) = if version >= 3 {
        anyhow::ensure!(bytes.len() >= HEADER_LEN + 8, "truncated v3 Path record");
        (read_u32(bytes, 20) as usize, HEADER_LEN)
    } else {
        (0, HEADER_LEN_V2)
    };
    // The reloaded spec carries the element dtype (v2 semantics; v1
    // blobs decode identically since the prec byte was always there).
    let spec = SigSpec::with_dtype(d, depth, E::PRECISION)?;
    anyhow::ensure!(stream >= 2, "Path record has {stream} points, need at least 2");
    let sig_rows = stream - usize::from(base == 0);
    let rest = &body[header_len..];
    let (points, rest) = read_elems::<E>(rest, stream * d)?;
    let (sigs, rest) = read_elems::<E>(rest, sig_rows * spec.sig_len())?;
    let (inv_sigs, rest) = read_elems::<E>(rest, sig_rows * spec.sig_len())?;
    let path = Path::from_raw_parts(spec.clone(), base, points, sigs, inv_sigs)?;
    let window = if has_window {
        anyhow::ensure!(rest.len() >= WINDOW_FIXED_LEN, "truncated window section");
        let wlen = read_u32(rest, 0) as usize;
        let wstride = read_u32(rest, 4) as usize;
        let logsig = basis_from_tag(rest[8])?;
        let rd_u64 = |at: usize| {
            u64::from_le_bytes(rest[at..at + 8].try_into().expect("8 bytes"))
        };
        let (next_end, emitted, delivered) = (rd_u64(9), rd_u64(17), rd_u64(25));
        let wspec = WindowSpec { len: wlen, stride: wstride, logsig };
        wspec.validate()?;
        anyhow::ensure!(delivered <= emitted, "window counters corrupt");
        let tail = &rest[WINDOW_FIXED_LEN..];
        let out_dim = match logsig {
            Some(basis) => crate::logsignature::LogSigPlan::new(&spec, basis)?.dim(),
            None => spec.sig_len(),
        };
        let rows = usize::try_from(emitted - delivered)?;
        let (pending, tail) = read_elems::<E>(tail, rows * out_dim)?;
        anyhow::ensure!(tail.is_empty(), "{} trailing bytes in Path record", tail.len());
        Some(RollingWindow::from_raw(&spec, wspec, next_end, emitted, delivered, pending)?)
    } else {
        anyhow::ensure!(rest.is_empty(), "{} trailing bytes in Path record", rest.len());
        None
    };
    Ok((path, window))
}

/// Exact size in bytes of a session record: the path record plus the
/// window section when present.
pub(crate) fn session_serialized_len<E: Elem>(
    path: &Path<E>,
    window: Option<&RollingWindow<E>>,
) -> usize {
    path.serialized_len()
        + window.map_or(0, |w| WINDOW_FIXED_LEN + w.pending_bytes())
}

/// Append the serialized form of a session — a `Path` plus optional
/// rolling-window state — to `out`. Bare sessions write exactly the
/// [`Path::serialize_into`] bytes; window sessions set the window flag and
/// append the window section. Bitwise round-trip with
/// [`deserialize_session`].
pub(crate) fn serialize_session_into<E: Elem>(
    path: &Path<E>,
    window: Option<&RollingWindow<E>>,
    out: &mut Vec<u8>,
) {
    out.reserve(session_serialized_len(path, window));
    encode_record(path, window, out);
}

/// Decode a session record written by [`serialize_session_into`] —
/// validates everything [`Path::deserialize`] does, plus the window
/// section's counters when present.
pub(crate) fn deserialize_session<E: Elem>(
    bytes: &[u8],
) -> anyhow::Result<(Path<E>, Option<RollingWindow<E>>)> {
    decode_record(bytes)
}

impl<E: Elem> Path<E> {
    /// Exact size in bytes of the serialized form (header + elements +
    /// checksum), for preallocating spill buffers.
    pub fn serialized_len(&self) -> usize {
        HEADER_LEN + self.storage_bytes() + 8
    }

    /// Append the versioned binary form of this `Path` to `out` (see the
    /// module docs for the layout). Bitwise round-trip with
    /// [`Path::deserialize`].
    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.serialized_len());
        encode_record(self, None, out);
    }

    /// The serialized form as a fresh buffer (convenience over
    /// [`Path::serialize_into`]).
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.serialized_len());
        self.serialize_into(&mut out);
        out
    }

    /// Decode a `Path` previously written by [`Path::serialize_into`].
    /// Validates magic, version, checksum, the element precision against
    /// `E`, and every buffer-length invariant; the workspace is rebuilt.
    /// The decoded buffers are adopted verbatim — reload is bitwise.
    /// Records carrying window state must decode through the session
    /// codec instead.
    pub fn deserialize(bytes: &[u8]) -> anyhow::Result<Path<E>> {
        let (path, window) = decode_record(bytes)?;
        anyhow::ensure!(
            window.is_none(),
            "Path record carries rolling-window state; decode it as a session"
        );
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::property;
    use crate::substrate::rng::Rng;
    use crate::ta::SigSpec;

    fn random_path_pts(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn roundtrip_is_bitwise_f32() {
        // The spill/reload contract: every stored buffer — sigs, inv_sigs,
        // points — survives serialize → deserialize bit-for-bit, across
        // specs and stream lengths, and the reloaded Path keeps answering
        // queries identically.
        property("codec roundtrip bitwise f32", 12, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 20);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path_pts(g.rng(), stream, d);
            let path = Path::new(&spec, &pts, stream).unwrap();
            let bytes = path.serialize();
            assert_eq!(bytes.len(), path.serialized_len());
            let back: Path = Path::deserialize(&bytes).unwrap();
            let (s0, _, p0, sig0, inv0) = path.raw_parts();
            let (s1, _, p1, sig1, inv1) = back.raw_parts();
            assert_eq!((s0.d(), s0.depth()), (s1.d(), s1.depth()));
            assert_eq!(p0, p1, "points");
            assert_eq!(sig0, sig1, "expanding signatures");
            assert_eq!(inv0, inv1, "inverse signatures");
            if stream > 2 {
                let i = g.usize_in(0, stream - 2);
                let j = g.usize_in(i + 1, stream - 1);
                assert_eq!(path.query(i, j).unwrap(), back.query(i, j).unwrap());
            }
        });
    }

    #[test]
    fn roundtrip_is_bitwise_f64() {
        // Same contract at the other end of the precision axis — the f64
        // half of the acceptance criterion.
        property("codec roundtrip bitwise f64", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 16);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts: Vec<f64> =
                random_path_pts(g.rng(), stream, d).iter().map(|&v| v as f64).collect();
            let path: Path<f64> = Path::new(&spec, &pts, stream).unwrap();
            let bytes = path.serialize();
            let back: Path<f64> = Path::deserialize(&bytes).unwrap();
            let (_, _, p0, sig0, inv0) = path.raw_parts();
            let (_, _, p1, sig1, inv1) = back.raw_parts();
            assert_eq!(p0, p1, "points");
            assert_eq!(sig0, sig1, "expanding signatures");
            assert_eq!(inv0, inv1, "inverse signatures");
        });
    }

    #[test]
    fn feed_after_reload_is_bitwise() {
        // Resuming a reloaded Path must continue the exact op sequence: a
        // spilled-and-reloaded session fed more points ends bitwise
        // identical to its never-spilled twin (the codec half of the
        // session-layer reload test).
        property("feed after reload bitwise", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let first = g.usize_in(2, 10);
            let extra = g.usize_in(1, 8);
            g.label(format!("d={d} n={n} first={first} extra={extra}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path_pts(g.rng(), first + extra, d);
            let mut control = Path::new(&spec, &pts[..first * d], first).unwrap();
            let bytes = control.serialize();
            let mut reloaded: Path = Path::deserialize(&bytes).unwrap();
            control.update(&pts[first * d..], extra).unwrap();
            reloaded.update(&pts[first * d..], extra).unwrap();
            let (_, _, p0, sig0, inv0) = control.raw_parts();
            let (_, _, p1, sig1, inv1) = reloaded.raw_parts();
            assert_eq!(sig0, sig1, "sigs diverged after reload");
            assert_eq!(inv0, inv1, "inv_sigs diverged after reload");
            assert_eq!(p0, p1);
        });
    }

    #[test]
    fn corruption_and_mismatch_are_clean_errors() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(5);
        let pts = random_path_pts(&mut rng, 6, 2);
        let path = Path::new(&spec, &pts, 6).unwrap();
        let bytes = path.serialize();
        // Truncation (torn write).
        assert!(Path::<f32>::deserialize(&bytes[..bytes.len() - 3]).is_err());
        assert!(Path::<f32>::deserialize(&bytes[..10]).is_err());
        // Bit flip in the body trips the checksum.
        let mut flipped = bytes.clone();
        flipped[HEADER_LEN + 5] ^= 0x40;
        assert!(Path::<f32>::deserialize(&flipped).is_err());
        // Wrong magic / version / flags.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Path::<f32>::deserialize(&bad).is_err());
        // Precision mismatch: an f32 record must not decode as f64.
        assert!(Path::<f64>::deserialize(&bytes).is_err());
        // A future version must not decode.
        let mut vnext = bytes.clone();
        vnext[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let body_end = vnext.len() - 8;
        let sum = fnv1a(&vnext[..body_end]).to_le_bytes();
        vnext[body_end..].copy_from_slice(&sum);
        assert!(Path::<f32>::deserialize(&vnext).is_err());
    }

    /// Hand-frame a pre-v3 record (20-byte header, no base field) from a
    /// path's buffers — the layout every blob on disk had before this
    /// version.
    fn frame_legacy(version: u16, path: &Path<f32>) -> Vec<u8> {
        let (spec, base, points, sigs, inv_sigs) = path.raw_parts();
        assert_eq!(base, 0, "legacy records are untruncated by definition");
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(b"SGXP");
        out.extend_from_slice(&version.to_le_bytes());
        out.push(crate::ta::Precision::F32.tag());
        out.push(0u8);
        out.extend_from_slice(&(spec.d() as u32).to_le_bytes());
        out.extend_from_slice(&(spec.depth() as u32).to_le_bytes());
        out.extend_from_slice(&(path.stored_len() as u32).to_le_bytes());
        for buf in [points, sigs, inv_sigs] {
            for &x in buf {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        let sum = fnv1a(&out).to_le_bytes();
        out.extend_from_slice(&sum);
        out
    }

    #[test]
    fn v1_and_v2_blobs_still_decode() {
        // Spill blobs written before the v3 header widening (no base
        // field) must keep reloading bitwise.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(6);
        let pts = random_path_pts(&mut rng, 5, 2);
        let path = Path::new(&spec, &pts, 5).unwrap();
        for version in [1u16, 2] {
            let bytes = frame_legacy(version, &path);
            let back: Path = Path::deserialize(&bytes).unwrap();
            let (_, b0, p0, sig0, inv0) = path.raw_parts();
            let (_, b1, p1, sig1, inv1) = back.raw_parts();
            assert_eq!((b0, b1), (0, 0));
            assert_eq!(p0, p1, "v{version} points");
            assert_eq!(sig0, sig1, "v{version} expanding signatures");
            assert_eq!(inv0, inv1, "v{version} inverse signatures");
        }
    }

    #[test]
    fn truncated_path_roundtrips_with_watermark() {
        // v3 carries the retention watermark: a truncated path reloads
        // with the same base, the same absolute indices, and bitwise
        // buffers — and keeps feeding identically afterwards.
        property("v3 watermark roundtrip", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 3);
            let stream = g.usize_in(6, 20);
            let cut = g.usize_in(1, stream - 2);
            g.label(format!("d={d} n={n} stream={stream} cut={cut}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path_pts(g.rng(), stream, d);
            let mut path = Path::new(&spec, &pts, stream).unwrap();
            path.truncate_front(cut);
            let bytes = path.serialize();
            assert_eq!(bytes.len(), path.serialized_len());
            let mut back: Path = Path::deserialize(&bytes).unwrap();
            assert_eq!(back.base(), cut);
            assert_eq!(back.len(), stream);
            let (_, _, p0, sig0, inv0) = path.raw_parts();
            let (_, _, p1, sig1, inv1) = back.raw_parts();
            assert_eq!(p0, p1, "points");
            assert_eq!(sig0, sig1, "expanding signatures");
            assert_eq!(inv0, inv1, "inverse signatures");
            let extra = g.normal_vec(2 * d, 0.3);
            path.update(&extra, 2).unwrap();
            back.update(&extra, 2).unwrap();
            assert_eq!(path.signature(), back.signature(), "feed after reload diverged");
        });
    }

    #[test]
    fn window_sessions_roundtrip_bitwise() {
        use crate::logsignature::LogSigBasis;
        use crate::path::{RollingWindow, WindowSpec};
        // The session codec carries the rolling-window section: cursor,
        // counters, and the undelivered pending rows (whose source points
        // may already be truncated — they are state, not cache).
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(7);
        let pts = random_path_pts(&mut rng, 30, 2);
        for logsig in [None, Some(LogSigBasis::Words)] {
            let wspec = WindowSpec { len: 6, stride: 2, logsig };
            let mut path = Path::<f32>::new(&spec, &pts, 30).unwrap();
            let mut win = RollingWindow::<f32>::new(&spec, wspec).unwrap();
            win.advance(&mut path).unwrap();
            win.mark_delivered(3); // partially delivered on purpose
            let mut bytes = Vec::new();
            serialize_session_into(&path, Some(&win), &mut bytes);
            assert_eq!(bytes.len(), session_serialized_len(&path, Some(&win)));
            let (mut path2, win2) = deserialize_session::<f32>(&bytes).unwrap();
            let mut win2 = win2.expect("window section decoded");
            assert_eq!(win.raw_parts().1, win2.raw_parts().1, "cursor");
            assert_eq!(win.raw_parts().4, win2.raw_parts().4, "pending rows");
            // A bare-path decode must refuse the window record cleanly.
            assert!(Path::<f32>::deserialize(&bytes).is_err());
            // And both continue identically: feed, advance, poll.
            let extra = rng.normal_vec(5 * 2, 0.3);
            path.update(&extra, 5).unwrap();
            path2.update(&extra, 5).unwrap();
            win.advance(&mut path).unwrap();
            win2.advance(&mut path2).unwrap();
            assert_eq!(win.poll(), win2.poll(), "logsig={logsig:?}");
        }
        // A bare session serializes to exactly the Path record bytes.
        let path = Path::<f32>::new(&spec, &pts, 30).unwrap();
        let mut bytes = Vec::new();
        serialize_session_into(&path, None, &mut bytes);
        assert_eq!(bytes, path.serialize());
        let (_, no_win) = deserialize_session::<f32>(&bytes).unwrap();
        assert!(no_win.is_none());
    }
}
