//! A compact versioned binary codec for [`Path`].
//!
//! The persistent state of a `Path` is its spec plus the three precomputed
//! buffers — points, expanding signatures, inverse signatures
//! ([`Path::storage_bytes`] measures exactly these); the fused-op
//! workspace is transient and rebuilt on load. Layout (little-endian):
//!
//! ```text
//! magic    b"SGXP"           4 bytes
//! version  u16               currently 2 (v1 blobs still decode; v2
//!                            marks that the decoded spec carries the
//!                            element dtype from the prec byte, so a
//!                            reloaded session keeps its native width)
//! prec     u8                Precision::tag() of the element type
//! flags    u8                reserved (0): basepoint/initial/inverse are
//!                            normalised into the stored buffers at
//!                            construction, so no variant flags exist yet
//! d        u32
//! depth    u32
//! stream   u32               number of stored points
//! points   stream * d        raw element bits
//! sigs     (stream-1) * sig_len
//! inv_sigs (stream-1) * sig_len
//! checksum u64               FNV-1a over every preceding byte
//! ```
//!
//! Elements are written as their raw IEEE bits (via the identity
//! `to_f32`/`to_f64` conversions at the matching width), so a
//! serialize → deserialize round trip is **bitwise** — the property the
//! spill/reload path and warm restart rely on, pinned by property tests
//! in both precisions. The checksum turns torn or corrupted spill files
//! into clean errors instead of silently wrong signatures.

use crate::path::Path;
use crate::ta::{Elem, Precision, SigSpec};

const MAGIC: &[u8; 4] = b"SGXP";
/// Version written by [`Path::serialize_into`]. v1 and v2 share the same
/// byte layout; the bump records the typed-row data plane (the decoded
/// spec's dtype now comes from the prec byte). Both versions decode.
const VERSION: u16 = 2;
const MIN_VERSION: u16 = 1;

/// FNV-1a, 64-bit: cheap, dependency-free torn-write detection (this is
/// an integrity check against partial writes, not an adversarial MAC).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn write_elems<E: Elem>(out: &mut Vec<u8>, xs: &[E]) {
    match E::PRECISION {
        // `to_f32` / `to_f64` are the identity at the matching width, so
        // these are the raw stored bits.
        Precision::F32 => {
            for &x in xs {
                out.extend_from_slice(&x.to_f32().to_le_bytes());
            }
        }
        Precision::F64 => {
            for &x in xs {
                out.extend_from_slice(&x.to_f64().to_le_bytes());
            }
        }
    }
}

fn read_elems<E: Elem>(buf: &[u8], n: usize) -> anyhow::Result<(Vec<E>, &[u8])> {
    let width = E::PRECISION.size_of();
    anyhow::ensure!(
        buf.len() >= n * width,
        "truncated Path record: needed {} element bytes, found {}",
        n * width,
        buf.len()
    );
    let (raw, rest) = buf.split_at(n * width);
    let mut xs = Vec::with_capacity(n);
    match E::PRECISION {
        Precision::F32 => {
            for c in raw.chunks_exact(4) {
                xs.push(E::from_f32(f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
            }
        }
        Precision::F64 => {
            for c in raw.chunks_exact(8) {
                xs.push(E::from_f64(f64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ])));
            }
        }
    }
    Ok((xs, rest))
}

fn read_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([buf[at], buf[at + 1], buf[at + 2], buf[at + 3]])
}

/// Fixed part of the record before the element buffers.
const HEADER_LEN: usize = 4 + 2 + 1 + 1 + 4 + 4 + 4;

impl<E: Elem> Path<E> {
    /// Exact size in bytes of the serialized form (header + elements +
    /// checksum), for preallocating spill buffers.
    pub fn serialized_len(&self) -> usize {
        HEADER_LEN + self.storage_bytes() + 8
    }

    /// Append the versioned binary form of this `Path` to `out` (see the
    /// module docs for the layout). Bitwise round-trip with
    /// [`Path::deserialize`].
    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        let (spec, points, sigs, inv_sigs) = self.raw_parts();
        out.reserve(self.serialized_len());
        let base = out.len();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(E::PRECISION.tag());
        out.push(0u8); // flags: reserved
        out.extend_from_slice(&(spec.d() as u32).to_le_bytes());
        out.extend_from_slice(&(spec.depth() as u32).to_le_bytes());
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        write_elems(out, points);
        write_elems(out, sigs);
        write_elems(out, inv_sigs);
        let sum = fnv1a(&out[base..]);
        out.extend_from_slice(&sum.to_le_bytes());
    }

    /// The serialized form as a fresh buffer (convenience over
    /// [`Path::serialize_into`]).
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.serialized_len());
        self.serialize_into(&mut out);
        out
    }

    /// Decode a `Path` previously written by [`Path::serialize_into`].
    /// Validates magic, version, checksum, the element precision against
    /// `E`, and every buffer-length invariant; the workspace is rebuilt.
    /// The decoded buffers are adopted verbatim — reload is bitwise.
    pub fn deserialize(bytes: &[u8]) -> anyhow::Result<Path<E>> {
        anyhow::ensure!(
            bytes.len() >= HEADER_LEN + 8,
            "Path record too short ({} bytes)",
            bytes.len()
        );
        anyhow::ensure!(&bytes[..4] == MAGIC, "bad Path record magic");
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        anyhow::ensure!(
            (MIN_VERSION..=VERSION).contains(&version),
            "unsupported Path codec version {version}"
        );
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(sum_bytes.try_into().expect("8 checksum bytes"));
        anyhow::ensure!(fnv1a(body) == want, "Path record checksum mismatch (torn write?)");
        let prec = bytes[6];
        anyhow::ensure!(
            prec == E::PRECISION.tag(),
            "Path record is precision tag {prec}, requested {}",
            E::PRECISION.label()
        );
        anyhow::ensure!(bytes[7] == 0, "unknown Path record flags {:#x}", bytes[7]);
        let d = read_u32(bytes, 8) as usize;
        let depth = read_u32(bytes, 12) as usize;
        let stream = read_u32(bytes, 16) as usize;
        // The reloaded spec carries the element dtype (v2 semantics; v1
        // blobs decode identically since the prec byte was always there).
        let spec = SigSpec::with_dtype(d, depth, E::PRECISION)?;
        anyhow::ensure!(stream >= 2, "Path record has {stream} points, need at least 2");
        let rest = &body[HEADER_LEN..];
        let (points, rest) = read_elems::<E>(rest, stream * d)?;
        let (sigs, rest) = read_elems::<E>(rest, (stream - 1) * spec.sig_len())?;
        let (inv_sigs, rest) = read_elems::<E>(rest, (stream - 1) * spec.sig_len())?;
        anyhow::ensure!(rest.is_empty(), "{} trailing bytes in Path record", rest.len());
        Path::from_raw_parts(spec, points, sigs, inv_sigs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::property;
    use crate::substrate::rng::Rng;
    use crate::ta::SigSpec;

    fn random_path_pts(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn roundtrip_is_bitwise_f32() {
        // The spill/reload contract: every stored buffer — sigs, inv_sigs,
        // points — survives serialize → deserialize bit-for-bit, across
        // specs and stream lengths, and the reloaded Path keeps answering
        // queries identically.
        property("codec roundtrip bitwise f32", 12, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 20);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path_pts(g.rng(), stream, d);
            let path = Path::new(&spec, &pts, stream).unwrap();
            let bytes = path.serialize();
            assert_eq!(bytes.len(), path.serialized_len());
            let back: Path = Path::deserialize(&bytes).unwrap();
            let (s0, p0, sig0, inv0) = path.raw_parts();
            let (s1, p1, sig1, inv1) = back.raw_parts();
            assert_eq!((s0.d(), s0.depth()), (s1.d(), s1.depth()));
            assert_eq!(p0, p1, "points");
            assert_eq!(sig0, sig1, "expanding signatures");
            assert_eq!(inv0, inv1, "inverse signatures");
            if stream > 2 {
                let i = g.usize_in(0, stream - 2);
                let j = g.usize_in(i + 1, stream - 1);
                assert_eq!(path.query(i, j).unwrap(), back.query(i, j).unwrap());
            }
        });
    }

    #[test]
    fn roundtrip_is_bitwise_f64() {
        // Same contract at the other end of the precision axis — the f64
        // half of the acceptance criterion.
        property("codec roundtrip bitwise f64", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 16);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts: Vec<f64> =
                random_path_pts(g.rng(), stream, d).iter().map(|&v| v as f64).collect();
            let path: Path<f64> = Path::new(&spec, &pts, stream).unwrap();
            let bytes = path.serialize();
            let back: Path<f64> = Path::deserialize(&bytes).unwrap();
            let (_, p0, sig0, inv0) = path.raw_parts();
            let (_, p1, sig1, inv1) = back.raw_parts();
            assert_eq!(p0, p1, "points");
            assert_eq!(sig0, sig1, "expanding signatures");
            assert_eq!(inv0, inv1, "inverse signatures");
        });
    }

    #[test]
    fn feed_after_reload_is_bitwise() {
        // Resuming a reloaded Path must continue the exact op sequence: a
        // spilled-and-reloaded session fed more points ends bitwise
        // identical to its never-spilled twin (the codec half of the
        // session-layer reload test).
        property("feed after reload bitwise", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let first = g.usize_in(2, 10);
            let extra = g.usize_in(1, 8);
            g.label(format!("d={d} n={n} first={first} extra={extra}"));
            let spec = SigSpec::new(d, n).unwrap();
            let pts = random_path_pts(g.rng(), first + extra, d);
            let mut control = Path::new(&spec, &pts[..first * d], first).unwrap();
            let bytes = control.serialize();
            let mut reloaded: Path = Path::deserialize(&bytes).unwrap();
            control.update(&pts[first * d..], extra).unwrap();
            reloaded.update(&pts[first * d..], extra).unwrap();
            let (_, p0, sig0, inv0) = control.raw_parts();
            let (_, p1, sig1, inv1) = reloaded.raw_parts();
            assert_eq!(sig0, sig1, "sigs diverged after reload");
            assert_eq!(inv0, inv1, "inv_sigs diverged after reload");
            assert_eq!(p0, p1);
        });
    }

    #[test]
    fn corruption_and_mismatch_are_clean_errors() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(5);
        let pts = random_path_pts(&mut rng, 6, 2);
        let path = Path::new(&spec, &pts, 6).unwrap();
        let bytes = path.serialize();
        // Truncation (torn write).
        assert!(Path::<f32>::deserialize(&bytes[..bytes.len() - 3]).is_err());
        assert!(Path::<f32>::deserialize(&bytes[..10]).is_err());
        // Bit flip in the body trips the checksum.
        let mut flipped = bytes.clone();
        flipped[HEADER_LEN + 5] ^= 0x40;
        assert!(Path::<f32>::deserialize(&flipped).is_err());
        // Wrong magic / version / flags.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Path::<f32>::deserialize(&bad).is_err());
        // Precision mismatch: an f32 record must not decode as f64.
        assert!(Path::<f64>::deserialize(&bytes).is_err());
        // A future version must not decode.
        let mut vnext = bytes.clone();
        vnext[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let body_end = vnext.len() - 8;
        let sum = fnv1a(&vnext[..body_end]).to_le_bytes();
        vnext[body_end..].copy_from_slice(&sum);
        assert!(Path::<f32>::deserialize(&vnext).is_err());
    }

    #[test]
    fn v1_blobs_still_decode() {
        // Spill blobs written before the version bump (same layout,
        // version field 1) must keep reloading bitwise: patch the version
        // back to 1 and re-seal the checksum.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(6);
        let pts = random_path_pts(&mut rng, 5, 2);
        let path = Path::new(&spec, &pts, 5).unwrap();
        let mut bytes = path.serialize();
        bytes[4..6].copy_from_slice(&1u16.to_le_bytes());
        let body_end = bytes.len() - 8;
        let sum = fnv1a(&bytes[..body_end]).to_le_bytes();
        bytes[body_end..].copy_from_slice(&sum);
        let back: Path = Path::deserialize(&bytes).unwrap();
        let (_, p0, sig0, inv0) = path.raw_parts();
        let (_, p1, sig1, inv1) = back.raw_parts();
        assert_eq!(p0, p1, "points");
        assert_eq!(sig0, sig1, "expanding signatures");
        assert_eq!(inv0, inv1, "inverse signatures");
    }
}
