//! Spill backends: where evicted session state goes instead of dying.
//!
//! [`SessionStore`] is a tiny blob store keyed by session id. The session
//! table serializes a victim's `Path` through the [`crate::state::codec`]
//! and `put`s it here; the next touch `get`s it back and deserializes —
//! eviction becomes a *spill* with transparent reload rather than data
//! loss. Two backends:
//!
//! - [`MemStore`]: a mutexed map. Frees no real memory overall (the bytes
//!   move from hot `Path` buffers to a cold compact blob) but exercises
//!   the full spill/reload lifecycle without touching disk — used by
//!   tests and useful when the budget pressure is on *workspace-carrying*
//!   resident paths rather than total footprint.
//! - [`DiskStore`]: one `{id}.sgxp` file per spilled session under a
//!   directory, written via a tmp-file rename so a crash mid-spill leaves
//!   either the old blob or none (the codec checksum catches torn tails).
//!
//! [`SpillConfig`] is the user-facing knob threaded through
//! `SessionConfig`: `None` preserves the original destroy-on-evict
//! behaviour; `Disk` additionally implies the feed-delta WAL and
//! warm-restart recovery (see [`crate::state::wal`]).

use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// A blob store for spilled session state, keyed by session id.
///
/// Implementations must be safe to call from the sweeper thread and
/// request threads concurrently; atomicity is per-call (the session layer
/// serializes per-session transitions under the session's slot lock).
pub trait SessionStore: Send + Sync {
    /// Store (or replace) the blob for `id`.
    fn put(&self, id: u64, bytes: &[u8]) -> anyhow::Result<()>;
    /// Fetch the blob for `id`; `Ok(None)` if nothing is spilled there.
    fn get(&self, id: u64) -> anyhow::Result<Option<Vec<u8>>>;
    /// Drop the blob for `id` (no-op if absent).
    fn remove(&self, id: u64) -> anyhow::Result<()>;
    /// All ids currently spilled, in no particular order.
    fn list(&self) -> anyhow::Result<Vec<u64>>;
    /// Drop every blob (used when WAL replay supersedes stale spills).
    fn clear(&self) -> anyhow::Result<()>;
}

/// In-memory spill backend: a mutexed `HashMap<u64, Vec<u8>>`.
#[derive(Default)]
pub struct MemStore {
    blobs: Mutex<HashMap<u64, Vec<u8>>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }
}

impl SessionStore for MemStore {
    fn put(&self, id: u64, bytes: &[u8]) -> anyhow::Result<()> {
        self.blobs.lock().unwrap().insert(id, bytes.to_vec());
        Ok(())
    }

    fn get(&self, id: u64) -> anyhow::Result<Option<Vec<u8>>> {
        Ok(self.blobs.lock().unwrap().get(&id).cloned())
    }

    fn remove(&self, id: u64) -> anyhow::Result<()> {
        self.blobs.lock().unwrap().remove(&id);
        Ok(())
    }

    fn list(&self) -> anyhow::Result<Vec<u64>> {
        Ok(self.blobs.lock().unwrap().keys().copied().collect())
    }

    fn clear(&self) -> anyhow::Result<()> {
        self.blobs.lock().unwrap().clear();
        Ok(())
    }
}

/// On-disk spill backend: `dir/{id}.sgxp`, one file per spilled session.
pub struct DiskStore {
    dir: PathBuf,
}

impl DiskStore {
    /// Open (creating if needed) a spill directory.
    pub fn new(dir: impl Into<PathBuf>) -> anyhow::Result<DiskStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(DiskStore { dir })
    }

    fn blob_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{id}.sgxp"))
    }
}

impl SessionStore for DiskStore {
    fn put(&self, id: u64, bytes: &[u8]) -> anyhow::Result<()> {
        // Write-then-rename so a crash mid-spill never leaves a half
        // blob under the final name.
        let tmp = self.dir.join(format!("{id}.sgxp.tmp"));
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, self.blob_path(id))?;
        Ok(())
    }

    fn get(&self, id: u64) -> anyhow::Result<Option<Vec<u8>>> {
        match std::fs::read(self.blob_path(id)) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn remove(&self, id: u64) -> anyhow::Result<()> {
        match std::fs::remove_file(self.blob_path(id)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    fn list(&self) -> anyhow::Result<Vec<u64>> {
        let mut ids = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(stem) = name.strip_suffix(".sgxp") {
                if let Ok(id) = stem.parse::<u64>() {
                    ids.push(id);
                }
            }
        }
        Ok(ids)
    }

    fn clear(&self) -> anyhow::Result<()> {
        for id in self.list()? {
            self.remove(id)?;
        }
        Ok(())
    }
}

/// Where eviction sends session state. `None` is the original behaviour:
/// eviction destroys the path and later touches error.
#[derive(Clone, Debug, Default)]
pub enum SpillConfig {
    /// Destroy on evict (seed behaviour).
    #[default]
    None,
    /// Spill to an in-memory blob map (lifecycle without durability).
    Memory,
    /// Spill to `{dir}/sessions/` and log feeds to `{dir}/wal.log` for
    /// warm restart — the `--state-dir` of `signax serve-stream`.
    Disk(PathBuf),
}

impl SpillConfig {
    /// Instantiate the spill backend, if any.
    pub fn build_store(&self) -> anyhow::Result<Option<Arc<dyn SessionStore>>> {
        match self {
            SpillConfig::None => Ok(None),
            SpillConfig::Memory => Ok(Some(Arc::new(MemStore::new()))),
            SpillConfig::Disk(dir) => {
                Ok(Some(Arc::new(DiskStore::new(dir.join("sessions"))?)))
            }
        }
    }

    /// The WAL path, when this configuration is durable.
    pub fn wal_path(&self) -> Option<PathBuf> {
        match self {
            SpillConfig::Disk(dir) => Some(dir.join("wal.log")),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(store: &dyn SessionStore) {
        assert!(store.get(7).unwrap().is_none());
        store.put(7, b"hello").unwrap();
        store.put(9, b"world").unwrap();
        assert_eq!(store.get(7).unwrap().as_deref(), Some(&b"hello"[..]));
        store.put(7, b"replaced").unwrap();
        assert_eq!(store.get(7).unwrap().as_deref(), Some(&b"replaced"[..]));
        let mut ids = store.list().unwrap();
        ids.sort_unstable();
        assert_eq!(ids, vec![7, 9]);
        store.remove(7).unwrap();
        store.remove(7).unwrap(); // idempotent
        assert!(store.get(7).unwrap().is_none());
        store.clear().unwrap();
        assert!(store.list().unwrap().is_empty());
    }

    #[test]
    fn mem_store_contract() {
        exercise(&MemStore::new());
    }

    #[test]
    fn disk_store_contract() {
        let dir = std::env::temp_dir().join(format!("signax-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = DiskStore::new(&dir).unwrap();
        exercise(&store);
        // Blobs survive reopening the directory.
        store.put(3, b"persist").unwrap();
        drop(store);
        let reopened = DiskStore::new(&dir).unwrap();
        assert_eq!(reopened.get(3).unwrap().as_deref(), Some(&b"persist"[..]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spill_config_wiring() {
        assert!(SpillConfig::None.build_store().unwrap().is_none());
        assert!(SpillConfig::None.wal_path().is_none());
        assert!(SpillConfig::Memory.build_store().unwrap().is_some());
        assert!(SpillConfig::Memory.wal_path().is_none());
        let dir = std::env::temp_dir().join(format!("signax-spillcfg-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SpillConfig::Disk(dir.clone());
        assert!(cfg.build_store().unwrap().is_some());
        assert_eq!(cfg.wal_path().unwrap(), dir.join("wal.log"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
