//! Shard-aware session placement across logical coordinator instances.
//!
//! [`Placement`] answers two questions for a
//! [`crate::coordinator::ShardedCoordinator`] running `n` logical shards:
//!
//! - **Where does a new session go?** [`Placement::place_open`] hashes
//!   the session's `(d, depth)` spec and assigns sessions of the same
//!   spec to the same shard in groups of [`crate::exec::LANE_BLOCK`]
//!   before overflowing to the next shard. Feed batching gains all its
//!   throughput from packing same-spec sessions into SIMD lane blocks
//!   (`SessionManager::feed_batch`); naive round-robin would scatter a
//!   same-spec fleet one-per-shard and every shard would feed scalar.
//!   Grouped assignment keeps lane peers co-located while still
//!   spreading an oversized fleet across shards.
//! - **Where does an existing session live?** [`Placement::locate`] is
//!   pure arithmetic, no table: each shard `k` allocates ids from the
//!   strided sequence `k + 1, k + 1 + n, k + 1 + 2n, …`
//!   (`SessionConfig::{first_id, id_stride}`), so the owner of id `s` is
//!   `(s - 1) % n`. Ids stay unique across shards with zero coordination
//!   and a session op needs no broadcast to find its home.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::exec::LANE_BLOCK;

/// Hash-sharding policy for session ids across `n` logical coordinators.
pub struct Placement {
    shards: usize,
    group: usize,
    /// Open counts per spec, for grouped same-spec assignment.
    counts: Mutex<HashMap<(usize, usize), u64>>,
}

impl Placement {
    /// Policy over `shards` logical instances, grouping same-spec opens
    /// in lane-width blocks ([`LANE_BLOCK`]).
    pub fn new(shards: usize) -> Placement {
        Placement::with_group(shards, LANE_BLOCK)
    }

    /// As [`Placement::new`] with an explicit group width (tests).
    pub fn with_group(shards: usize, group: usize) -> Placement {
        Placement {
            shards: shards.max(1),
            group: group.max(1),
            counts: Mutex::new(HashMap::new()),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard for the `k`-th open of spec `(d, depth)`: the spec hash
    /// anchors the spec's home shard; every `group` opens of that spec
    /// step to the next shard, so lane peers co-locate before spreading.
    pub fn place_open(&self, d: usize, depth: usize) -> usize {
        let mut counts = self.counts.lock().unwrap();
        let seq = counts.entry((d, depth)).or_insert(0);
        let k = *seq;
        *seq += 1;
        let anchor = spec_hash(d, depth);
        ((anchor + k / self.group as u64) % self.shards as u64) as usize
    }

    /// Shard owning session id `id`, given id-striped allocation
    /// (shard `k` issues ids ≡ `k + 1` mod `shards`, ids start at 1).
    pub fn locate(&self, id: u64) -> usize {
        debug_assert!(id >= 1, "session ids start at 1");
        ((id - 1) % self.shards as u64) as usize
    }
}

/// FNV-1a over the spec fields — stable across runs (placement of a
/// recovering fleet must match the run that wrote the state dir).
fn spec_hash(d: usize, depth: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in (d as u64).to_le_bytes().iter().chain((depth as u64).to_le_bytes().iter()) {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_spec_opens_group_into_lane_blocks() {
        let p = Placement::with_group(4, 4);
        let shards: Vec<usize> = (0..12).map(|_| p.place_open(3, 2)).collect();
        // First 4 opens co-locate, next 4 on the following shard, etc.
        assert_eq!(&shards[0..4], &[shards[0]; 4]);
        assert_eq!(&shards[4..8], &[(shards[0] + 1) % 4; 4]);
        assert_eq!(&shards[8..12], &[(shards[0] + 2) % 4; 4]);
    }

    #[test]
    fn distinct_specs_spread_over_shards() {
        let p = Placement::with_group(4, 16);
        let hit: std::collections::HashSet<usize> =
            (1..=8).map(|d| p.place_open(d, 3)).collect();
        // The spec hash should not collapse every spec onto one shard.
        assert!(hit.len() > 1, "all specs landed on one shard: {hit:?}");
    }

    #[test]
    fn locate_inverts_strided_allocation() {
        let n = 3;
        let p = Placement::new(n);
        // Shard k issues first_id = k + 1, stride n.
        for k in 0..n {
            for step in 0..5u64 {
                let id = (k as u64 + 1) + step * n as u64;
                assert_eq!(p.locate(id), k, "id {id}");
            }
        }
    }

    #[test]
    fn single_shard_degenerates() {
        let p = Placement::new(1);
        assert_eq!(p.place_open(2, 3), 0);
        assert_eq!(p.place_open(5, 1), 0);
        assert_eq!(p.locate(1), 0);
        assert_eq!(p.locate(999), 0);
    }
}
