//! Append-only feed-delta log for warm restarts.
//!
//! Every session mutation — open (with its initial points), feed, close —
//! appends a [`WalRecord`] to the [`FeedLog`]. Appends go to an in-process
//! buffer under the log's mutex (so record order matches the order the
//! session layer applied the mutations); the session sweeper thread calls
//! [`FeedLog::flush`] on its cadence, batching many appends into one
//! write + fsync. A feed is therefore durable within one sweep interval
//! of being acknowledged — the same write-behind trade the LRU sweeper
//! already makes for eviction.
//!
//! On startup with the same `--state-dir`, [`FeedLog::replay`] returns
//! the records in order and the session layer rebuilds every open session
//! by replaying its feeds through the ordinary `Path` extension. That
//! recovery is **bitwise** — not approximately right — because `Path`
//! extension is exactly resumable (`update_matches_fresh_bit_for_bit`):
//! replaying the same points through the same ops yields the same bits.
//!
//! Framing per record: `len: u32 LE` of the payload, `fnv1a: u64 LE` of
//! the payload, then the payload. Replay stops cleanly at the first
//! short or checksum-failing record, so a crash mid-write costs at most
//! the unflushed tail, never the log.
//!
//! The WAL frames rows at their **native element width**: records carry
//! typed [`Rows`], with separate tags for f32 (`1`/`2`) and f64 (`4`/`5`)
//! opens and feeds, so an f64 session's recovery replays 8-byte points
//! through the f64 kernels and never transits f32. Logs written before
//! the typed-row change used tags `1`/`2` only and replay unchanged.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path as FsPath, PathBuf};
use std::sync::Mutex;

use super::codec::fnv1a;
use crate::logsignature::LogSigBasis;
use crate::path::WindowSpec;
use crate::ta::{Precision, Rows};

/// Flush inline (not waiting for the sweeper) once this much is buffered.
const BUF_CAP: usize = 1 << 20;

const TAG_OPEN: u8 = 1;
const TAG_FEED: u8 = 2;
const TAG_CLOSE: u8 = 3;
const TAG_OPEN64: u8 = 4;
const TAG_FEED64: u8 = 5;
const TAG_OPEN_WINDOW: u8 = 6;
const TAG_OPEN_WINDOW64: u8 = 7;
const TAG_POLL: u8 = 8;

fn window_basis_tag(logsig: Option<LogSigBasis>) -> u8 {
    match logsig {
        None => 0,
        Some(LogSigBasis::Expanded) => 1,
        Some(LogSigBasis::Lyndon) => 2,
        Some(LogSigBasis::Words) => 3,
    }
}

fn window_basis_from_tag(tag: u8) -> anyhow::Result<Option<LogSigBasis>> {
    Ok(match tag {
        0 => None,
        1 => Some(LogSigBasis::Expanded),
        2 => Some(LogSigBasis::Lyndon),
        3 => Some(LogSigBasis::Words),
        t => anyhow::bail!("unknown WAL window basis tag {t}"),
    })
}

/// One logged session mutation. Point rows are typed; the encoder picks
/// the f32 or f64 tag from the rows' own precision.
///
/// Window sessions log two extra things: their `OpenWindow` (the window
/// spec must survive a restart — feeds alone cannot reconstruct it) and
/// every `Poll` (replayed feeds re-emit every window; the poll watermark
/// is what keeps a warm restart from re-delivering rows a client already
/// received).
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Session opened with `count` initial points of dimension `d`.
    Open { id: u64, d: u32, depth: u32, count: u32, points: Rows },
    /// Window session opened: `Open` plus the rolling-window spec.
    OpenWindow { id: u64, d: u32, depth: u32, count: u32, points: Rows, window: WindowSpec },
    /// `count` more points fed to an open session.
    Feed { id: u64, count: u32, points: Rows },
    /// The first `upto` window slides were delivered to the client.
    Poll { id: u64, upto: u64 },
    /// Session closed; its state is gone on purpose.
    Close { id: u64 },
}

/// Raw IEEE bits, little-endian, at the rows' native width.
fn write_rows(out: &mut Vec<u8>, rows: &Rows) {
    match rows {
        Rows::F32(ps) => {
            for &p in ps {
                out.extend_from_slice(&p.to_le_bytes());
            }
        }
        Rows::F64(ps) => {
            for &p in ps {
                out.extend_from_slice(&p.to_le_bytes());
            }
        }
    }
}

impl WalRecord {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Open { id, d, depth, count, points } => {
                out.push(match points.precision() {
                    Precision::F32 => TAG_OPEN,
                    Precision::F64 => TAG_OPEN64,
                });
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&d.to_le_bytes());
                out.extend_from_slice(&depth.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
                write_rows(out, points);
            }
            WalRecord::OpenWindow { id, d, depth, count, points, window } => {
                out.push(match points.precision() {
                    Precision::F32 => TAG_OPEN_WINDOW,
                    Precision::F64 => TAG_OPEN_WINDOW64,
                });
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&d.to_le_bytes());
                out.extend_from_slice(&depth.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
                out.extend_from_slice(&(window.len as u32).to_le_bytes());
                out.extend_from_slice(&(window.stride as u32).to_le_bytes());
                out.push(window_basis_tag(window.logsig));
                write_rows(out, points);
            }
            WalRecord::Feed { id, count, points } => {
                out.push(match points.precision() {
                    Precision::F32 => TAG_FEED,
                    Precision::F64 => TAG_FEED64,
                });
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
                write_rows(out, points);
            }
            WalRecord::Poll { id, upto } => {
                out.push(TAG_POLL);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&upto.to_le_bytes());
            }
            WalRecord::Close { id } => {
                out.push(TAG_CLOSE);
                out.extend_from_slice(&id.to_le_bytes());
            }
        }
    }

    fn decode(payload: &[u8]) -> anyhow::Result<WalRecord> {
        anyhow::ensure!(!payload.is_empty(), "empty WAL payload");
        let tag = payload[0];
        let rest = &payload[1..];
        let u64_at = |at: usize| -> anyhow::Result<u64> {
            Ok(u64::from_le_bytes(
                rest.get(at..at + 8)
                    .ok_or_else(|| anyhow::anyhow!("short WAL payload"))?
                    .try_into()?,
            ))
        };
        let u32_at = |at: usize| -> anyhow::Result<u32> {
            Ok(u32::from_le_bytes(
                rest.get(at..at + 4)
                    .ok_or_else(|| anyhow::anyhow!("short WAL payload"))?
                    .try_into()?,
            ))
        };
        let rows32 = |at: usize, n: usize| -> anyhow::Result<Rows> {
            let raw = rest
                .get(at..at + n * 4)
                .ok_or_else(|| anyhow::anyhow!("short WAL point buffer"))?;
            anyhow::ensure!(rest.len() == at + n * 4, "trailing bytes in WAL record");
            Ok(Rows::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ))
        };
        let rows64 = |at: usize, n: usize| -> anyhow::Result<Rows> {
            let raw = rest
                .get(at..at + n * 8)
                .ok_or_else(|| anyhow::anyhow!("short WAL point buffer"))?;
            anyhow::ensure!(rest.len() == at + n * 8, "trailing bytes in WAL record");
            Ok(Rows::F64(
                raw.chunks_exact(8)
                    .map(|c| {
                        f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                    })
                    .collect(),
            ))
        };
        match tag {
            TAG_OPEN | TAG_OPEN64 => {
                let id = u64_at(0)?;
                let d = u32_at(8)?;
                let depth = u32_at(12)?;
                let count = u32_at(16)?;
                let n = count as usize * d as usize;
                let points =
                    if tag == TAG_OPEN { rows32(20, n)? } else { rows64(20, n)? };
                Ok(WalRecord::Open { id, d, depth, count, points })
            }
            TAG_OPEN_WINDOW | TAG_OPEN_WINDOW64 => {
                let id = u64_at(0)?;
                let d = u32_at(8)?;
                let depth = u32_at(12)?;
                let count = u32_at(16)?;
                let wlen = u32_at(20)?;
                let wstride = u32_at(24)?;
                let basis = *rest
                    .get(28)
                    .ok_or_else(|| anyhow::anyhow!("short WAL payload"))?;
                let window = WindowSpec {
                    len: wlen as usize,
                    stride: wstride as usize,
                    logsig: window_basis_from_tag(basis)?,
                };
                let n = count as usize * d as usize;
                let points =
                    if tag == TAG_OPEN_WINDOW { rows32(29, n)? } else { rows64(29, n)? };
                Ok(WalRecord::OpenWindow { id, d, depth, count, points, window })
            }
            TAG_POLL => {
                anyhow::ensure!(rest.len() == 16, "malformed WAL poll record");
                Ok(WalRecord::Poll { id: u64_at(0)?, upto: u64_at(8)? })
            }
            TAG_FEED | TAG_FEED64 => {
                let id = u64_at(0)?;
                let count = u32_at(8)?;
                let width = if tag == TAG_FEED { 4 } else { 8 };
                anyhow::ensure!(
                    rest.len() >= 12
                        && (rest.len() - 12) % width == 0
                        && count as usize > 0,
                    "malformed WAL feed record"
                );
                let d = (rest.len() - 12) / width / count as usize;
                let n = count as usize * d;
                let points =
                    if tag == TAG_FEED { rows32(12, n)? } else { rows64(12, n)? };
                Ok(WalRecord::Feed { id, count, points })
            }
            TAG_CLOSE => Ok(WalRecord::Close { id: u64_at(0)? }),
            other => anyhow::bail!("unknown WAL record tag {other}"),
        }
    }
}

struct Inner {
    file: File,
    buf: Vec<u8>,
}

impl Inner {
    fn flush(&mut self) -> anyhow::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.file.write_all(&self.buf)?;
        self.file.sync_data()?;
        self.buf.clear();
        Ok(())
    }
}

/// The append-only feed-delta log (see the module docs).
pub struct FeedLog {
    path: PathBuf,
    inner: Mutex<Inner>,
}

impl FeedLog {
    /// Open (appending) or create the log at `path`.
    pub fn open(path: impl Into<PathBuf>) -> anyhow::Result<FeedLog> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(FeedLog { path, inner: Mutex::new(Inner { file, buf: Vec::new() }) })
    }

    /// Where this log lives.
    pub fn path(&self) -> &FsPath {
        &self.path
    }

    /// Append a record (buffered; durable after the next [`flush`]).
    ///
    /// [`flush`]: FeedLog::flush
    pub fn append(&self, rec: &WalRecord) -> anyhow::Result<()> {
        let mut payload = Vec::new();
        rec.encode(&mut payload);
        let mut inner = self.inner.lock().unwrap();
        inner.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        inner.buf.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        inner.buf.extend_from_slice(&payload);
        if inner.buf.len() >= BUF_CAP {
            inner.flush()?;
        }
        Ok(())
    }

    /// Write out and fsync everything buffered. Called by the session
    /// sweeper each interval (fsync batching) and on drop.
    pub fn flush(&self) -> anyhow::Result<()> {
        self.inner.lock().unwrap().flush()
    }

    /// Read every intact record from a log file, in append order.
    /// Stops cleanly at the first torn or corrupt record (crash tail).
    pub fn replay(path: impl AsRef<FsPath>) -> anyhow::Result<Vec<WalRecord>> {
        let mut bytes = Vec::new();
        match File::open(path.as_ref()) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        }
        let mut records = Vec::new();
        let mut at = 0usize;
        while at + 12 <= bytes.len() {
            let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
            let want = u64::from_le_bytes(bytes[at + 4..at + 12].try_into().unwrap());
            let Some(payload) = bytes.get(at + 12..at + 12 + len) else {
                break; // torn tail
            };
            if fnv1a(payload) != want {
                break; // corrupt tail
            }
            match WalRecord::decode(payload) {
                Ok(rec) => records.push(rec),
                Err(_) => break,
            }
            at += 12 + len;
        }
        Ok(records)
    }
}

impl Drop for FeedLog {
    fn drop(&mut self) {
        if let Ok(mut inner) = self.inner.lock() {
            let _ = inner.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("signax-wal-{}-{}", name, std::process::id()))
    }

    /// Mixed-precision sample log: the roundtrip covers all four typed
    /// tags (f32 and f64 opens and feeds) plus close.
    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Open {
                id: 1,
                d: 2,
                depth: 3,
                count: 2,
                points: vec![0.0f32, 0.5, 1.0, -1.5].into(),
            },
            WalRecord::Feed { id: 1, count: 1, points: vec![2.0f32, 0.25].into() },
            WalRecord::Open {
                id: 2,
                d: 1,
                depth: 4,
                count: 3,
                points: vec![0.1f64, 0.2, 0.3].into(),
            },
            WalRecord::Feed { id: 2, count: 2, points: vec![0.4f64, 0.5].into() },
            WalRecord::OpenWindow {
                id: 3,
                d: 2,
                depth: 2,
                count: 2,
                points: vec![0.0f32, 1.0, 2.0, 3.0].into(),
                window: WindowSpec { len: 4, stride: 2, logsig: Some(LogSigBasis::Lyndon) },
            },
            WalRecord::OpenWindow {
                id: 4,
                d: 1,
                depth: 3,
                count: 2,
                points: vec![0.25f64, -0.5].into(),
                window: WindowSpec { len: 8, stride: 1, logsig: None },
            },
            WalRecord::Poll { id: 3, upto: 5 },
            WalRecord::Close { id: 1 },
        ]
    }

    #[test]
    fn append_flush_replay_roundtrip() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let log = FeedLog::open(&path).unwrap();
        let recs = sample_records();
        for r in &recs {
            log.append(r).unwrap();
        }
        // Unflushed appends are buffered, not yet on disk.
        assert!(FeedLog::replay(&path).unwrap().is_empty());
        log.flush().unwrap();
        assert_eq!(FeedLog::replay(&path).unwrap(), recs);
        // Appends after reopening extend the same log.
        drop(log);
        let log = FeedLog::open(&path).unwrap();
        log.append(&WalRecord::Close { id: 2 }).unwrap();
        drop(log); // drop flushes
        let all = FeedLog::replay(&path).unwrap();
        assert_eq!(all.len(), recs.len() + 1);
        assert_eq!(all.last(), Some(&WalRecord::Close { id: 2 }));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_tolerates_torn_and_corrupt_tails() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let log = FeedLog::open(&path).unwrap();
        for r in &sample_records() {
            log.append(r).unwrap();
        }
        log.flush().unwrap();
        drop(log);
        let full = std::fs::read(&path).unwrap();
        let n = sample_records().len();
        // Torn tail: chop bytes off the end — intact prefix still replays.
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert_eq!(FeedLog::replay(&path).unwrap().len(), n - 1);
        // Corrupt tail: flip a bit in the last record's payload.
        let mut corrupt = full.clone();
        let end = corrupt.len() - 1;
        corrupt[end] ^= 0x01;
        std::fs::write(&path, &corrupt).unwrap();
        assert_eq!(FeedLog::replay(&path).unwrap().len(), n - 1);
        // Missing file is an empty log, not an error.
        std::fs::remove_file(&path).unwrap();
        assert!(FeedLog::replay(&path).unwrap().is_empty());
    }

    #[test]
    fn points_survive_bitwise() {
        // WAL replay feeds the recovered points back through Path::update;
        // the floats must come back with identical bits — at both widths,
        // including f64 values with no f32 representation at all.
        let path = tmp("bits");
        let _ = std::fs::remove_file(&path);
        let exact: Vec<f32> = vec![0.1, -0.2, 1e-30, 3.4e38, f32::MIN_POSITIVE];
        let wide: Vec<f64> = vec![0.1, -0.2, 1e-300, 1.7e308, f64::MIN_POSITIVE];
        let log = FeedLog::open(&path).unwrap();
        log.append(&WalRecord::Open {
            id: 9,
            d: 5,
            depth: 2,
            count: 1,
            points: exact.clone().into(),
        })
        .unwrap();
        log.append(&WalRecord::Feed { id: 9, count: 1, points: wide.clone().into() }).unwrap();
        log.flush().unwrap();
        drop(log);
        let recs = FeedLog::replay(&path).unwrap();
        match &recs[0] {
            WalRecord::Open { points: Rows::F32(points), .. } => {
                for (a, b) in exact.iter().zip(points) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected record {other:?}"),
        }
        match &recs[1] {
            WalRecord::Feed { points: Rows::F64(points), .. } => {
                for (a, b) in wide.iter().zip(points) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected record {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn legacy_f32_tags_still_replay() {
        // A log written before the typed-row change (tags 1/2 only, 4-byte
        // points) must replay as F32 rows byte-for-byte. Frame one by hand
        // with the v0 layout to pin the compatibility, independent of the
        // current encoder.
        let path = tmp("legacy");
        let _ = std::fs::remove_file(&path);
        let pts = [0.25f32, -0.75];
        let mut payload = vec![1u8]; // TAG_OPEN, the original f32 tag
        payload.extend_from_slice(&7u64.to_le_bytes()); // id
        payload.extend_from_slice(&2u32.to_le_bytes()); // d
        payload.extend_from_slice(&3u32.to_le_bytes()); // depth
        payload.extend_from_slice(&1u32.to_le_bytes()); // count
        for p in pts {
            payload.extend_from_slice(&p.to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        std::fs::write(&path, &bytes).unwrap();
        let recs = FeedLog::replay(&path).unwrap();
        assert_eq!(
            recs,
            vec![WalRecord::Open {
                id: 7,
                d: 2,
                depth: 3,
                count: 1,
                points: pts.to_vec().into(),
            }]
        );
        std::fs::remove_file(&path).unwrap();
    }
}
