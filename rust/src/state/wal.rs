//! Append-only feed-delta log for warm restarts.
//!
//! Every session mutation — open (with its initial points), feed, close —
//! appends a [`WalRecord`] to the [`FeedLog`]. Appends go to an in-process
//! buffer under the log's mutex (so record order matches the order the
//! session layer applied the mutations); the session sweeper thread calls
//! [`FeedLog::flush`] on its cadence, batching many appends into one
//! write + fsync. A feed is therefore durable within one sweep interval
//! of being acknowledged — the same write-behind trade the LRU sweeper
//! already makes for eviction.
//!
//! On startup with the same `--state-dir`, [`FeedLog::replay`] returns
//! the records in order and the session layer rebuilds every open session
//! by replaying its feeds through the ordinary `Path` extension. That
//! recovery is **bitwise** — not approximately right — because `Path`
//! extension is exactly resumable (`update_matches_fresh_bit_for_bit`):
//! replaying the same points through the same ops yields the same bits.
//!
//! Framing per record: `len: u32 LE` of the payload, `fnv1a: u64 LE` of
//! the payload, then the payload. Replay stops cleanly at the first
//! short or checksum-failing record, so a crash mid-write costs at most
//! the unflushed tail, never the log.
//!
//! The WAL stores f32 points only: sessions are opened over the wire
//! (f32 rows), and the native feed path is f32 — the f64 `Path` codec
//! exists for spill blobs, which carry their own precision tag.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path as FsPath, PathBuf};
use std::sync::Mutex;

use super::codec::fnv1a;

/// Flush inline (not waiting for the sweeper) once this much is buffered.
const BUF_CAP: usize = 1 << 20;

const TAG_OPEN: u8 = 1;
const TAG_FEED: u8 = 2;
const TAG_CLOSE: u8 = 3;

/// One logged session mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Session opened with `count` initial points of dimension `d`.
    Open { id: u64, d: u32, depth: u32, count: u32, points: Vec<f32> },
    /// `count` more points fed to an open session.
    Feed { id: u64, count: u32, points: Vec<f32> },
    /// Session closed; its state is gone on purpose.
    Close { id: u64 },
}

impl WalRecord {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Open { id, d, depth, count, points } => {
                out.push(TAG_OPEN);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&d.to_le_bytes());
                out.extend_from_slice(&depth.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
                for &p in points {
                    out.extend_from_slice(&p.to_le_bytes());
                }
            }
            WalRecord::Feed { id, count, points } => {
                out.push(TAG_FEED);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
                for &p in points {
                    out.extend_from_slice(&p.to_le_bytes());
                }
            }
            WalRecord::Close { id } => {
                out.push(TAG_CLOSE);
                out.extend_from_slice(&id.to_le_bytes());
            }
        }
    }

    fn decode(payload: &[u8]) -> anyhow::Result<WalRecord> {
        anyhow::ensure!(!payload.is_empty(), "empty WAL payload");
        let tag = payload[0];
        let rest = &payload[1..];
        let u64_at = |at: usize| -> anyhow::Result<u64> {
            Ok(u64::from_le_bytes(
                rest.get(at..at + 8)
                    .ok_or_else(|| anyhow::anyhow!("short WAL payload"))?
                    .try_into()?,
            ))
        };
        let u32_at = |at: usize| -> anyhow::Result<u32> {
            Ok(u32::from_le_bytes(
                rest.get(at..at + 4)
                    .ok_or_else(|| anyhow::anyhow!("short WAL payload"))?
                    .try_into()?,
            ))
        };
        let floats = |at: usize, n: usize| -> anyhow::Result<Vec<f32>> {
            let raw = rest
                .get(at..at + n * 4)
                .ok_or_else(|| anyhow::anyhow!("short WAL point buffer"))?;
            anyhow::ensure!(rest.len() == at + n * 4, "trailing bytes in WAL record");
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        match tag {
            TAG_OPEN => {
                let id = u64_at(0)?;
                let d = u32_at(8)?;
                let depth = u32_at(12)?;
                let count = u32_at(16)?;
                let points = floats(20, count as usize * d as usize)?;
                Ok(WalRecord::Open { id, d, depth, count, points })
            }
            TAG_FEED => {
                let id = u64_at(0)?;
                let count = u32_at(8)?;
                anyhow::ensure!(
                    (rest.len() - 12) % 4 == 0 && count as usize > 0,
                    "malformed WAL feed record"
                );
                let d = (rest.len() - 12) / 4 / count as usize;
                let points = floats(12, count as usize * d)?;
                Ok(WalRecord::Feed { id, count, points })
            }
            TAG_CLOSE => Ok(WalRecord::Close { id: u64_at(0)? }),
            other => anyhow::bail!("unknown WAL record tag {other}"),
        }
    }
}

struct Inner {
    file: File,
    buf: Vec<u8>,
}

impl Inner {
    fn flush(&mut self) -> anyhow::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.file.write_all(&self.buf)?;
        self.file.sync_data()?;
        self.buf.clear();
        Ok(())
    }
}

/// The append-only feed-delta log (see the module docs).
pub struct FeedLog {
    path: PathBuf,
    inner: Mutex<Inner>,
}

impl FeedLog {
    /// Open (appending) or create the log at `path`.
    pub fn open(path: impl Into<PathBuf>) -> anyhow::Result<FeedLog> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(FeedLog { path, inner: Mutex::new(Inner { file, buf: Vec::new() }) })
    }

    /// Where this log lives.
    pub fn path(&self) -> &FsPath {
        &self.path
    }

    /// Append a record (buffered; durable after the next [`flush`]).
    ///
    /// [`flush`]: FeedLog::flush
    pub fn append(&self, rec: &WalRecord) -> anyhow::Result<()> {
        let mut payload = Vec::new();
        rec.encode(&mut payload);
        let mut inner = self.inner.lock().unwrap();
        inner.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        inner.buf.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        inner.buf.extend_from_slice(&payload);
        if inner.buf.len() >= BUF_CAP {
            inner.flush()?;
        }
        Ok(())
    }

    /// Write out and fsync everything buffered. Called by the session
    /// sweeper each interval (fsync batching) and on drop.
    pub fn flush(&self) -> anyhow::Result<()> {
        self.inner.lock().unwrap().flush()
    }

    /// Read every intact record from a log file, in append order.
    /// Stops cleanly at the first torn or corrupt record (crash tail).
    pub fn replay(path: impl AsRef<FsPath>) -> anyhow::Result<Vec<WalRecord>> {
        let mut bytes = Vec::new();
        match File::open(path.as_ref()) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        }
        let mut records = Vec::new();
        let mut at = 0usize;
        while at + 12 <= bytes.len() {
            let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
            let want = u64::from_le_bytes(bytes[at + 4..at + 12].try_into().unwrap());
            let Some(payload) = bytes.get(at + 12..at + 12 + len) else {
                break; // torn tail
            };
            if fnv1a(payload) != want {
                break; // corrupt tail
            }
            match WalRecord::decode(payload) {
                Ok(rec) => records.push(rec),
                Err(_) => break,
            }
            at += 12 + len;
        }
        Ok(records)
    }
}

impl Drop for FeedLog {
    fn drop(&mut self) {
        if let Ok(mut inner) = self.inner.lock() {
            let _ = inner.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("signax-wal-{}-{}", name, std::process::id()))
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Open { id: 1, d: 2, depth: 3, count: 2, points: vec![0.0, 0.5, 1.0, -1.5] },
            WalRecord::Feed { id: 1, count: 1, points: vec![2.0, 0.25] },
            WalRecord::Open { id: 2, d: 1, depth: 4, count: 3, points: vec![0.1, 0.2, 0.3] },
            WalRecord::Feed { id: 2, count: 2, points: vec![0.4, 0.5] },
            WalRecord::Close { id: 1 },
        ]
    }

    #[test]
    fn append_flush_replay_roundtrip() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let log = FeedLog::open(&path).unwrap();
        let recs = sample_records();
        for r in &recs {
            log.append(r).unwrap();
        }
        // Unflushed appends are buffered, not yet on disk.
        assert!(FeedLog::replay(&path).unwrap().is_empty());
        log.flush().unwrap();
        assert_eq!(FeedLog::replay(&path).unwrap(), recs);
        // Appends after reopening extend the same log.
        drop(log);
        let log = FeedLog::open(&path).unwrap();
        log.append(&WalRecord::Close { id: 2 }).unwrap();
        drop(log); // drop flushes
        let all = FeedLog::replay(&path).unwrap();
        assert_eq!(all.len(), recs.len() + 1);
        assert_eq!(all.last(), Some(&WalRecord::Close { id: 2 }));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_tolerates_torn_and_corrupt_tails() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let log = FeedLog::open(&path).unwrap();
        for r in &sample_records() {
            log.append(r).unwrap();
        }
        log.flush().unwrap();
        drop(log);
        let full = std::fs::read(&path).unwrap();
        let n = sample_records().len();
        // Torn tail: chop bytes off the end — intact prefix still replays.
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert_eq!(FeedLog::replay(&path).unwrap().len(), n - 1);
        // Corrupt tail: flip a bit in the last record's payload.
        let mut corrupt = full.clone();
        let end = corrupt.len() - 1;
        corrupt[end] ^= 0x01;
        std::fs::write(&path, &corrupt).unwrap();
        assert_eq!(FeedLog::replay(&path).unwrap().len(), n - 1);
        // Missing file is an empty log, not an error.
        std::fs::remove_file(&path).unwrap();
        assert!(FeedLog::replay(&path).unwrap().is_empty());
    }

    #[test]
    fn points_survive_bitwise() {
        // WAL replay feeds the recovered points back through Path::update;
        // the floats must come back with identical bits.
        let path = tmp("bits");
        let _ = std::fs::remove_file(&path);
        let exact: Vec<f32> = vec![0.1, -0.2, 1e-30, 3.4e38, f32::MIN_POSITIVE];
        let log = FeedLog::open(&path).unwrap();
        log.append(&WalRecord::Open { id: 9, d: 5, depth: 2, count: 1, points: exact.clone() })
            .unwrap();
        log.flush().unwrap();
        drop(log);
        match &FeedLog::replay(&path).unwrap()[0] {
            WalRecord::Open { points, .. } => {
                for (a, b) in exact.iter().zip(points) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected record {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }
}
