//! Reimplementations of the systems the paper benchmarks against (§6).
//!
//! - [`iisignature_like`] — the strongest competitor: the *conventional*
//!   algorithm of App. A.1.1 (explicit exponential, then a full ⊠ per
//!   increment, `C(d,N) = Θ(N d^N)` multiplications) with an
//!   autodiff-style backward that **stores every intermediate prefix
//!   signature** (no reversibility). This is exactly the algorithmic
//!   profile the paper attributes to `iisignature`, so measuring signax
//!   against it reproduces the paper's Signatory-vs-iisignature
//!   comparison on like-for-like resources.
//! - [`esig_like`] — the `esig`-profile baseline: conventional algorithm,
//!   per-step allocations, a hard size guard (esig "is incapable of larger
//!   operations" — dashes in the paper's tables), and **no backward**.

pub mod esig_like;
pub mod iisignature_like;
