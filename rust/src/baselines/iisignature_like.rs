//! The `iisignature`-profile baseline: conventional (non-fused) Chen
//! iteration and a store-everything backward.

use crate::ta::exp::{exp_into, exp_vjp};
use crate::ta::mul::{mul_into, mul_vjp};
use crate::ta::{SigSpec, Workspace};

/// Signature via the conventional algorithm: per increment compute
/// `exp(z_i)` explicitly, then a full out-of-place ⊠ (App. A.1.1). Costs
/// `C(d, N)` multiplications per increment vs the fused `F(d, N)`.
pub fn signature(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    assert!(stream >= 2);
    assert_eq!(path.len(), stream * spec.d());
    let d = spec.d();
    let mut ws = Workspace::new(spec);
    let mut z = vec![0.0f32; d];
    // First increment: the signature IS the exponential.
    for c in 0..d {
        z[c] = path[d + c] - path[c];
    }
    let mut sig = spec.zeros();
    exp_into(spec, &z, &mut sig);
    let mut next = spec.zeros();
    for i in 2..stream {
        for c in 0..d {
            z[c] = path[i * d + c] - path[(i - 1) * d + c];
        }
        exp_into(spec, &z, &mut ws.t0); // explicit exponential
        mul_into(spec, &sig, &ws.t0, &mut next); // full, unfused ⊠
        std::mem::swap(&mut sig, &mut next);
    }
    sig
}

/// Forward pass retaining all intermediate prefix signatures (what a
/// tape-based autodiff must do without reversibility). Returns
/// `(stream - 1, sig_len)`: prefix signatures after each increment.
pub fn signature_with_tape(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    assert!(stream >= 2);
    let d = spec.d();
    let len = spec.sig_len();
    let mut tape = vec![0.0f32; (stream - 1) * len];
    let mut ws = Workspace::new(spec);
    let mut z = vec![0.0f32; d];
    for c in 0..d {
        z[c] = path[d + c] - path[c];
    }
    {
        let (first, _) = tape.split_at_mut(len);
        exp_into(spec, &z, first);
    }
    for i in 2..stream {
        for c in 0..d {
            z[c] = path[i * d + c] - path[(i - 1) * d + c];
        }
        exp_into(spec, &z, &mut ws.t0);
        let (prev, cur) = tape[(i - 2) * len..i * len].split_at_mut(len);
        mul_into(spec, prev, &ws.t0, cur);
    }
    tape
}

/// Backward pass in the iisignature style: consumes the stored tape
/// (`O(L · sig_len)` memory — this is the memory profile the paper's
/// reversibility avoids, App. C.1/D.2).
pub fn signature_vjp(path: &[f32], stream: usize, spec: &SigSpec, g: &[f32]) -> Vec<f32> {
    let d = spec.d();
    let len = spec.sig_len();
    assert_eq!(g.len(), len);
    let tape = signature_with_tape(path, stream, spec);
    let mut grad_path = vec![0.0f32; stream * d];
    let mut g_state = g.to_vec();
    let mut z = vec![0.0f32; d];
    let mut e = spec.zeros();
    for i in (2..stream).rev() {
        for c in 0..d {
            z[c] = path[i * d + c] - path[(i - 1) * d + c];
        }
        exp_into(spec, &z, &mut e);
        let prev = &tape[(i - 2) * len..(i - 1) * len];
        let mut g_prev = vec![0.0f32; len];
        let mut g_e = vec![0.0f32; len];
        mul_vjp(spec, prev, &e, &g_state, &mut g_prev, &mut g_e);
        let mut gz = vec![0.0f32; d];
        exp_vjp(spec, &z, &g_e, &mut gz);
        for c in 0..d {
            grad_path[i * d + c] += gz[c];
            grad_path[(i - 1) * d + c] -= gz[c];
        }
        g_state = g_prev;
    }
    // First increment: sig_1 = exp(z_1).
    for c in 0..d {
        z[c] = path[d + c] - path[c];
    }
    let mut gz = vec![0.0f32; d];
    exp_vjp(spec, &z, &g_state, &mut gz);
    for c in 0..d {
        grad_path[d + c] += gz[c];
        grad_path[c] -= gz[c];
    }
    grad_path
}

/// Peak additional memory (bytes) the tape-based backward retains, for the
/// §D.2 memory comparison.
pub fn tape_bytes(stream: usize, spec: &SigSpec) -> usize {
    (stream - 1) * spec.sig_len() * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn matches_fused_signature() {
        property("baseline == signax fwd", 20, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let stream = g.usize_in(2, 16);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            assert_close(
                &signature(&path, stream, &spec),
                &crate::signature::signature(&path, stream, &spec),
                1e-4,
                1e-5,
            );
        });
    }

    #[test]
    fn tape_last_entry_is_signature() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(2);
        let path = random_path(&mut rng, 8, 2);
        let tape = signature_with_tape(&path, 8, &spec);
        let len = spec.sig_len();
        assert_close(&tape[6 * len..], &signature(&path, 8, &spec), 1e-6, 1e-7);
    }

    #[test]
    fn backward_matches_reversibility_backward() {
        property("baseline bwd == signax bwd", 8, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 10);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let gvec = g.normal_vec(spec.sig_len(), 1.0);
            let ours = crate::signature::signature_vjp(&path, stream, &spec, &gvec);
            let theirs = signature_vjp(&path, stream, &spec, &gvec);
            assert_close(&theirs, &ours, 2e-3, 1e-3);
        });
    }

    #[test]
    fn tape_memory_is_linear() {
        let spec = SigSpec::new(3, 4).unwrap();
        assert_eq!(tape_bytes(128, &spec), 127 * spec.sig_len() * 4);
    }
}
