//! The `esig`-profile baseline: correct but slow, capped, forward-only.
//!
//! In the paper's tables esig is an order of magnitude slower than
//! iisignature, cannot compute backward passes at all, and shows dashes
//! ("incapable") for larger operations. We reproduce that profile
//! faithfully: the conventional algorithm with fresh allocations per step
//! and no workspace reuse, a hard size guard, and no backward entry point.

use crate::ta::exp::exp;
use crate::ta::mul::mul;
use crate::ta::SigSpec;

/// The largest `sig_len` this baseline accepts, mimicking esig's inability
/// to run the paper's larger benchmark points. Calibrated to the paper's
/// tables: esig computes (channels 4, depth 6), `sig_len` 5460, but dashes
/// at (channels 4, depth 7) = 21844 and (channels 4+, depth 7) onward.
pub const MAX_SIG_LEN: usize = 6_000;

/// Forward signature, esig-style. Errors (like esig's failure) when the
/// operation is too large or the input malformed. There is deliberately no
/// `signature_vjp` in this module — esig has no backward operation.
pub fn signature(path: &[f32], stream: usize, spec: &SigSpec) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        spec.sig_len() <= MAX_SIG_LEN,
        "esig_like: operation too large (sig_len {} > {MAX_SIG_LEN})",
        spec.sig_len()
    );
    anyhow::ensure!(stream >= 2, "need at least two points");
    anyhow::ensure!(path.len() == stream * spec.d(), "bad path buffer");
    let d = spec.d();
    let incr = |i: usize| -> Vec<f32> {
        (0..d).map(|c| path[(i + 1) * d + c] - path[i * d + c]).collect()
    };
    // exp + ⊠ per step, every intermediate freshly allocated.
    let mut sig = exp(spec, &incr(0));
    for i in 1..stream - 1 {
        let e = exp(spec, &incr(i));
        sig = mul(spec, &sig, &e);
    }
    Ok(sig)
}

/// Whether the baseline supports the given problem size (for rendering the
/// paper's dashes).
pub fn supports(spec: &SigSpec) -> bool {
    spec.sig_len() <= MAX_SIG_LEN
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    #[test]
    fn matches_signax_when_supported() {
        let spec = SigSpec::new(3, 4).unwrap();
        let mut rng = Rng::new(8);
        let stream = 10;
        let mut path = vec![0.0f32; stream * 3];
        for i in 1..stream {
            for c in 0..3 {
                path[i * 3 + c] = path[(i - 1) * 3 + c] + rng.normal_f32() * 0.3;
            }
        }
        let ours = crate::signature::signature(&path, stream, &spec);
        let esig = signature(&path, stream, &spec).unwrap();
        assert_close(&esig, &ours, 1e-4, 1e-5);
    }

    #[test]
    fn rejects_large_operations() {
        // channels 7, depth 7: sig_len ≈ 960k > the guard — the dash cells
        // of Tables 1 and 5.
        let spec = SigSpec::new(7, 7).unwrap();
        assert!(!supports(&spec));
        let path = vec![0.0f32; 2 * 7];
        assert!(signature(&path, 2, &spec).is_err());
    }

    #[test]
    fn small_operations_supported() {
        // channels 2 and 3 at depth 7 are within esig's range (the paper's
        // populated esig cells).
        assert!(supports(&SigSpec::new(2, 7).unwrap()));
        assert!(supports(&SigSpec::new(3, 7).unwrap()));
        assert!(!supports(&SigSpec::new(4, 7).unwrap()));
    }
}
