//! Observed shape-mix histogram: the planner's memory of recent traffic.
//!
//! The serving layer records one [`ShapeKey`] per request; the histogram
//! keeps exponentially decayed per-shape counts (halved whenever the total
//! reaches twice the window, so old traffic fades instead of pinning the
//! mix forever) plus, for the feed lane, a tiny ring of recently seen
//! feeder sessions per spec. Both signals are deliberately coarse — they
//! steer *batch formation* (how long to linger, how wide to open a lane),
//! never numerical results.
//!
//! Feeder-ring lifecycle vs. session durability: a slot is removed only
//! by [`ShapeMix::forget_feeder`], which the coordinator calls on
//! `CloseStream` alone. Spill-to-disk eviction and the transparent
//! reload on the next touch ([`crate::state`]) deliberately do **not**
//! forget feeders — a spilled session is still the same logical stream
//! under the same id, and its next feed after reload should rejoin its
//! lane peers immediately instead of paying the ring-rebuild round.

use crate::ta::Precision;
use std::collections::HashMap;
use std::sync::Mutex;

/// Records before the adaptive capacity rules engage; below this the
/// configured base capacity applies unchanged (no signal yet).
pub const MIX_WARMUP: usize = 8;

/// How many of a key's *own* feed records a feeder-ring entry stays
/// "recent" for. Deliberately key-local: measured against global traffic,
/// heavy stateless load would age out feed peers between rounds and turn
/// the lane into a pure linger penalty for slow streams.
const FEEDER_WINDOW: u64 = 64;

/// Distinct feeder sessions remembered per spec key.
const FEEDER_SLOTS: usize = 4;

/// Identity of a request shape in the mix histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    /// 0 = stateless signature, 1 = session feed, 2 = stateless
    /// logsignature. Sig and logsig requests of one shape microbatch in
    /// *separate* queues (different output widths and epilogues), so they
    /// adapt independently too.
    pub kind: u8,
    pub d: usize,
    pub depth: usize,
    /// Points per request for stateless shapes (ragged lengths batch
    /// separately, so capacity adapts per length too); 0 for feeds, whose
    /// lane handles ragged point counts natively.
    pub points: usize,
    /// Element precision of the request. Part of the key's identity: f32
    /// and f64 requests of one shape never coalesce into one microbatch,
    /// so each precision adapts on its own traffic.
    pub dtype: Precision,
}

impl ShapeKey {
    /// Key for a stateless signature request (default f32 precision).
    pub fn signature(d: usize, depth: usize, points: usize) -> ShapeKey {
        ShapeKey { kind: 0, d, depth, points, dtype: Precision::F32 }
    }

    /// Key for a session feed (spec only; feeds are ragged by design).
    pub fn feed(d: usize, depth: usize) -> ShapeKey {
        ShapeKey { kind: 1, d, depth, points: 0, dtype: Precision::F32 }
    }

    /// Key for a stateless logsignature request (the logsig work shape the
    /// planner learned in PR 5; distinct from the same-(d, depth, points)
    /// signature key so the two surfaces adapt on their own traffic).
    pub fn logsignature(d: usize, depth: usize, points: usize) -> ShapeKey {
        ShapeKey { kind: 2, d, depth, points, dtype: Precision::F32 }
    }

    /// The same key at a different precision — the serving layer derives
    /// f64 keys this way so the two precisions never share a queue.
    pub fn with_dtype(self, dtype: Precision) -> ShapeKey {
        ShapeKey { dtype, ..self }
    }
}

#[derive(Clone, Copy, Default)]
struct FeederSlot {
    session: u64,
    /// This key's feed tick at last sighting; 0 = empty (ticks start
    /// at 1).
    tick: u64,
}

#[derive(Default)]
struct KeyStats {
    /// Decayed request count.
    count: u64,
    /// Monotone count of this key's feed records (not decayed; drives
    /// feeder recency, immune to unrelated traffic).
    feed_tick: u64,
    /// Recently seen feeder sessions (feed keys only).
    feeders: [FeederSlot; FEEDER_SLOTS],
}

#[derive(Default)]
struct Inner {
    /// Decayed total across keys (= Σ count).
    total: u64,
    stats: HashMap<ShapeKey, KeyStats>,
}

/// Concurrent decayed histogram of recent request shapes. All methods are
/// O(1)-ish under one short mutex; recording is trivially cheap next to a
/// signature computation.
pub struct ShapeMix {
    window: usize,
    inner: Mutex<Inner>,
}

impl Default for ShapeMix {
    fn default() -> Self {
        ShapeMix::new(64)
    }
}

impl ShapeMix {
    /// A histogram whose decayed total hovers around `window` (halved on
    /// reaching `2 * window`).
    pub fn new(window: usize) -> ShapeMix {
        ShapeMix { window: window.max(MIX_WARMUP), inner: Mutex::new(Inner::default()) }
    }

    /// Record one request of `key`.
    pub fn record(&self, key: ShapeKey) {
        let mut inner = self.inner.lock().unwrap();
        inner.stats.entry(key).or_default().count += 1;
        inner.total += 1;
        self.decay(&mut inner);
    }

    /// Record a feed of `key` by `session`; returns the number of distinct
    /// sessions seen feeding this spec within the recency window
    /// (including this one). Recency is measured in *this key's* feed
    /// records, so unrelated traffic never ages out a slow stream's peer.
    pub fn record_feeder(&self, key: ShapeKey, session: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let stats = inner.stats.entry(key).or_default();
        stats.count += 1;
        stats.feed_tick += 1;
        let now = stats.feed_tick;
        // Refresh this session's slot, or claim the stalest one.
        let mut hit = None;
        let mut stalest = 0usize;
        for (i, slot) in stats.feeders.iter().enumerate() {
            if slot.tick > 0 && slot.session == session {
                hit = Some(i);
                break;
            }
            if slot.tick < stats.feeders[stalest].tick {
                stalest = i;
            }
        }
        let idx = hit.unwrap_or(stalest);
        stats.feeders[idx] = FeederSlot { session, tick: now };
        let distinct = stats
            .feeders
            .iter()
            .filter(|s| s.tick > 0 && now - s.tick <= FEEDER_WINDOW)
            .count();
        inner.total += 1;
        self.decay(&mut inner);
        distinct
    }

    /// Remove `session` from `key`'s feeder ring (the session closed; its
    /// slot must not keep quoting lane capacity to survivors).
    pub fn forget_feeder(&self, key: ShapeKey, session: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(stats) = inner.stats.get_mut(&key) {
            for slot in stats.feeders.iter_mut() {
                if slot.tick > 0 && slot.session == session {
                    *slot = FeederSlot::default();
                }
            }
        }
    }

    /// `(count(key), total)` over the decayed window.
    pub fn count_and_total(&self, key: ShapeKey) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.stats.get(&key).map_or(0, |s| s.count), inner.total)
    }

    /// Number of distinct shapes currently in the window (the shape-mix
    /// gauge the coordinator publishes).
    pub fn distinct(&self) -> usize {
        self.inner.lock().unwrap().stats.len()
    }

    /// Total decayed records (warm-up checks).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    fn decay(&self, inner: &mut Inner) {
        if inner.total >= 2 * self.window as u64 {
            // Halve with a floor of 1: a live shape never decays to a
            // zero count, so an all-unique long tail cannot collapse the
            // total and bounce the planner back into warm-up (which would
            // make rare shapes linger again — the exact latency adaptive
            // dispatch exists to remove).
            for s in inner.stats.values_mut() {
                s.count = (s.count / 2).max(1);
            }
            inner.total = inner.stats.values().map(|s| s.count).sum();
            // The floor means dead shapes never self-evict; bound the
            // table instead, evicting the lowest-count shapes first and
            // preferring to keep keys with live feeder rings (evicting
            // one only costs its next feed a direct serve while the ring
            // rebuilds).
            let cap = self.window;
            if inner.stats.len() > cap {
                let mut order: Vec<(bool, u64, ShapeKey)> = inner
                    .stats
                    .iter()
                    .map(|(k, s)| {
                        (s.feeders.iter().any(|f| f.tick > 0), s.count, *k)
                    })
                    .collect();
                // Victims first: feeder-less, then lowest count.
                order.sort_by_key(|&(has_feeders, count, _)| (has_feeders, count));
                for &(_, _, key) in order.iter().take(inner.stats.len() - cap) {
                    if let Some(s) = inner.stats.remove(&key) {
                        inner.total -= s.count;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_decay() {
        let mix = ShapeMix::new(16);
        let a = ShapeKey::signature(2, 3, 8);
        let b = ShapeKey::signature(4, 4, 128);
        for _ in 0..24 {
            mix.record(a);
        }
        for _ in 0..8 {
            mix.record(b);
        }
        // Total hit 2*16 = 32 at the last record and halved once.
        let (ca, total) = mix.count_and_total(a);
        let (cb, _) = mix.count_and_total(b);
        assert_eq!(total, ca + cb);
        assert!(total <= 32, "decay keeps the window bounded, total={total}");
        assert!(ca > cb, "hot shape outweighs the rare one after decay");
        assert_eq!(mix.distinct(), 2);
    }

    #[test]
    fn decay_floors_live_counts_and_never_reenters_warmup() {
        // Regression: decay used to halve count-1 shapes to zero, so an
        // all-unique long tail collapsed the total below MIX_WARMUP and
        // the planner handed rare shapes full capacity again (a periodic
        // linger relapse). Live counts now floor at 1, so the decayed
        // total can never fall below the window (>= MIX_WARMUP).
        let mix = ShapeMix::new(16);
        let once = ShapeKey::signature(9, 2, 4);
        mix.record(once);
        let hot = ShapeKey::signature(2, 3, 8);
        for _ in 0..200 {
            mix.record(hot);
        }
        // The rare shape survives decay with a floor count of 1 and the
        // total stays comfortably past warm-up.
        assert_eq!(mix.count_and_total(once).0, 1);
        assert!(mix.total() >= MIX_WARMUP as u64);
        assert_eq!(mix.distinct(), 2);
    }

    #[test]
    fn table_is_capped_under_all_unique_traffic() {
        // A long tail of unique shapes must bound the table (gauge and
        // memory) at the window while keeping the total meaningful — a
        // fresh rare shape still reads as rare, never as "warm-up over,
        // everyone gets full capacity".
        let mix = ShapeMix::new(16);
        for k in 0..200 {
            mix.record(ShapeKey::signature(2, 3, 100 + k));
            // The cap applies at decay time; between decays the table can
            // grow back toward the decay trigger, so 2x window is the
            // standing bound.
            assert!(mix.distinct() < 32, "table must stay bounded");
        }
        assert!(mix.total() >= MIX_WARMUP as u64, "total never re-enters warm-up");
        // Feed keys with live rings are preferentially retained.
        let feed = ShapeKey::feed(3, 4);
        mix.record_feeder(feed, 1);
        for k in 0..200 {
            mix.record(ShapeKey::signature(2, 3, 500 + k));
        }
        let (count, _) = mix.count_and_total(feed);
        assert!(count >= 1, "feeder-bearing key evicted before feeder-less ones");
    }

    #[test]
    fn logsig_keys_are_independent_of_signature_keys() {
        // Same (d, depth, points), different kind: logsig traffic must
        // never inherit (or poison) the signature shape's capacity signal.
        let mix = ShapeMix::new(16);
        let sig = ShapeKey::signature(2, 3, 8);
        let logsig = ShapeKey::logsignature(2, 3, 8);
        assert_ne!(sig, logsig);
        for _ in 0..10 {
            mix.record(sig);
        }
        assert_eq!(mix.count_and_total(logsig).0, 0);
        mix.record(logsig);
        assert_eq!(mix.count_and_total(logsig).0, 1);
        assert_eq!(mix.distinct(), 2);
    }

    #[test]
    fn f32_and_f64_keys_of_one_shape_never_coalesce() {
        // Same (kind, d, depth, points), different precision: the two keys
        // are distinct identities, so f32 and f64 requests of one shape
        // never share a microbatch queue and adapt on separate counts.
        let mix = ShapeMix::new(16);
        let f32_key = ShapeKey::signature(3, 4, 8);
        let f64_key = f32_key.with_dtype(Precision::F64);
        assert_ne!(f32_key, f64_key);
        assert_eq!(f64_key.with_dtype(Precision::F32), f32_key);
        for _ in 0..12 {
            mix.record(f32_key);
        }
        assert_eq!(mix.count_and_total(f64_key).0, 0, "f64 key must not inherit f32 counts");
        mix.record(f64_key);
        assert_eq!(mix.count_and_total(f64_key).0, 1);
        assert_eq!(mix.distinct(), 2);
        // The same holds for logsig and feed kinds.
        assert_ne!(
            ShapeKey::logsignature(3, 4, 8),
            ShapeKey::logsignature(3, 4, 8).with_dtype(Precision::F64)
        );
        assert_ne!(ShapeKey::feed(3, 4), ShapeKey::feed(3, 4).with_dtype(Precision::F64));
    }

    #[test]
    fn feeder_ring_tracks_distinct_sessions() {
        let mix = ShapeMix::new(64);
        let key = ShapeKey::feed(3, 4);
        assert_eq!(mix.record_feeder(key, 1), 1);
        assert_eq!(mix.record_feeder(key, 1), 1, "same session stays 1");
        assert_eq!(mix.record_feeder(key, 2), 2);
        assert_eq!(mix.record_feeder(key, 3), 3);
        // A long-idle feeder ages out of the recency window.
        for _ in 0..(FEEDER_WINDOW as usize + 1) {
            mix.record_feeder(key, 2);
        }
        assert_eq!(mix.record_feeder(key, 2), 1, "stale feeders aged out");
    }

    #[test]
    fn unrelated_traffic_does_not_age_feed_peers() {
        // Regression: recency used to be measured in global records, so
        // heavy stateless traffic between feed rounds aged out a slow
        // stream's peer and the lane degenerated into a per-round linger
        // penalty. Feeder recency is per-key now.
        let mix = ShapeMix::new(64);
        let key = ShapeKey::feed(3, 4);
        mix.record_feeder(key, 1);
        mix.record_feeder(key, 2);
        for _ in 0..(10 * FEEDER_WINDOW as usize) {
            mix.record(ShapeKey::signature(2, 3, 8)); // unrelated flood
        }
        assert_eq!(mix.record_feeder(key, 1), 2, "peer must still count as recent");
    }

    #[test]
    fn feeder_ring_survives_spill_and_reload_but_not_close() {
        // Durability contract: spill-to-disk eviction + reload keeps the
        // session id, and nothing in that lifecycle calls
        // `forget_feeder` — so a reloaded session's next feed still
        // counts it among the lane peers (no ring-rebuild round). Only
        // CloseStream forgets a feeder.
        let mix = ShapeMix::new(64);
        let key = ShapeKey::feed(3, 4);
        assert_eq!(mix.record_feeder(key, 1), 1);
        assert_eq!(mix.record_feeder(key, 2), 2);
        // Session 1 spills and reloads: no mix call happens in between,
        // so its very next feed after reload is still peer #2.
        assert_eq!(mix.record_feeder(key, 1), 2, "reloaded session lost its slot");
        // Closing really does forget: the survivor no longer sees a peer.
        mix.forget_feeder(key, 1);
        assert_eq!(mix.record_feeder(key, 2), 1, "closed session still quoted as a peer");
    }

    #[test]
    fn feeder_ring_evicts_stalest_slot() {
        let mix = ShapeMix::new(64);
        let key = ShapeKey::feed(2, 2);
        for s in 0..(FEEDER_SLOTS as u64 + 2) {
            mix.record_feeder(key, s);
        }
        // Ring is full of the newest FEEDER_SLOTS sessions, all recent.
        assert_eq!(mix.record_feeder(key, 99), FEEDER_SLOTS);
    }
}
