//! **Unified execution planner**: one adaptive dispatch layer for every
//! execution strategy the CPU engine has grown.
//!
//! The paper's speed claims come from picking the right batching strategy
//! per workload. The crate now has three:
//!
//! - **Scalar**: one serial fused sweep per path (the paper's "CPU no
//!   parallel" column; batched work distributes paths over threads).
//! - **Stream-parallel**: the chunked Chen-identity factorisation inside a
//!   single path — the ⊠-reduction forward (§5.1) and the chunked
//!   backward of [`crate::signature::backward`].
//! - **Lane-fused**: blocks of same-spec signatures advancing together
//!   through the lane-interleaved kernels of [`crate::ta::batch`],
//!   vectorised *across* the batch — the serving regime winner (many
//!   short streams, small `d`), bitwise identical per lane to scalar.
//!   The block width is a *runtime* choice ([`lane_width`]) among
//!   [`LANE_WIDTHS`], keyed on `(d, depth, dtype)`: small signatures run
//!   64-wide, large ones fall back to the [`LANE_BLOCK`] floor so one
//!   block's state stays cache-resident.
//!
//! Before this module, the choice between them was re-derived inline at
//! every call site (`signature_batch`, `signature_batch_vjp`,
//! `deepsig::train_step`, the coordinator's router). [`ExecPlanner`] owns
//! that choice: callers describe the work as a [`WorkShape`] — which since
//! the precision axis landed includes the element dtype
//! ([`Precision::F32`]/[`Precision::F64`]) — and execute whatever
//! [`ExecPlan`] comes back. The **logsignature** pipeline executes the
//! same plans ([`crate::logsignature::batch`]): its work shape is the
//! underlying signature sweep's shape, the log + basis projection is a
//! per-lane epilogue that never changes the schedule — so logsig traffic
//! keys the shape mix under its own [`ShapeKey`] kind and otherwise needs
//! nothing planner-specific. The lane-fused backward is available at
//! **every** dimension: the scalar VJP dispatches to monomorphised bodies
//! for `d ≤` [`LANE_VJP_MAX_D`] and to the runtime-`d`
//! `fused_mexp_vjp_dyn` beyond, and the batched twin mirrors both
//! op-for-op, so the planner no longer refuses `LaneFused` backward at
//! `d >` [`LANE_VJP_MAX_D`]. The serving layer
//! additionally feeds the planner an observed **shape-mix histogram**
//! ([`ShapeMix`]) so microbatch formation adapts to recent traffic
//! instead of obeying one static knob — see
//! [`ExecPlanner::microbatch_capacity`] and
//! [`ExecPlanner::feed_lane_capacity`].
//!
//! Keeping selection in one layer is also what makes the next backend a
//! one-layer change: lowering `ExecPlan::LaneFused` onto the XLA/GPU path
//! (the lane-interleaved layout *is* the batched-kernel layout) swaps the
//! executor for a plan, not N call sites — and logsignature plans lower
//! through the same path, their epilogue staying host-side (or fusing as
//! a gather, for the Words basis).

mod mix;

pub use mix::{ShapeKey, ShapeMix, MIX_WARMUP};

use crate::ta::Precision;

/// The narrowest lane tier, and the width every shape is guaranteed:
/// bounds the batched workspace (a few signatures' worth per block)
/// while filling the widest SIMD registers even for large signatures.
/// Group-granularity consumers (shard placement, the sharded fan-out,
/// the default microbatch capacity) key on this floor; the *runtime*
/// block for a lane-fused plan is chosen per shape by [`lane_width`]
/// among [`LANE_WIDTHS`] and may be wider.
pub const LANE_BLOCK: usize = 16;

/// The lane-width tiers the planner chooses among at plan time, keyed
/// on `(d, depth, dtype)`: small signatures run wider blocks (more
/// lanes amortising each increment's sweep), large signatures fall back
/// toward the [`LANE_BLOCK`] floor so one block's interleaved state
/// stays cache-resident. Per-lane results are independent of the block
/// partition, so the choice is pure scheduling — never values.
pub const LANE_WIDTHS: [usize; 3] = [16, 32, 64];

/// Widest tier in [`LANE_WIDTHS`]; executors clamp untrusted plan
/// blocks to this rather than to [`LANE_BLOCK`].
pub const MAX_LANE_WIDTH: usize = 64;

/// Per-block workspace budget (bytes) that [`lane_width`] fits the
/// interleaved lane state into: `width * sig_len * size_of(dtype)` must
/// stay under this (≈ half a typical per-core L2) for a wider tier to
/// be worth it — beyond that the sweep goes memory-bound and wider
/// blocks only evict each other.
const LANE_WORKSPACE_BUDGET: usize = 256 * 1024;

/// Minimum effective points before stream parallelism engages on the
/// *forward* pass; below this the chunk bookkeeping costs more than the
/// serial sweep.
pub const PARALLEL_FORWARD_MIN_POINTS: usize = 16;

/// Minimum effective points before the chunked Chen *backward* engages;
/// the backward pays two extra ⊠-VJPs per chunk, so its floor is higher
/// than the forward's.
pub const PARALLEL_BACKWARD_MIN_POINTS: usize = 32;

/// Largest `d` with a monomorphised scalar VJP kernel. This is a
/// **dispatch crossover**, not a planner ceiling: beyond it the scalar
/// side runs the runtime-`d` `fused_mexp_vjp_dyn`, which replays the same
/// op order as the mono bodies and the lane-fused batched backward, so
/// `LaneFused` plans stay bitwise-exact at every `d`.
pub const LANE_VJP_MAX_D: usize = 8;

/// The shape of one unit of signature work, as the planner sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkShape {
    /// Paths in the batch (1 = a single path).
    pub batch: usize,
    /// Effective points per path, including any basepoint.
    pub points: usize,
    /// Path channels.
    pub d: usize,
    /// Truncation depth.
    pub depth: usize,
    /// Element precision the kernels will run in. Scheduling rules are
    /// precision-independent, but the dtype is part of the shape's
    /// identity: f32 and f64 work never share a lane block or microbatch.
    pub dtype: Precision,
}

/// An execution strategy chosen by the planner.
///
/// Plans describe *scheduling only*: for a given input, every plan of the
/// same pass computes the same values (Scalar and LaneFused are bitwise
/// identical to each other; StreamParallel re-associates ⊠ and agrees to
/// f32 rounding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecPlan {
    /// One serial fused sweep per path; a batch distributes paths over the
    /// thread budget. The bitwise-reference strategy.
    Scalar,
    /// Chunked Chen-identity parallelism over the stream *inside* each
    /// path, with `threads` chunks per path (batched callers additionally
    /// distribute paths over the budget).
    StreamParallel {
        /// Chunk-level parallelism within one path.
        threads: usize,
    },
    /// Lane-fused across the batch: blocks of `block` lanes advance
    /// through one interleaved sweep each, blocks distributed over the
    /// thread budget. Bitwise identical per lane to `Scalar`.
    LaneFused {
        /// Lanes per block (≤ [`MAX_LANE_WIDTH`]; the planner picks the
        /// shape's tier via [`lane_width`]).
        block: usize,
    },
}

/// Owns strategy selection for every execution site, plus the observed
/// shape mix that drives the serving layer's adaptive microbatching.
///
/// Construction is cheap; library entry points build a transient planner
/// from their thread budget, while the coordinator keeps one long-lived
/// instance so the shape mix accumulates across requests.
pub struct ExecPlanner {
    threads: usize,
    mix: ShapeMix,
}

impl ExecPlanner {
    /// A planner with the given thread budget and the default shape-mix
    /// window.
    pub fn new(threads: usize) -> ExecPlanner {
        ExecPlanner { threads: threads.max(1), mix: ShapeMix::default() }
    }

    /// A planner with an explicit shape-mix window (serving: see
    /// [`crate::coordinator::DispatchConfig::mix_window`]).
    pub fn with_mix_window(threads: usize, window: usize) -> ExecPlanner {
        ExecPlanner { threads: threads.max(1), mix: ShapeMix::new(window) }
    }

    /// The thread budget this planner plans for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The observed shape mix (serving gauges read `distinct()`).
    pub fn mix(&self) -> &ShapeMix {
        &self.mix
    }

    /// Strategy for a *forward* signature pass.
    ///
    /// - `batch == 1`: stream-parallel when there are threads to use and
    ///   at least [`PARALLEL_FORWARD_MIN_POINTS`] effective points,
    ///   otherwise scalar.
    /// - `batch >= 2`: lane-fused. The block adapts to the thread budget
    ///   and the shape's lane tier: every thread gets a block before
    ///   blocks grow toward the width [`lane_width`] picks for
    ///   `(d, depth, dtype)` (a single full-width block would serialise
    ///   any batch ≤ width no matter how many threads were requested).
    ///   Per-lane results are independent of the partition.
    pub fn plan_forward(&self, s: &WorkShape) -> ExecPlan {
        if s.batch <= 1 {
            if self.threads > 1 && s.points >= PARALLEL_FORWARD_MIN_POINTS {
                ExecPlan::StreamParallel { threads: self.threads }
            } else {
                ExecPlan::Scalar
            }
        } else {
            let width = lane_width(s.d, s.depth, s.dtype);
            ExecPlan::LaneFused { block: lane_block(s.batch, self.threads, width) }
        }
    }

    /// Strategy for a *backward* (VJP) pass.
    ///
    /// - `batch == 1`: chunked Chen stream parallelism when there are
    ///   threads and ≥ [`PARALLEL_BACKWARD_MIN_POINTS`] effective points.
    /// - `batch >= 2` with surplus threads (`threads > batch`): per-path
    ///   dispatch with the spare threads spread over each path's stream.
    /// - `batch >= 2` otherwise: the lane-fused batched reverse sweep, at
    ///   **any** `d` (bitwise identical to per-path serial — the scalar
    ///   dispatcher's mono bodies for `d ≤` [`LANE_VJP_MAX_D`] and the
    ///   runtime-`d` `fused_mexp_vjp_dyn` beyond both replay the lane
    ///   kernel's op order, so the old `d > 8` scalar fallback is gone).
    pub fn plan_backward(&self, s: &WorkShape) -> ExecPlan {
        if s.batch <= 1 {
            if self.threads > 1 && s.points >= PARALLEL_BACKWARD_MIN_POINTS {
                ExecPlan::StreamParallel { threads: self.threads }
            } else {
                ExecPlan::Scalar
            }
        } else {
            let stream_threads = (self.threads / s.batch).max(1);
            if stream_threads > 1 {
                ExecPlan::StreamParallel { threads: stream_threads }
            } else {
                let width = lane_width(s.d, s.depth, s.dtype);
                ExecPlan::LaneFused { block: lane_block(s.batch, self.threads, width) }
            }
        }
    }

    /// Strategy for one flushed native serving microbatch of `rows`
    /// same-spec signatures.
    ///
    /// A lone row always runs the serial scalar sweep — a request's bits
    /// must not depend on whether traffic happened to coalesce with it
    /// (the stream-parallel forward re-associates ⊠). Multi-row flushes
    /// lane-fuse like any batch.
    pub fn plan_native_flush(&self, rows: usize, s: &WorkShape) -> ExecPlan {
        if rows <= 1 {
            ExecPlan::Scalar
        } else {
            self.plan_forward(&WorkShape { batch: rows, ..*s })
        }
    }

    /// Strategy for the batched window-slide sweep that follows a
    /// feed-lane flush: `lanes` windowed sessions of one `(d, depth,
    /// dtype)` group advancing their rolling windows together.
    ///
    /// Below two lanes the per-session scalar advance runs — a lone
    /// windowed streamer never pays lane pack/repack overhead for a batch
    /// of one. From two lanes up the sweep lane-fuses through the batched
    /// Chen kernels (bitwise identical per lane either way, so this is a
    /// scheduling decision only, like every other plan).
    pub fn plan_window_sweep(&self, lanes: usize, s: &WorkShape) -> ExecPlan {
        if lanes < 2 {
            ExecPlan::Scalar
        } else {
            let width = lane_width(s.d, s.depth, s.dtype);
            ExecPlan::LaneFused { block: lane_block(lanes, self.threads, width) }
        }
    }

    /// Record one observed request shape into the mix histogram.
    pub fn record_shape(&self, key: ShapeKey) {
        self.mix.record(key);
    }

    /// Adaptive microbatch capacity for a stateless signature shape.
    ///
    /// `base` is the configured capacity ceiling (the old `native_batch`
    /// knob); `0` is the documented escape hatch and passes through
    /// unchanged (microbatching disabled — no linger, ever). During
    /// warm-up (fewer than [`MIX_WARMUP`] recorded shapes) the base
    /// applies as-is. After warm-up, a shape whose share of recent
    /// traffic promises at least one same-shape peer within a base-sized
    /// window keeps the full capacity; rarer shapes get capacity 1 — they
    /// execute directly instead of idling out the linger waiting for
    /// peers that recent traffic says will not come.
    pub fn microbatch_capacity(&self, base: usize, key: ShapeKey) -> usize {
        if base < 2 {
            return base;
        }
        let (count, total) = self.mix.count_and_total(key);
        if total < MIX_WARMUP as u64 {
            return base;
        }
        if count.saturating_mul(base as u64) >= total {
            base
        } else {
            1
        }
    }

    /// Adaptive capacity for the *feed lane* (stateful session feeds).
    ///
    /// Lane-fusing feeds only pays when at least two **distinct sessions**
    /// feed the same spec concurrently; a single session's feed stream
    /// must never idle out the linger (feeds were latency-direct before
    /// the lane existed). Records the feeder and returns the lane
    /// capacity: the observed number of distinct recent feeders (clamped
    /// to `base`) when there are at least two — so a complete group of
    /// concurrent feeders *fills* its pending batch and executes inline
    /// instead of waiting out the linger — and 1 (direct scalar feed)
    /// for a lone feeder. `base < 2` passes through (0 = disabled).
    pub fn feed_lane_capacity(&self, base: usize, key: ShapeKey, session: u64) -> usize {
        if base < 2 {
            return base;
        }
        let distinct = self.mix.record_feeder(key, session);
        if distinct >= 2 {
            distinct.min(base)
        } else {
            1
        }
    }

    /// Drop `session` from `key`'s recent-feeder ring — called when a
    /// session closes, so a surviving lone feeder drops back to the
    /// direct path immediately instead of paying the linger until the
    /// closed peer ages out of the recency window. (Evicted/expired
    /// sessions are not forgotten eagerly; they age out after
    /// [`ShapeMix`]'s feeder window.)
    pub fn forget_feeder(&self, key: ShapeKey, session: u64) {
        self.mix.forget_feeder(key, session);
    }
}

/// Runtime lane-width choice for a `(d, depth, dtype)` shape: the widest
/// tier in [`LANE_WIDTHS`] whose interleaved block state
/// (`width * sig_len * size_of(dtype)` bytes) fits the per-block
/// workspace budget, floored at [`LANE_BLOCK`]. The signature length is
/// computed with saturating arithmetic so absurd shapes degrade to the
/// floor instead of overflowing. Benches sweep every tier per shape
/// (`bench batch` records the sweep in `BENCH_batch.json`); serving and
/// the library entry points take this one answer.
pub fn lane_width(d: usize, depth: usize, dtype: Precision) -> usize {
    let mut sig_len = 0usize;
    let mut pow = 1usize;
    for _ in 0..depth {
        pow = pow.saturating_mul(d);
        sig_len = sig_len.saturating_add(pow);
    }
    let row_bytes = sig_len.saturating_mul(dtype.size_of()).max(1);
    LANE_WIDTHS
        .into_iter()
        .filter(|w| w.saturating_mul(row_bytes) <= LANE_WORKSPACE_BUDGET)
        .max()
        .unwrap_or(LANE_BLOCK)
}

/// Shared lane-block rule: `ceil(batch / threads)` capped at the shape's
/// lane `width`. Forward and backward use the same rule so both passes
/// always pick the same schedule for a given shape.
fn lane_block(batch: usize, threads: usize, width: usize) -> usize {
    batch.div_ceil(threads.max(1)).min(width).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(batch: usize, points: usize, d: usize) -> WorkShape {
        WorkShape { batch, points, d, depth: 4, dtype: Precision::F32 }
    }

    #[test]
    fn window_sweep_gate_is_two_lanes() {
        // A lone windowed streamer never pays repack overhead; from two
        // lanes up the slide sweep lane-fuses.
        let p = ExecPlanner::new(4);
        assert_eq!(p.plan_window_sweep(0, &shape(1, 64, 2)), ExecPlan::Scalar);
        assert_eq!(p.plan_window_sweep(1, &shape(1, 64, 2)), ExecPlan::Scalar);
        assert!(matches!(
            p.plan_window_sweep(2, &shape(2, 64, 2)),
            ExecPlan::LaneFused { .. }
        ));
        assert!(matches!(
            p.plan_window_sweep(16, &shape(16, 64, 2)),
            ExecPlan::LaneFused { .. }
        ));
    }

    #[test]
    fn forward_single_path_decisions() {
        // Serial when single-threaded or the stream is short.
        let p1 = ExecPlanner::new(1);
        assert_eq!(p1.plan_forward(&shape(1, 1000, 3)), ExecPlan::Scalar);
        let p8 = ExecPlanner::new(8);
        assert_eq!(
            p8.plan_forward(&shape(1, PARALLEL_FORWARD_MIN_POINTS - 1, 3)),
            ExecPlan::Scalar
        );
        assert_eq!(
            p8.plan_forward(&shape(1, PARALLEL_FORWARD_MIN_POINTS, 3)),
            ExecPlan::StreamParallel { threads: 8 }
        );
    }

    #[test]
    fn forward_batches_lane_fuse_with_thread_adaptive_blocks() {
        // Every thread gets a block before blocks widen toward the
        // shape's lane tier (64 for d=2/depth=4 — sig_len 30 is tiny).
        let p4 = ExecPlanner::new(4);
        assert_eq!(p4.plan_forward(&shape(8, 32, 2)), ExecPlan::LaneFused { block: 2 });
        assert_eq!(p4.plan_forward(&shape(64, 32, 2)), ExecPlan::LaneFused { block: 16 });
        // threads > batch: one lane per block, blocks spread over threads.
        let p8 = ExecPlanner::new(8);
        assert_eq!(p8.plan_forward(&shape(3, 32, 2)), ExecPlan::LaneFused { block: 1 });
        // Single thread: blocks widen past the old 16-lane ceiling up to
        // the shape's tier — 40 lanes in one block here, capped at 64.
        let p1 = ExecPlanner::new(1);
        assert_eq!(p1.plan_forward(&shape(40, 32, 2)), ExecPlan::LaneFused { block: 40 });
        assert_eq!(
            p1.plan_forward(&shape(100, 32, 2)),
            ExecPlan::LaneFused { block: MAX_LANE_WIDTH }
        );
        // A big signature (d=8/depth=4, sig_len 4680) stays on the
        // 16-lane floor: its interleaved state would blow the workspace
        // budget at any wider tier.
        assert_eq!(
            p1.plan_forward(&shape(40, 32, 8)),
            ExecPlan::LaneFused { block: LANE_BLOCK }
        );
    }

    #[test]
    fn lane_width_keys_on_signature_footprint_and_dtype() {
        // Tiny rows fill the widest tier in either precision.
        assert_eq!(lane_width(2, 4, Precision::F32), 64);
        assert_eq!(lane_width(2, 4, Precision::F64), 64);
        // d=5/depth=4 (sig_len 780): f64 rows are twice as wide, so the
        // same shape sits one tier narrower than f32.
        assert_eq!(lane_width(5, 4, Precision::F32), 64);
        assert_eq!(lane_width(5, 4, Precision::F64), 32);
        // d=6/depth=4 (sig_len 1554): mid tier for f32, floor for f64.
        assert_eq!(lane_width(6, 4, Precision::F32), 32);
        assert_eq!(lane_width(6, 4, Precision::F64), 16);
        // Past the budget at every tier the floor still applies — wider
        // would thrash, narrower would starve the SIMD lanes.
        assert_eq!(lane_width(8, 4, Precision::F32), LANE_BLOCK);
        assert_eq!(lane_width(9, 4, Precision::F64), LANE_BLOCK);
        // Absurd shapes saturate instead of overflowing.
        assert_eq!(lane_width(usize::MAX, 30, Precision::F64), LANE_BLOCK);
    }

    #[test]
    fn backward_decisions_across_corners() {
        // batch = 1: stream-parallel only past the backward floor.
        let p8 = ExecPlanner::new(8);
        assert_eq!(
            p8.plan_backward(&shape(1, PARALLEL_BACKWARD_MIN_POINTS - 1, 2)),
            ExecPlan::Scalar
        );
        assert_eq!(
            p8.plan_backward(&shape(1, PARALLEL_BACKWARD_MIN_POINTS, 2)),
            ExecPlan::StreamParallel { threads: 8 }
        );
        // Surplus threads (threads > batch): spread over each stream.
        assert_eq!(
            p8.plan_backward(&shape(2, 80, 2)),
            ExecPlan::StreamParallel { threads: 4 }
        );
        // threads <= batch at small d: lane-fused.
        let p3 = ExecPlanner::new(3);
        assert_eq!(p3.plan_backward(&shape(6, 32, 8)), ExecPlan::LaneFused { block: 2 });
        // d > LANE_VJP_MAX_D no longer falls off the lane VJP: the
        // runtime-d scalar body keeps bitwise parity past the mono window.
        assert_eq!(p3.plan_backward(&shape(6, 32, 9)), ExecPlan::LaneFused { block: 2 });
        // batch = 1 single thread.
        let p1 = ExecPlanner::new(1);
        assert_eq!(p1.plan_backward(&shape(1, 4096, 2)), ExecPlan::Scalar);
    }

    #[test]
    fn backward_plans_lane_fused_beyond_the_mono_window() {
        // The dimensions the issue pins: d ∈ {9, 12, 20} all plan
        // LaneFused backward once threads ≤ batch, in both precisions.
        let p2 = ExecPlanner::new(2);
        for d in [9usize, 12, 20] {
            for dtype in [Precision::F32, Precision::F64] {
                let s = WorkShape { batch: 8, points: 32, d, depth: 3, dtype };
                assert_eq!(
                    p2.plan_backward(&s),
                    ExecPlan::LaneFused { block: 4 },
                    "d={d} {dtype:?}"
                );
            }
        }
        // Surplus-thread and single-path rules are untouched at large d.
        let p8 = ExecPlanner::new(8);
        assert_eq!(
            p8.plan_backward(&WorkShape { batch: 2, points: 80, d: 12, depth: 3, dtype: Precision::F64 }),
            ExecPlan::StreamParallel { threads: 4 }
        );
        assert_eq!(
            ExecPlanner::new(1)
                .plan_backward(&WorkShape { batch: 1, points: 16, d: 20, depth: 3, dtype: Precision::F32 }),
            ExecPlan::Scalar
        );
    }

    #[test]
    fn native_flush_lone_row_is_always_scalar() {
        // A request's bits must not depend on traffic coalescing: one real
        // row never takes the stream-parallel (re-associating) forward,
        // however long the stream and large the thread budget.
        let p = ExecPlanner::new(16);
        assert_eq!(p.plan_native_flush(1, &shape(1, 4096, 2)), ExecPlan::Scalar);
        assert_eq!(
            p.plan_native_flush(6, &shape(1, 64, 2)),
            ExecPlan::LaneFused { block: 1 }
        );
    }

    #[test]
    fn microbatch_capacity_adapts_to_shape_mix() {
        let p = ExecPlanner::with_mix_window(4, 64);
        let hot = ShapeKey::signature(2, 3, 8);
        let rare = ShapeKey::signature(5, 3, 9);
        // Escape hatch and direct mode pass through untouched.
        assert_eq!(p.microbatch_capacity(0, hot), 0);
        assert_eq!(p.microbatch_capacity(1, hot), 1);
        // Warm-up: base applies while the histogram is empty.
        assert_eq!(p.microbatch_capacity(8, hot), 8);
        // Overwhelmingly hot shape keeps full capacity; the rare shape
        // (1 of 65 recent requests, share < 1/8) drops to direct.
        for _ in 0..64 {
            p.record_shape(hot);
        }
        p.record_shape(rare);
        assert_eq!(p.microbatch_capacity(8, hot), 8);
        assert_eq!(p.microbatch_capacity(8, rare), 1);
        // If the "rare" shape becomes a real share of traffic, capacity
        // returns — records keep flowing regardless of dispatch path.
        for _ in 0..32 {
            p.record_shape(rare);
        }
        assert_eq!(p.microbatch_capacity(8, rare), 8);
    }

    #[test]
    fn feed_lane_capacity_tracks_distinct_sessions() {
        let p = ExecPlanner::with_mix_window(4, 64);
        let key = ShapeKey::feed(3, 4);
        // A single session feeding never lingers.
        for _ in 0..10 {
            assert_eq!(p.feed_lane_capacity(8, key, 101), 1);
        }
        // A second session on the same spec opens a lane sized to the
        // observed concurrency, so a full group flushes inline instead of
        // idling out the linger.
        assert_eq!(p.feed_lane_capacity(8, key, 202), 2);
        assert_eq!(p.feed_lane_capacity(8, key, 101), 2);
        assert_eq!(p.feed_lane_capacity(8, key, 303), 3);
        // The quote is clamped to the configured base.
        assert_eq!(p.feed_lane_capacity(2, key, 202), 2);
        // Different spec keys are independent.
        assert_eq!(p.feed_lane_capacity(8, ShapeKey::feed(2, 2), 101), 1);
        // Disabled passes through.
        assert_eq!(p.feed_lane_capacity(0, key, 101), 0);
    }

    #[test]
    fn closed_sessions_are_forgotten_immediately() {
        // The surviving feeder must drop back to the direct path on the
        // very next feed after its peer closes — not `FEEDER_WINDOW`
        // records later.
        let p = ExecPlanner::with_mix_window(4, 64);
        let key = ShapeKey::feed(3, 4);
        p.feed_lane_capacity(8, key, 1);
        assert_eq!(p.feed_lane_capacity(8, key, 2), 2);
        p.forget_feeder(key, 2);
        assert_eq!(p.feed_lane_capacity(8, key, 1), 1, "lone survivor serves direct");
        // Forgetting an unknown session/key is a no-op.
        p.forget_feeder(ShapeKey::feed(9, 9), 7);
    }
}
