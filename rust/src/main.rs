//! signax CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//! - `tables`    — regenerate the paper's benchmark tables/figures.
//! - `sig`       — compute a signature of a random or CSV path.
//! - `logsig`    — compute a logsignature (basis selectable).
//! - `train`     — train the deep signature model (§6.2, Fig 3), comparing
//!                 backends; writes the loss-vs-wallclock curve.
//! - `serve`     — run a synthetic serving workload through the
//!                 coordinator (router + dynamic batcher) and print
//!                 throughput/latency + metrics.
//! - `serve-stream` — run a stateful streaming workload (open / feed /
//!                 interval-query / close sessions) through the
//!                 coordinator, with optional memory budget and idle TTL;
//!                 `--state-dir` makes sessions durable (spill-to-disk
//!                 eviction + warm-restart recovery) and `--shards` runs
//!                 N id-striped logical coordinators.
//! - `info`      — artifact registry / platform diagnostics.

use std::io::Write as _;
use std::time::{Duration, Instant};

use signax::bench::{run_table, table_ids, BenchCtx, Scale};
use signax::coordinator::{Coordinator, CoordinatorConfig, Request, SessionConfig, ShardedCoordinator};
use signax::state::SpillConfig;
use signax::data::gbm::{gbm_batch, GbmConfig};
use signax::deepsig::{accuracy, train_step, ModelConfig, Params, SigBackend};
use signax::logsignature::{logsignature_with, LogSigBasis, LogSigPlan};
use signax::runtime::EngineHandle;
use signax::signature::{signature, SigConfig};
use signax::substrate::cli::{Cli, Command};
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

fn cli() -> Cli {
    Cli {
        prog: "signax",
        about: "signature & logsignature transforms: native engine, AOT-XLA runtime, coordinator",
        commands: vec![
            Command::new("tables", "regenerate the paper's benchmark tables")
                .opt("table", "table id (1..16, opcount, path, memory, backward, batch) or 'all'", "all")
                .opt("scale", "paper | small | ci", "small")
                .opt("artifacts", "artifact directory for the XLA column", "artifacts")
                .opt("out", "directory for CSV output", "results"),
            Command::new("sig", "compute a signature of a random path")
                .opt("channels", "path channels d", "4")
                .opt("depth", "truncation depth N", "4")
                .opt("stream", "number of points L", "128")
                .opt("seed", "rng seed", "0")
                .flag("parallel", "use the chunked stream reduction"),
            Command::new("logsig", "compute a logsignature of a random path")
                .opt("channels", "path channels d", "4")
                .opt("depth", "truncation depth N", "4")
                .opt("stream", "number of points L", "128")
                .opt("basis", "words | lyndon | expanded", "words")
                .opt("seed", "rng seed", "0"),
            Command::new("train", "train the deep signature model (Fig 3)")
                .opt("steps", "training steps", "200")
                .opt("batch", "batch size", "32")
                .opt("stream", "sequence length", "64")
                .opt("lr", "learning rate", "1.0")
                .opt("backend", "fused | conventional | xla | all", "all")
                .opt("artifacts", "artifact directory (xla backend)", "artifacts")
                .opt("out", "loss-curve CSV directory", "results"),
            Command::new("serve", "synthetic serving workload through the coordinator")
                .opt("requests", "total requests", "256")
                .opt("concurrency", "concurrent client threads", "16")
                .opt("stream", "points per request", "128")
                .opt("channels", "channels", "4")
                .opt("depth", "depth", "4")
                .opt("artifacts", "artifact directory", "artifacts")
                .flag("native-only", "disable the XLA backend"),
            Command::new("serve-stream", "stateful streaming workload through the coordinator")
                .opt("sessions", "concurrent streaming sessions (one client thread each)", "8")
                .opt("feeds", "feed requests per session", "64")
                .opt("feed-points", "points appended per feed", "32")
                .opt("channels", "channels", "3")
                .opt("depth", "depth", "4")
                .opt("query-every", "interval query after every K feeds (0 = never)", "8")
                .opt("budget-mb", "session memory budget, MiB (0 = unbounded)", "0")
                .opt("ttl-ms", "evict sessions idle for this long, ms (0 = off)", "0")
                .opt(
                    "state-dir",
                    "durable session state dir: eviction spills here instead of destroying, \
                     and a restart with the same dir recovers every live session (empty = off)",
                    "",
                )
                .opt("shards", "logical coordinator shards (session ids stripe across them)", "1"),
            Command::new("info", "artifact registry / platform diagnostics")
                .opt("artifacts", "artifact directory", "artifacts"),
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let (cmd, args) = match cli.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.name {
        "tables" => cmd_tables(&args),
        "sig" => cmd_sig(&args),
        "logsig" => cmd_logsig(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "serve-stream" => cmd_serve_stream(&args),
        "info" => cmd_info(&args),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_tables(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    let scale = Scale::parse(args.get_or("scale", "small"))?;
    let which = args.get_or("table", "all");
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let ctx = BenchCtx::new(scale, Some(args.get_or("artifacts", "artifacts").into()));
    if ctx.xla.is_none() {
        eprintln!("note: no artifacts found — the `signax XLA` column will be dashes");
    }
    let ids: Vec<String> = if which == "all" {
        table_ids().into_iter().map(|s| s.to_string()).collect()
    } else {
        which.split(',').map(|s| s.trim().to_string()).collect()
    };
    for id in &ids {
        let t0 = Instant::now();
        let table = run_table(&ctx, id)?;
        println!("{}", table.render());
        println!("[table {id} took {:.1}s]\n", t0.elapsed().as_secs_f64());
        let csv_path = out_dir.join(format!("table_{id}.csv"));
        std::fs::write(&csv_path, table.to_csv())?;
    }
    println!("CSV written to {}", out_dir.display());
    Ok(())
}

fn cmd_sig(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    let d = args.get_usize("channels", 4)?;
    let depth = args.get_usize("depth", 4)?;
    let stream = args.get_usize("stream", 128)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let spec = SigSpec::new(d, depth)?;
    let mut rng = Rng::new(seed);
    let path = signax::data::random_path(&mut rng, stream, d, 0.2);
    let t0 = Instant::now();
    let sig = if args.flag("parallel") {
        signax::signature::signature_with(
            &path,
            stream,
            &spec,
            &signax::signature::SigConfig::parallel(signax::substrate::pool::default_threads()),
        )?
    } else {
        signature(&path, stream, &spec)
    };
    let dt = t0.elapsed();
    println!(
        "Sig^{depth} of a {stream}x{d} path: {} values in {:.3}ms",
        sig.len(),
        dt.as_secs_f64() * 1e3
    );
    println!("level 1 (= total increment): {:?}", &sig[..d.min(8)]);
    Ok(())
}

fn cmd_logsig(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    let d = args.get_usize("channels", 4)?;
    let depth = args.get_usize("depth", 4)?;
    let stream = args.get_usize("stream", 128)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let basis = match args.get_or("basis", "words") {
        "words" => LogSigBasis::Words,
        "lyndon" => LogSigBasis::Lyndon,
        "expanded" => LogSigBasis::Expanded,
        other => anyhow::bail!("unknown basis {other:?}"),
    };
    let spec = SigSpec::new(d, depth)?;
    let plan = LogSigPlan::new(&spec, basis)?;
    let mut rng = Rng::new(seed);
    let path = signax::data::random_path(&mut rng, stream, d, 0.2);
    let t0 = Instant::now();
    let z = logsignature_with(&path, stream, &spec, &plan, &SigConfig::serial())?;
    println!(
        "LogSig^{depth} ({basis:?}) of a {stream}x{d} path: {} values in {:.3}ms (witt={})",
        z.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        signax::words::witt_dimension(d, depth)
    );
    Ok(())
}

fn cmd_train(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    let steps = args.get_usize("steps", 200)?;
    let batch = args.get_usize("batch", 32)?;
    let stream = args.get_usize("stream", 64)?;
    let lr = args.get_f64("lr", 1.0)? as f32;
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let backend_arg = args.get_or("backend", "all");
    let backends: Vec<&str> = if backend_arg == "all" {
        vec!["fused", "conventional", "xla"]
    } else {
        vec![backend_arg]
    };
    let cfg = ModelConfig::default();
    let gcfg = GbmConfig { stream, ..Default::default() };

    for backend in backends {
        let mut rng = Rng::new(2024);
        let p0 = Params::init(&cfg, &mut rng);
        let (x, y) = gbm_batch(&mut rng, batch, &gcfg);
        let (xt, yt) = gbm_batch(&mut rng, 256, &gcfg);
        let mut curve: Vec<(f64, f32)> = vec![];
        let t0 = Instant::now();
        match backend {
            "fused" | "conventional" => {
                let be = if backend == "fused" { SigBackend::Fused } else { SigBackend::Conventional };
                let mut p = p0.clone();
                for s in 0..steps {
                    let loss = train_step(
                        &cfg,
                        &mut p,
                        &x,
                        &y,
                        lr,
                        be,
                        signax::substrate::pool::default_threads(),
                    );
                    curve.push((t0.elapsed().as_secs_f64(), loss));
                    if s % 50 == 0 {
                        println!("[{backend}] step {s}: loss {loss:.4}");
                    }
                }
                println!(
                    "[{backend}] {steps} steps in {:.2}s, final loss {:.4}, test acc {:.3}",
                    t0.elapsed().as_secs_f64(),
                    curve.last().unwrap().1,
                    accuracy(&cfg, &p, &xt, &yt)
                );
            }
            "xla" => {
                let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
                if !dir.join("MANIFEST.json").exists() {
                    eprintln!("[xla] skipped: no artifacts (run `make artifacts`)");
                    continue;
                }
                let (engine, registry) = EngineHandle::spawn(dir)?;
                let entry = registry
                    .train()
                    .ok_or_else(|| anyhow::anyhow!("no train artifact"))?
                    .clone();
                anyhow::ensure!(
                    entry.batch == batch && entry.length == stream,
                    "train artifact is for batch={} stream={}; pass matching --batch/--stream",
                    entry.batch,
                    entry.length
                );
                let mut bufs = p0.to_buffers();
                engine.warm(&entry)?;
                for s in 0..steps {
                    let (nb, loss) = engine.train_step(&entry, bufs, x.clone(), y.clone(), lr)?;
                    bufs = nb;
                    curve.push((t0.elapsed().as_secs_f64(), loss));
                    if s % 50 == 0 {
                        println!("[xla] step {s}: loss {loss:.4}");
                    }
                }
                let p = Params::from_buffers(&cfg, &bufs);
                println!(
                    "[xla] {steps} steps in {:.2}s, final loss {:.4}, test acc {:.3}",
                    t0.elapsed().as_secs_f64(),
                    curve.last().unwrap().1,
                    accuracy(&cfg, &p, &xt, &yt)
                );
            }
            other => anyhow::bail!("unknown backend {other:?}"),
        }
        // Write the loss-vs-wallclock curve (Fig 3).
        let mut f = std::fs::File::create(out_dir.join(format!("fig3_loss_{backend}.csv")))?;
        writeln!(f, "wallclock_s,loss")?;
        for (t, l) in &curve {
            writeln!(f, "{t},{l}")?;
        }
    }
    println!("loss curves written to {}", out_dir.display());
    Ok(())
}

fn cmd_serve(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    let n_requests = args.get_usize("requests", 256)?;
    let concurrency = args.get_usize("concurrency", 16)?;
    let stream = args.get_usize("stream", 128)?;
    let d = args.get_usize("channels", 4)?;
    let depth = args.get_usize("depth", 4)?;
    let coord = Coordinator::new(if args.flag("native-only") {
        CoordinatorConfig::native_only()
    } else {
        CoordinatorConfig {
            artifact_dir: Some(args.get_or("artifacts", "artifacts").into()),
            ..Default::default()
        }
    })?;
    println!("coordinator up (xla backend: {})", coord.has_xla());
    let mut rng = Rng::new(7);
    let reqs: Vec<Request> = (0..n_requests)
        .map(|_| Request::Signature {
            path: signax::data::random_path(&mut rng, stream, d, 0.2).into(),
            stream,
            d,
            depth,
        })
        .collect();
    let t0 = Instant::now();
    // Issue with bounded concurrency.
    let chunks: Vec<Vec<Request>> = reqs.chunks(concurrency).map(|c| c.to_vec()).collect();
    let mut ok = 0usize;
    for chunk in chunks {
        for r in coord.call_many(chunk) {
            if r.is_ok() {
                ok += 1;
            }
        }
    }
    let dt = t0.elapsed();
    let snap = coord.metrics().snapshot();
    println!(
        "{ok}/{n_requests} ok in {:.2}s  ({:.0} req/s, mean latency {:?})",
        dt.as_secs_f64(),
        ok as f64 / dt.as_secs_f64(),
        snap.mean_latency
    );
    println!("metrics: {}", snap.render());
    let lat = snap.render_latency();
    if !lat.is_empty() {
        println!("{lat}");
    }
    println!("padding ratio: {:.1}%", coord.metrics().padding_ratio() * 100.0);
    println!(
        "adaptive dispatch: {} (shapes with batch peers lane-fuse; rare shapes skip the linger)",
        snap.render_dispatch()
    );
    Ok(())
}

fn cmd_serve_stream(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    let n_sessions = args.get_usize("sessions", 8)?;
    let feeds = args.get_usize("feeds", 64)?;
    let feed_points = args.get_usize("feed-points", 32)?.max(1);
    let d = args.get_usize("channels", 3)?;
    let depth = args.get_usize("depth", 4)?;
    let query_every = args.get_usize("query-every", 8)?;
    let budget_mb = args.get_usize("budget-mb", 0)?;
    let ttl_ms = args.get_usize("ttl-ms", 0)?;
    let state_dir = args.get_or("state-dir", "");
    let shards = args.get_usize("shards", 1)?.max(1);

    let mut session = SessionConfig::default();
    if budget_mb > 0 {
        session.budget_bytes = Some(budget_mb << 20);
    }
    if ttl_ms > 0 {
        session.ttl = Some(Duration::from_millis(ttl_ms as u64));
    }
    if !state_dir.is_empty() {
        // Durable sessions: eviction/expiry spill to disk and reload on
        // the next touch; the feed log makes a restart with the same dir
        // recover every live session (each shard under its own subdir).
        session.spill = SpillConfig::Disk(std::path::PathBuf::from(state_dir));
    }
    let coord = ShardedCoordinator::new(
        CoordinatorConfig { session, ..CoordinatorConfig::native_only() },
        shards,
    )?;
    println!(
        "coordinator up (streaming, budget: {}, ttl: {}, state: {}, shards: {shards})",
        if budget_mb > 0 { format!("{budget_mb} MiB") } else { "unbounded".into() },
        if ttl_ms > 0 { format!("{ttl_ms} ms") } else { "off".into() },
        if state_dir.is_empty() { "in-memory".into() } else { format!("durable at {state_dir}") },
    );

    let ok = AtomicU64::new(0);
    let errs = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..n_sessions {
            let coord = &coord;
            let ok = &ok;
            let errs = &errs;
            scope.spawn(move || {
                let call = |req: Request| match coord.call(req) {
                    Ok(resp) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                        Some(resp)
                    }
                    Err(_) => {
                        errs.fetch_add(1, Ordering::Relaxed);
                        None
                    }
                };
                let mut rng = Rng::new(0x57E4 + t as u64);
                let seed_points = 4usize;
                let Some(open) = call(Request::OpenStream {
                    points: signax::data::random_path(&mut rng, seed_points, d, 0.2).into(),
                    stream: seed_points,
                    d,
                    depth,
                }) else {
                    return;
                };
                let Some(sid) = open.session else { return };
                let mut len = seed_points;
                for k in 0..feeds {
                    let pts = rng.normal_vec(feed_points * d, 0.2);
                    if call(Request::Feed { session: sid, points: pts.into(), count: feed_points })
                        .is_some()
                    {
                        len += feed_points;
                    }
                    if query_every > 0 && (k + 1) % query_every == 0 && len >= 4 {
                        let i = len / 3;
                        let j = len - 1;
                        // Alternate signature / logsignature interval queries.
                        if k % (2 * query_every) < query_every {
                            call(Request::QueryInterval { session: sid, i, j });
                        } else {
                            call(Request::LogSigQueryInterval { session: sid, i, j });
                        }
                    }
                }
                // Half the clients close explicitly; the rest leave their
                // sessions to the budget/TTL policies.
                if t % 2 == 0 {
                    call(Request::CloseStream { session: sid });
                }
            });
        }
    });
    let dt = t0.elapsed();
    let ok = ok.load(Ordering::Relaxed);
    let errs = errs.load(Ordering::Relaxed);
    println!(
        "{ok} ok / {errs} errors in {:.2}s  ({:.0} req/s)",
        dt.as_secs_f64(),
        (ok + errs) as f64 / dt.as_secs_f64(),
    );
    for k in 0..coord.num_shards() {
        let snap = coord.shard(k).metrics().snapshot();
        let label = if coord.num_shards() > 1 { format!("[shard {k}] ") } else { String::new() };
        println!("{label}metrics: {} (mean latency {:?})", snap.render(), snap.mean_latency);
        let lat = snap.render_latency();
        if !lat.is_empty() {
            println!("{label}{lat}");
        }
        println!(
            "{label}sessions: open={} resident={:.2} MiB evicted={} expired={} spilled={} \
             reloaded={} spilled_bytes={} wal_appends={}",
            snap.open_sessions,
            snap.session_bytes as f64 / (1 << 20) as f64,
            snap.sessions_evicted,
            snap.sessions_expired,
            snap.sessions_spilled,
            snap.sessions_reloaded,
            snap.spilled_bytes,
            snap.wal_appends
        );
        println!(
            "{label}adaptive dispatch: {} (feed_lane_batches = cross-session fused \
             Path::update sweeps)",
            snap.render_dispatch()
        );
    }
    Ok(())
}

fn cmd_info(args: &signax::substrate::cli::Args) -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    println!("signax — Signatory (ICLR 2021) reproduction");
    println!("native engine: always available");
    if dir.join("MANIFEST.json").exists() {
        let (engine, registry) = EngineHandle::spawn(dir)?;
        println!("PJRT platform: {}", engine.platform());
        println!("artifacts ({}):", registry.entries.len());
        for e in &registry.entries {
            println!(
                "  {:<34} kind={:?} b={} L={} d={} N={} pallas={}",
                e.file, e.kind, e.batch, e.length, e.d, e.depth, e.pallas
            );
        }
    } else {
        println!("no artifacts at {dir:?} (run `make artifacts`)");
    }
    Ok(())
}
