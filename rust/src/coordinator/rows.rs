//! The **single sanctioned precision boundary** of the serving stack.
//!
//! Typed rows ([`crate::ta::Rows`]) flow from the wire to the kernels at
//! their native element width; the one place the serving code is allowed
//! to look at a [`crate::ta::Precision`] tag and pick an element type is
//! the [`with_elem!`] macro below. Everything downstream of that dispatch
//! is generic over [`crate::ta::Elem`] and crosses between `Rows` and
//! native buffers through the cast-free row hooks
//! ([`crate::ta::Elem::rows_from`] / `rows_into` / `rows_as_slice`).
//!
//! A CI grep-lint (`tools/lint_row_casts.sh`) fails the build on any new
//! `as f32` / `as f64` row cast inside `coordinator/` outside this
//! module, so "no transport-induced rounding" is enforced structurally,
//! not by review.

/// Dispatch a generic body on a [`crate::ta::Precision`] exactly once:
/// `with_elem!(prec, E, { ... })` runs the block with `E` aliased to
/// `f32` or `f64`. The block's value is the macro's value; both arms must
/// therefore agree on the (usually `Rows`-typed or fully generic) result.
macro_rules! with_elem {
    ($prec:expr, $E:ident, $body:block) => {
        match $prec {
            $crate::ta::Precision::F32 => {
                type $E = f32;
                $body
            }
            $crate::ta::Precision::F64 => {
                type $E = f64;
                $body
            }
        }
    };
}

pub(crate) use with_elem;
