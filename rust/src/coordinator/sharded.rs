//! N logical coordinators behind one front door, with spec-aware
//! session placement.
//!
//! [`ShardedCoordinator`] owns `n` independent [`Coordinator`] instances
//! and routes every request through the [`Placement`] policy
//! ([`crate::state::placement`]):
//!
//! - **`OpenStream`** goes to [`Placement::place_open`]: same-spec
//!   sessions co-locate on one shard in feed-lane-width groups
//!   ([`crate::exec::LANE_BLOCK`]) before overflowing to the next, so
//!   `Feed` traffic from a same-spec fleet still coalesces into
//!   `Path::update_batch` lane sweeps instead of scattering one session
//!   per shard and feeding scalar everywhere.
//! - **Session ops** (`Feed` / `QueryInterval` / `LogSigQueryInterval` /
//!   `CloseStream`) go to [`Placement::locate`]: shard `k` allocates ids
//!   from the strided lattice `k + 1, k + 1 + n, …`
//!   ([`SessionConfig::first_id`] / [`SessionConfig::id_stride`]), so the
//!   owning shard is pure arithmetic on the id — no shared table, no
//!   broadcast.
//! - **Stateless requests** round-robin across shards.
//!
//! With a [`SpillConfig::Disk`] state dir, each shard persists under its
//! own `shard-k/` subdirectory; because id striping is deterministic from
//! `(k, n)`, a restarted fleet of the same width recovers every shard's
//! sessions under the same ids and [`Placement::locate`] still finds
//! them. `n = 1` degenerates to a plain [`Coordinator`] (every id maps to
//! shard 0).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::state::{Placement, SpillConfig};

use super::router::{Coordinator, CoordinatorConfig, Request, Response};
use super::session::SessionConfig;

/// `n` logical coordinator shards behind one `call` front door.
pub struct ShardedCoordinator {
    shards: Vec<Coordinator>,
    placement: Placement,
    /// Round-robin cursor for stateless traffic.
    rr: AtomicUsize,
}

impl ShardedCoordinator {
    /// Build `n` shards from one base configuration. Shard `k` gets
    /// `first_id = k + 1, id_stride = n` (the lattice [`Placement::locate`]
    /// inverts) and, when the base session config spills to disk, its own
    /// `shard-k/` subdirectory of the state dir.
    pub fn new(base: CoordinatorConfig, n: usize) -> anyhow::Result<ShardedCoordinator> {
        let n = n.max(1);
        let mut shards = Vec::with_capacity(n);
        for k in 0..n {
            let mut cfg = base.clone();
            cfg.session = SessionConfig {
                first_id: k as u64 + 1,
                id_stride: n as u64,
                spill: match &base.session.spill {
                    SpillConfig::Disk(dir) => SpillConfig::Disk(dir.join(format!("shard-{k}"))),
                    other => other.clone(),
                },
                ..base.session.clone()
            };
            shards.push(Coordinator::new(cfg)?);
        }
        Ok(ShardedCoordinator {
            shards,
            placement: Placement::new(n),
            rr: AtomicUsize::new(0),
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard that owns logical instance `k` (metrics, tests).
    pub fn shard(&self, k: usize) -> &Coordinator {
        &self.shards[k]
    }

    /// The placement policy (exposed so callers can predict routing).
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Which shard this request routes to.
    fn route_of(&self, req: &Request) -> usize {
        match req {
            // Window opens place like stream opens — spec-aware, so
            // windowed feeders of one spec land where their lane peers
            // are.
            Request::OpenStream { d, depth, .. } | Request::OpenWindow { d, depth, .. } => {
                self.placement.place_open(*d, *depth)
            }
            Request::Feed { session, .. }
            | Request::QueryInterval { session, .. }
            | Request::LogSigQueryInterval { session, .. }
            | Request::PollWindow { session, .. }
            | Request::CloseStream { session } => self.placement.locate(session.0),
            _ => self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len(),
        }
    }

    /// Serve one request on its owning shard.
    pub fn call(&self, req: Request) -> anyhow::Result<Response> {
        let shard = self.route_of(&req);
        self.shards[shard].call(req)
    }

    /// Serve many requests, each on its owning shard (sequentially; the
    /// per-shard coordinators do their own internal batching, and callers
    /// wanting concurrency thread `call` themselves as with
    /// [`Coordinator::call`]).
    pub fn call_many(&self, reqs: Vec<Request>) -> Vec<anyhow::Result<Response>> {
        reqs.into_iter().map(|r| self.call(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::SessionId;
    use crate::data::synth::Rng;
    use crate::signature::signature;
    use crate::ta::SigSpec;

    fn native_sharded(n: usize) -> ShardedCoordinator {
        ShardedCoordinator::new(CoordinatorConfig::native_only().with_native_batch(0), n).unwrap()
    }

    #[test]
    fn open_feed_query_close_roundtrip_across_shards() {
        let sc = native_sharded(3);
        let mut rng = Rng::new(31);
        // Distinct specs so opens spread; every op must find its session
        // again purely from the id. A twin `Path` per session is the
        // bitwise oracle (the session table runs the identical code).
        let mut sessions = Vec::new();
        for (d, depth) in [(2usize, 3usize), (3, 2), (2, 3), (4, 2)] {
            let pts = rng.normal_vec(4 * d, 0.5);
            let resp = sc
                .call(Request::OpenStream { points: pts.clone().into(), stream: 4, d, depth })
                .unwrap();
            let id = resp.session.unwrap();
            // The issuing shard is recoverable from the id alone.
            assert_eq!(sc.placement().locate(id.0), ((id.0 - 1) % 3) as usize);
            let spec = SigSpec::new(d, depth).unwrap();
            let twin = crate::path::Path::new(&spec, &pts, 4).unwrap();
            sessions.push((id, d, twin));
        }
        // Ids are unique across shards (strided lattices are disjoint).
        let mut ids: Vec<u64> = sessions.iter().map(|(id, ..)| id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), sessions.len(), "id collision across shards");

        for (id, d, twin) in &mut sessions {
            let extra = rng.normal_vec(2 * *d, 0.5);
            twin.update(&extra, 2).unwrap();
            let fed = sc
                .call(Request::Feed { session: *id, points: extra.into(), count: 2 })
                .unwrap();
            assert_eq!(fed.session, Some(*id));
            assert_eq!(fed.values, twin.signature(), "feed through the sharded front door");
        }
        for (id, _d, twin) in &sessions {
            let q = sc.call(Request::QueryInterval { session: *id, i: 1, j: 5 }).unwrap();
            assert_eq!(q.values, twin.query(1, 5).unwrap(), "interval query != twin path");
        }
        let (id0, ..) = sessions[0];
        sc.call(Request::CloseStream { session: id0 }).unwrap();
        let err = sc.call(Request::QueryInterval { session: id0, i: 0, j: 1 }).unwrap_err();
        assert!(err.to_string().contains("closed"), "taxonomy survives sharding: {err}");
        // An unknown id still routes deterministically and errors cleanly.
        let err = sc
            .call(Request::QueryInterval { session: SessionId(998), i: 0, j: 1 })
            .unwrap_err();
        assert!(err.to_string().contains("never opened"), "{err}");
    }

    #[test]
    fn same_spec_opens_co_locate_in_lane_blocks() {
        let sc = native_sharded(4);
        let mut rng = Rng::new(32);
        let group = crate::exec::LANE_BLOCK;
        // One lane block of same-spec opens must land on ONE shard.
        let mut homes = std::collections::HashSet::new();
        for _ in 0..group {
            let pts = rng.normal_vec(3 * 2, 0.5);
            let resp = sc
                .call(Request::OpenStream { points: pts.into(), stream: 3, d: 2, depth: 3 })
                .unwrap();
            homes.insert(sc.placement().locate(resp.session.unwrap().0));
        }
        assert_eq!(homes.len(), 1, "a lane block scattered across shards: {homes:?}");
        // The next block steps to the following shard.
        let pts = rng.normal_vec(3 * 2, 0.5);
        let resp =
            sc.call(Request::OpenStream { points: pts.into(), stream: 3, d: 2, depth: 3 }).unwrap();
        let next = sc.placement().locate(resp.session.unwrap().0);
        let first = *homes.iter().next().unwrap();
        assert_eq!(next, (first + 1) % 4, "overflow block should step one shard over");
    }

    #[test]
    fn single_shard_degenerates_to_plain_coordinator() {
        let sc = native_sharded(1);
        let mut rng = Rng::new(33);
        let spec = SigSpec::new(2, 2).unwrap();
        let p = rng.normal_vec(5 * 2, 0.4);
        let resp = sc
            .call(Request::Signature { path: p.clone().into(), stream: 5, d: 2, depth: 2 })
            .unwrap();
        assert_eq!(resp.values, signature(&p, 5, &spec));
        let open = sc
            .call(Request::OpenStream { points: p.into(), stream: 5, d: 2, depth: 2 })
            .unwrap();
        assert_eq!(sc.placement().locate(open.session.unwrap().0), 0);
    }

    #[test]
    fn stateless_round_robin_spreads_shards() {
        let sc = native_sharded(2);
        let mut rng = Rng::new(34);
        let spec = SigSpec::new(2, 2).unwrap();
        for _ in 0..4 {
            let p = rng.normal_vec(4 * 2, 0.4);
            let resp = sc
                .call(Request::Signature { path: p.clone().into(), stream: 4, d: 2, depth: 2 })
                .unwrap();
            assert_eq!(resp.values, signature(&p, 4, &spec));
        }
        let served: u64 = (0..2)
            .map(|k| sc.shard(k).metrics().snapshot().native_requests)
            .sum();
        assert_eq!(served, 4);
        for k in 0..2 {
            assert_eq!(
                sc.shard(k).metrics().snapshot().native_requests,
                2,
                "round-robin should split stateless traffic evenly"
            );
        }
    }
}
