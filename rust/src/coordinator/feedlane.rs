//! The **feed lane**: a dynamic batcher for stateful session feeds.
//!
//! `Request::Feed` traffic is the stateful mirror of the native signature
//! microbatch: many sessions streaming the same spec can share one
//! lane-fused `Path::update_batch` sweep ([`crate::path::Path`]) instead
//! of N scalar updates. This batcher gathers same-spec feeds inside one
//! linger window (keyed by `(d, depth, dtype)` — feeds are ragged in
//! point count by design, which the lane sweep handles natively, but
//! never mix element precisions: f32 and f64 sessions keep separate
//! groups) and flushes them into [`SessionManager::feed_batch`], whose
//! lanes are **bitwise identical** to scalar `Path::update`.
//!
//! Whether a feed enters the lane at all is the planner's call
//! ([`crate::exec::ExecPlanner::feed_lane_capacity`]): lane-fusing only
//! pays when at least two distinct sessions feed a spec concurrently, so
//! a lone streaming client keeps the direct scalar path and never pays
//! the linger — the same latency contract the `native_batch = 0` escape
//! hatch documents for stateless traffic.
//!
//! The pending-queue / condvar / deadline machinery (including the
//! stale-linger and missed-wakeup fixes) lives in the unified
//! [`super::flusher::GroupBatcher`]; this module is only the feed-shaped
//! instantiation — net deletion relative to the pre-unification copy that
//! mirrored `batcher.rs` line for line.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::flusher::{GroupBatcher, GroupExecutor};
use super::session::{SessionId, SessionManager};
use crate::ta::{Precision, Rows};

/// Spec key feeds are grouped under: `(d, depth, dtype)` — the dtype
/// component keeps the never-coalesce-across-precision invariant at the
/// queue level.
pub type FeedKey = (usize, usize, Precision);

struct FeedItem {
    session: SessionId,
    points: Rows,
    count: usize,
    tx: mpsc::Sender<anyhow::Result<Rows>>,
}

/// The feed-shaped [`GroupExecutor`]: flushes a gathered group into one
/// [`SessionManager::feed_batch`] call and delivers each feed's result.
/// Dispatch metrics are not taken here: `feed_batch` owns the
/// `feed_lane_batches` / dispatch counters, so every flush path counts
/// identically.
struct FeedExecutor {
    sessions: Arc<SessionManager>,
}

impl GroupExecutor for FeedExecutor {
    type Key = FeedKey;
    type Item = FeedItem;

    fn execute(&self, _key: FeedKey, _capacity: usize, items: Vec<FeedItem>) {
        let mut txs = Vec::with_capacity(items.len());
        let feeds: Vec<(SessionId, Rows, usize)> = items
            .into_iter()
            .map(|it| {
                let FeedItem { session, points, count, tx } = it;
                txs.push(tx);
                (session, points, count)
            })
            .collect();
        let results = self.sessions.feed_batch(feeds);
        for (tx, result) in txs.into_iter().zip(results) {
            let _ = tx.send(result);
        }
    }
}

/// The feed-lane batcher: a [`GroupBatcher`] instantiation keyed on the
/// spec. Submit feeds; each receives its whole-stream signature on its own
/// channel once its group executes (full, or linger elapsed).
pub struct FeedLane {
    inner: GroupBatcher<FeedExecutor>,
}

impl FeedLane {
    pub fn new(sessions: Arc<SessionManager>, linger: Duration) -> FeedLane {
        let executor = Arc::new(FeedExecutor { sessions });
        FeedLane { inner: GroupBatcher::new("signax-feedlane", executor, linger) }
    }

    /// Submit one feed with the capacity the planner quoted for its spec.
    /// A full group executes on the calling thread (tail latency stays
    /// off the flusher); otherwise the flusher fires it at the deadline.
    pub fn submit(
        &self,
        key: FeedKey,
        capacity: usize,
        session: SessionId,
        points: Rows,
        count: usize,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Rows>>> {
        anyhow::ensure!(
            points.precision() == key.2,
            "feed precision {} does not match the lane key's {}",
            points.precision().label(),
            key.2.label()
        );
        let (tx, rx) = mpsc::channel();
        self.inner.submit(key, capacity, FeedItem { session, points, count, tx })?;
        Ok(rx)
    }

    /// Force-flush everything (shutdown and tests).
    pub fn flush(&self) {
        self.inner.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;
    use crate::ta::SigSpec;

    fn setup() -> (Arc<SessionManager>, Arc<super::super::metrics::Metrics>) {
        let metrics = Arc::new(super::super::metrics::Metrics::default());
        (Arc::new(SessionManager::new(Arc::clone(&metrics))), metrics)
    }

    #[test]
    fn full_group_executes_inline_and_coalesces() {
        let (sessions, metrics) = setup();
        let lane = FeedLane::new(
            Arc::clone(&sessions),
            Duration::from_secs(60), // only fullness triggers
        );
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(1);
        let ids: Vec<SessionId> = (0..3)
            .map(|_| sessions.open(&spec, &rng.normal_vec(4 * 2, 0.3).into(), 4).unwrap())
            .collect();
        let mut rxs = vec![];
        for &id in &ids {
            let pts = rng.normal_vec(2 * 2, 0.3);
            rxs.push(lane.submit((2, 3, Precision::F32), 3, id, pts.into(), 2).unwrap());
        }
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        }
        assert_eq!(metrics.snapshot().feed_lane_batches, 1);
        for &id in &ids {
            assert_eq!(sessions.session_len(id).unwrap(), 6);
        }
    }

    #[test]
    fn linger_flushes_partial_group() {
        let (sessions, _metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_millis(10));
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(2);
        let id = sessions.open(&spec, &rng.normal_vec(4 * 2, 0.3).into(), 4).unwrap();
        let rx = lane
            .submit((2, 3, Precision::F32), 8, id, rng.normal_vec(2 * 2, 0.3).into(), 2)
            .unwrap();
        let sig = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(sig.len(), spec.sig_len());
        assert_eq!(sessions.session_len(id).unwrap(), 6);
    }

    #[test]
    fn distinct_specs_flush_separately() {
        let (sessions, metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_millis(10));
        let s2 = SigSpec::new(2, 3).unwrap();
        let s3 = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(3);
        let a = sessions.open(&s2, &rng.normal_vec(4 * 2, 0.3).into(), 4).unwrap();
        let b = sessions.open(&s3, &rng.normal_vec(4 * 3, 0.3).into(), 4).unwrap();
        let rx_a = lane
            .submit((2, 3, Precision::F32), 8, a, rng.normal_vec(2 * 2, 0.3).into(), 2)
            .unwrap();
        let rx_b = lane
            .submit((3, 3, Precision::F32), 8, b, rng.normal_vec(2 * 3, 0.3).into(), 2)
            .unwrap();
        assert!(rx_a.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        assert!(rx_b.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        // Two singleton flushes: scalar dispatch, no fused feed sweep.
        assert_eq!(metrics.snapshot().feed_lane_batches, 0);
    }

    #[test]
    fn errors_reach_their_caller_only() {
        let (sessions, _metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_secs(60));
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(4);
        let good = sessions.open(&spec, &rng.normal_vec(4 * 2, 0.3).into(), 4).unwrap();
        let rx_bad = lane
            .submit((2, 3, Precision::F32), 2, SessionId(777), rng.normal_vec(2 * 2, 0.3).into(), 2)
            .unwrap();
        let rx_good = lane
            .submit((2, 3, Precision::F32), 2, good, rng.normal_vec(2 * 2, 0.3).into(), 2)
            .unwrap();
        assert!(rx_bad.recv_timeout(Duration::from_secs(5)).unwrap().is_err());
        assert!(rx_good.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }

    #[test]
    fn zero_capacity_rejected_through_the_generic() {
        // The unified generic owns the capacity >= 1 contract.
        let (sessions, _metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_millis(10));
        let pts: Rows = vec![0.0f32; 4].into();
        assert!(lane.submit((2, 3, Precision::F32), 0, SessionId(1), pts, 2).is_err());
    }

    #[test]
    fn cross_precision_submit_rejected() {
        // An f64 feed under an f32 lane key is a hard error, not a cast.
        let (sessions, _metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_millis(10));
        let pts: Rows = vec![0.0f64; 4].into();
        assert!(lane.submit((2, 3, Precision::F32), 2, SessionId(1), pts, 2).is_err());
    }
}
