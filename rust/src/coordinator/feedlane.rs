//! The **feed lane**: a dynamic batcher for stateful session feeds.
//!
//! `Request::Feed` traffic is the stateful mirror of the native signature
//! microbatch: many sessions streaming the same spec can share one
//! lane-fused `Path::update_batch` sweep ([`crate::path::Path`]) instead
//! of N scalar updates. This batcher gathers same-spec feeds inside one
//! linger window (keyed by `(d, depth)` — feeds are ragged in point count
//! by design, which the lane sweep handles natively) and flushes them
//! into [`SessionManager::feed_batch`], whose lanes are **bitwise
//! identical** to scalar `Path::update`.
//!
//! Whether a feed enters the lane at all is the planner's call
//! ([`crate::exec::ExecPlanner::feed_lane_capacity`]): lane-fusing only
//! pays when at least two distinct sessions feed a spec concurrently, so
//! a lone streaming client keeps the direct scalar path and never pays
//! the linger — the same latency contract the `native_batch = 0` escape
//! hatch documents for stateless traffic.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::session::{SessionId, SessionManager};

/// Spec key feeds are grouped under: `(d, depth)`.
pub type FeedKey = (usize, usize);

struct FeedItem {
    session: SessionId,
    points: Vec<f32>,
    count: usize,
    tx: mpsc::Sender<anyhow::Result<Vec<f32>>>,
}

struct PendingFeeds {
    /// Capacity fixed by the first submitter of this pending group (the
    /// planner may quote later submitters differently; see the batcher's
    /// identical rule).
    capacity: usize,
    items: Vec<FeedItem>,
    deadline: Instant,
}

struct Shared {
    queues: Mutex<HashMap<FeedKey, PendingFeeds>>,
    wake: Condvar,
    shutdown: Mutex<bool>,
}

/// The feed-lane batcher. Submit feeds; each receives its whole-stream
/// signature on its own channel once its group executes (full, or linger
/// elapsed).
pub struct FeedLane {
    shared: Arc<Shared>,
    sessions: Arc<SessionManager>,
    linger: Duration,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl FeedLane {
    /// Dispatch metrics are not taken here: [`SessionManager::feed_batch`]
    /// owns the `feed_lane_batches` / dispatch counters, so every flush
    /// path counts identically.
    pub fn new(sessions: Arc<SessionManager>, linger: Duration) -> FeedLane {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            wake: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let flusher = {
            let shared = Arc::clone(&shared);
            let sessions = Arc::clone(&sessions);
            std::thread::Builder::new()
                .name("signax-feedlane".into())
                .spawn(move || flusher_loop(shared, sessions, linger))
                .expect("spawn feed lane")
        };
        FeedLane { shared, sessions, linger, flusher: Some(flusher) }
    }

    /// Submit one feed with the capacity the planner quoted for its spec.
    /// A full group executes on the calling thread (tail latency stays
    /// off the flusher); otherwise the flusher fires it at the deadline.
    pub fn submit(
        &self,
        key: FeedKey,
        capacity: usize,
        session: SessionId,
        points: Vec<f32>,
        count: usize,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<f32>>>> {
        anyhow::ensure!(capacity >= 1, "feed-lane capacity must be at least 1");
        let (tx, rx) = mpsc::channel();
        let full = {
            let mut queues = self.shared.queues.lock().unwrap();
            let pending = queues.entry(key).or_insert_with(|| PendingFeeds {
                capacity,
                items: Vec::with_capacity(capacity),
                deadline: Instant::now() + self.linger,
            });
            pending.items.push(FeedItem { session, points, count, tx });
            if pending.items.len() >= pending.capacity {
                queues.remove(&key)
            } else {
                self.shared.wake.notify_one();
                None
            }
        };
        if let Some(pending) = full {
            execute_feeds(&self.sessions, pending.items);
        }
        Ok(rx)
    }

    /// Force-flush everything (shutdown and tests).
    pub fn flush(&self) {
        let drained: Vec<PendingFeeds> = {
            let mut queues = self.shared.queues.lock().unwrap();
            queues.drain().map(|(_, p)| p).collect()
        };
        for pending in drained {
            execute_feeds(&self.sessions, pending.items);
        }
    }
}

impl Drop for FeedLane {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.wake.notify_all();
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        self.flush();
    }
}

fn flusher_loop(shared: Arc<Shared>, sessions: Arc<SessionManager>, linger: Duration) {
    loop {
        if *shared.shutdown.lock().unwrap() {
            return;
        }
        let mut due: Vec<PendingFeeds> = vec![];
        {
            let mut queues = shared.queues.lock().unwrap();
            let now = Instant::now();
            let due_keys: Vec<FeedKey> =
                queues.iter().filter(|(_, p)| p.deadline <= now).map(|(k, _)| *k).collect();
            for k in due_keys {
                if let Some(p) = queues.remove(&k) {
                    due.push(p);
                }
            }
        }
        for pending in due {
            execute_feeds(&sessions, pending.items);
        }
        // Recompute the earliest deadline *after* executing — a submit
        // landing mid-execution dropped its notify on the floor (nobody
        // was waiting), so sleeping on a pre-execution deadline would let
        // it idle a stale full linger (same fix as the row batcher).
        let guard = shared.queues.lock().unwrap();
        let now = Instant::now();
        if guard.values().any(|p| p.deadline <= now) {
            continue;
        }
        let wait = guard
            .values()
            .map(|p| p.deadline)
            .min()
            .map(|dl| dl.saturating_duration_since(now))
            .unwrap_or(linger)
            .max(Duration::from_micros(100));
        let _unused = shared.wake.wait_timeout(guard, wait).unwrap();
    }
}

fn execute_feeds(sessions: &SessionManager, items: Vec<FeedItem>) {
    let mut txs = Vec::with_capacity(items.len());
    let feeds: Vec<(SessionId, Vec<f32>, usize)> = items
        .into_iter()
        .map(|it| {
            let FeedItem { session, points, count, tx } = it;
            txs.push(tx);
            (session, points, count)
        })
        .collect();
    let results = sessions.feed_batch(feeds);
    for (tx, result) in txs.into_iter().zip(results) {
        let _ = tx.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;
    use crate::ta::SigSpec;

    fn setup() -> (Arc<SessionManager>, Arc<super::super::metrics::Metrics>) {
        let metrics = Arc::new(super::super::metrics::Metrics::default());
        (Arc::new(SessionManager::new(Arc::clone(&metrics))), metrics)
    }

    #[test]
    fn full_group_executes_inline_and_coalesces() {
        let (sessions, metrics) = setup();
        let lane = FeedLane::new(
            Arc::clone(&sessions),
            Duration::from_secs(60), // only fullness triggers
        );
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(1);
        let ids: Vec<SessionId> = (0..3)
            .map(|_| sessions.open(&spec, &rng.normal_vec(4 * 2, 0.3), 4).unwrap())
            .collect();
        let mut rxs = vec![];
        for &id in &ids {
            let pts = rng.normal_vec(2 * 2, 0.3);
            rxs.push(lane.submit((2, 3), 3, id, pts, 2).unwrap());
        }
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        }
        assert_eq!(metrics.snapshot().feed_lane_batches, 1);
        for &id in &ids {
            assert_eq!(sessions.session_len(id).unwrap(), 6);
        }
    }

    #[test]
    fn linger_flushes_partial_group() {
        let (sessions, _metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_millis(10));
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(2);
        let id = sessions.open(&spec, &rng.normal_vec(4 * 2, 0.3), 4).unwrap();
        let rx = lane.submit((2, 3), 8, id, rng.normal_vec(2 * 2, 0.3), 2).unwrap();
        let sig = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(sig.len(), spec.sig_len());
        assert_eq!(sessions.session_len(id).unwrap(), 6);
    }

    #[test]
    fn distinct_specs_flush_separately() {
        let (sessions, metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_millis(10));
        let s2 = SigSpec::new(2, 3).unwrap();
        let s3 = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(3);
        let a = sessions.open(&s2, &rng.normal_vec(4 * 2, 0.3), 4).unwrap();
        let b = sessions.open(&s3, &rng.normal_vec(4 * 3, 0.3), 4).unwrap();
        let rx_a = lane.submit((2, 3), 8, a, rng.normal_vec(2 * 2, 0.3), 2).unwrap();
        let rx_b = lane.submit((3, 3), 8, b, rng.normal_vec(2 * 3, 0.3), 2).unwrap();
        assert!(rx_a.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        assert!(rx_b.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        // Two singleton flushes: scalar dispatch, no fused feed sweep.
        assert_eq!(metrics.snapshot().feed_lane_batches, 0);
    }

    #[test]
    fn errors_reach_their_caller_only() {
        let (sessions, _metrics) = setup();
        let lane = FeedLane::new(Arc::clone(&sessions), Duration::from_secs(60));
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(4);
        let good = sessions.open(&spec, &rng.normal_vec(4 * 2, 0.3), 4).unwrap();
        let rx_bad = lane
            .submit((2, 3), 2, SessionId(777), rng.normal_vec(2 * 2, 0.3), 2)
            .unwrap();
        let rx_good = lane.submit((2, 3), 2, good, rng.normal_vec(2 * 2, 0.3), 2).unwrap();
        assert!(rx_bad.recv_timeout(Duration::from_secs(5)).unwrap().is_err());
        assert!(rx_good.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }
}
