//! The request router: the coordinator's front door.
//!
//! Each request is routed to the XLA backend when an AOT artifact with a
//! matching shape exists (going through the dynamic batcher), and to the
//! native Rust engine otherwise. The native path is also the fallback when
//! no artifact directory is present, so the coordinator is fully usable
//! without running `make artifacts`.
//!
//! Native execution strategy is owned by the **execution planner**
//! ([`crate::exec::ExecPlanner`], configured through [`DispatchConfig`]):
//! the coordinator records every request's shape into the planner's
//! observed shape-mix histogram, and the planner decides per shape whether
//! to microbatch (same-spec `Signature` **and `LogSignature`** requests
//! gathered within one linger window execute as a single **lane-fused**
//! sweep through [`crate::ta::batch`] — logsig rows add a per-row log +
//! Words-basis projection epilogue from the shared plan cache) or to
//! serve directly (shapes too rare in recent traffic to find batch peers
//! skip the linger entirely). Stateful `Feed` requests get the same
//! treatment through the **feed lane**
//! ([`super::feedlane::FeedLane`]): once two or more distinct sessions
//! stream the same spec, their feeds coalesce into one
//! `Path::update_batch` sweep — bitwise identical per session to scalar
//! feeding. All three gathering surfaces are instantiations of one
//! unified batcher generic ([`super::flusher::GroupBatcher`]).
//!
//! **Precision axis**: rows are **natively typed** end to end
//! ([`crate::ta::Rows`]). The element width of a request's buffers IS its
//! compute precision — f32 rows run the f32 kernels bitwise as before,
//! and f64 rows run the same scalar-generic kernels at f64, with no
//! upcast or downcast anywhere between the wire and the kernel. The one
//! place serving code inspects the precision tag and picks an element
//! type is [`super::rows::with_elem!`]; everything past that dispatch is
//! generic over [`crate::ta::Elem`]. The precision is part of both the
//! planner's [`ShapeKey`] and the batcher's queue identity
//! ([`BatchShape::prec`]), so f32 and f64 requests of one logical shape
//! never share a microbatch — their bits differ. The XLA artifacts are
//! compiled for f32 only, so f64 requests always route native.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{BatchBackend, BatchShape, Batcher};
use super::feedlane::FeedLane;
use super::metrics::{Metrics, RequestKind};
use super::rows::with_elem;
use super::session::{SessionConfig, SessionId, SessionManager};
use crate::exec::{ExecPlan, ExecPlanner, ShapeKey, WorkShape};
use crate::path::WindowSpec;
use crate::logsignature::{
    logsignature_batch_planned, logsignature_with, LogSigPlan, WordsPlanCache,
};
#[cfg(test)]
use crate::logsignature::LogSigBasis;
use crate::runtime::{ArtifactKind, EngineHandle, Registry};
use crate::signature::{signature_batch_planned, signature_vjp_with, signature_with, SigConfig};
#[cfg(test)]
use crate::signature::signature;
use crate::ta::{Elem, Precision, Rows, SigSpec};

/// Kinds encoded into [`BatchShape::kind`].
const KIND_SIG: u8 = 0;
const KIND_LOGSIG: u8 = 1;
const KIND_SIGGRAD: u8 = 2;
/// Native lane-fused signature microbatch (no artifact involved).
const KIND_SIG_NATIVE: u8 = 3;
/// Native lane-fused *logsignature* microbatch: the same lane-interleaved
/// signature sweep plus the per-row log + Words-basis projection epilogue.
const KIND_LOGSIG_NATIVE: u8 = 4;

/// A request against the coordinator.
///
/// Requests carry **typed rows** ([`Rows`]): the element width of the
/// payload IS the compute precision, end to end. There is no separate
/// precision tag to keep in sync with the buffer — f32 rows preserve the
/// pre-precision-axis behaviour bitwise, and f64 rows run the f64 kernels
/// natively and answer in f64 (no serving layer upcasts or downcasts a
/// row; see [`super::rows`]).
#[derive(Clone, Debug)]
pub enum Request {
    /// `Sig^depth(path)` for one `(stream, d)` path.
    Signature { path: Rows, stream: usize, d: usize, depth: usize },
    /// Words-basis `LogSig^depth(path)`. Both element widths serve: the
    /// log + Words-projection epilogue is generic over the element type,
    /// so f64 rows run the whole pipeline at f64, in their own microbatch
    /// queue.
    LogSignature { path: Rows, stream: usize, d: usize, depth: usize },
    /// VJP: cotangent on the signature -> gradient on the path. The
    /// cotangent must match the path's element precision; the gradient
    /// comes back at the same width.
    SignatureGrad { path: Rows, stream: usize, d: usize, depth: usize, cotangent: Rows },
    /// Open a streaming session seeded with an initial path (>= 2 points).
    /// The response carries the new id in [`Response::session`] and the
    /// signature of the seed path in `values`. The session records the
    /// element type of its seed rows; every later feed must match it.
    OpenStream { points: Rows, stream: usize, d: usize, depth: usize },
    /// Append points to a session ("keeping the signature up-to-date",
    /// §5.5, eq. 7); returns the whole-stream signature so far.
    Feed { session: SessionId, points: Rows, count: usize },
    /// O(1)-in-L interval signature query against a session's stream
    /// (0-based inclusive endpoints, `i < j < len`).
    QueryInterval { session: SessionId, i: usize, j: usize },
    /// Words-basis logsignature interval query (served from the
    /// coordinator's cached `LogSigPlan` for the session's spec).
    LogSigQueryInterval { session: SessionId, i: usize, j: usize },
    /// Close a session, releasing its precomputed storage.
    CloseStream { session: SessionId },
    /// Open a **rolling-window session**: like `OpenStream`, plus the
    /// server keeps `window`'s sliding signatures (or logsignatures, per
    /// [`WindowSpec::logsig`]) up to date as feeds arrive — one O(1)
    /// stored-inverse combination per slide — retaining only O(window)
    /// points per session. The response carries the seed signature and
    /// the new id; emitted slides buffer server-side until a
    /// `PollWindow` drains them.
    OpenWindow { points: Rows, stream: usize, d: usize, depth: usize, window: WindowSpec },
    /// Drain a rolling-window session's undelivered slides. The response
    /// packs them row-major in `values` (one row per slide, width
    /// `sig_len` or the basis dimension), sets
    /// [`Response::window_slide`] to the first row's slide index, and
    /// [`Response::window_remaining`] to the slides still buffered
    /// server-side. `max_slides` caps the page (`None` = drain
    /// everything): a slow poller bounds each response's payload and
    /// re-issues the request until `window_remaining` reads 0 — the
    /// continuation cursor is implicit (slides always deliver in order,
    /// so the next page starts where this one ended).
    PollWindow { session: SessionId, max_slides: Option<u64> },
}

impl Request {
    /// The metrics kind this request files latency under.
    pub fn kind(&self) -> RequestKind {
        match self {
            Request::Signature { .. } => RequestKind::Signature,
            Request::LogSignature { .. } => RequestKind::LogSignature,
            Request::SignatureGrad { .. } => RequestKind::SignatureGrad,
            Request::OpenStream { .. } => RequestKind::OpenStream,
            Request::Feed { .. } => RequestKind::Feed,
            Request::QueryInterval { .. } => RequestKind::QueryInterval,
            Request::LogSigQueryInterval { .. } => RequestKind::LogSigQueryInterval,
            Request::CloseStream { .. } => RequestKind::CloseStream,
            Request::OpenWindow { .. } => RequestKind::OpenWindow,
            Request::PollWindow { .. } => RequestKind::PollWindow,
        }
    }
}

/// Which backend served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Native,
    Xla,
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Typed result rows, at the same element width the request carried
    /// (streaming responses: the session's recorded dtype).
    pub values: Rows,
    pub backend: Backend,
    /// The element precision of `values` — always derived from the buffer
    /// itself, never assumed (XLA responses are [`Precision::F32`], the
    /// only width artifacts are compiled for).
    pub precision: Precision,
    /// Set on streaming responses: the session the request addressed
    /// (`OpenStream` returns the freshly allocated id here).
    pub session: Option<SessionId>,
    /// Set on `PollWindow` responses: the slide index of the first row in
    /// `values` (row `r` is slide `window_slide + r`). `None` everywhere
    /// else.
    pub window_slide: Option<u64>,
    /// Set on `PollWindow` responses: slides still buffered server-side
    /// after this page (0 = drained; nonzero only when the request's
    /// `max_slides` cap truncated the drain). `None` everywhere else.
    pub window_remaining: Option<u64>,
}

/// Adaptive-dispatch knobs: how the coordinator's [`ExecPlanner`] turns
/// the observed shape mix into microbatch formation. Replaces the old
/// static `native_batch` knob (see
/// [`CoordinatorConfig::with_native_batch`] for the compatibility alias).
#[derive(Clone, Debug)]
pub struct DispatchConfig {
    /// Microbatch capacity ceiling for native `Signature` requests: when
    /// `>= 2`, same-spec requests gathered within one linger window run
    /// as **one lane-fused sweep** ([`crate::ta::batch`]) instead of N
    /// independent signatures — the CPU serving hot path for many short
    /// streams at small `d`. Requests whose shapes differ batch
    /// separately (the batcher keys on shape), so a ragged mix degrades
    /// gracefully to per-shape microbatches. `0` **disables** native
    /// microbatching entirely — the documented escape hatch for
    /// latency-sensitive single-stream callers: every request computes
    /// directly, no linger, guaranteed (pinned by a regression test and
    /// preserved verbatim through the planner).
    pub microbatch: usize,
    /// Adapt per-shape capacity to the observed shape mix
    /// ([`ExecPlanner::microbatch_capacity`]): shapes too rare in recent
    /// traffic to expect a batch peer execute directly instead of idling
    /// out the linger. `false` restores the static pre-planner behaviour
    /// (every shape always lingers up to `microbatch` rows).
    pub adaptive: bool,
    /// Lane-fuse same-spec session feeds through the feed lane
    /// ([`super::feedlane::FeedLane`]). Engages per spec only once two or
    /// more distinct sessions feed it concurrently
    /// ([`ExecPlanner::feed_lane_capacity`]); a lone streaming client
    /// always keeps the direct scalar path. `microbatch = 0` disables
    /// the feed lane too.
    pub feed_lanes: bool,
    /// Cap on per-request stream parallelism for native `SignatureGrad`:
    /// the coordinator already serves requests concurrently (one caller
    /// thread each), so uncapped `native_threads` here would multiply
    /// into requests x cores scoped workers under load.
    pub grad_stream_threads: usize,
    /// Window of the planner's decayed shape-mix histogram.
    pub mix_window: usize,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        DispatchConfig {
            microbatch: crate::exec::LANE_BLOCK,
            adaptive: true,
            feed_lanes: true,
            grad_stream_threads: 4,
            mix_window: 64,
        }
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Artifact directory; `None` => native-only coordinator.
    pub artifact_dir: Option<PathBuf>,
    /// Route to XLA when possible (otherwise XLA is only used when asked
    /// explicitly by benchmarks).
    pub prefer_xla: bool,
    /// Dynamic batcher linger.
    pub linger: Duration,
    /// Threads for native batch work.
    pub native_threads: usize,
    /// Adaptive execution dispatch (strategy selection + microbatch
    /// formation); see [`DispatchConfig`].
    pub dispatch: DispatchConfig,
    /// Streaming-session knobs: table sharding, the resident-memory budget
    /// (`session.budget_bytes`, enforced by LRU eviction of idle
    /// sessions), and the idle TTL (`session.ttl`, enforced by a
    /// background sweeper). Defaults to unbounded.
    pub session: SessionConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: Some(crate::runtime::default_artifact_dir()),
            prefer_xla: true,
            linger: Duration::from_millis(2),
            native_threads: crate::substrate::pool::default_threads(),
            dispatch: DispatchConfig::default(),
            session: SessionConfig::default(),
        }
    }
}

impl CoordinatorConfig {
    /// A native-only configuration (no artifacts, no PJRT).
    pub fn native_only() -> Self {
        CoordinatorConfig { artifact_dir: None, prefer_xla: false, ..Default::default() }
    }

    /// Compatibility alias for the pre-planner `native_batch` knob: sets
    /// the microbatch capacity ceiling ([`DispatchConfig::microbatch`]).
    /// `0` keeps its documented meaning — native microbatching (and the
    /// feed lane) fully disabled, no linger on any native request.
    pub fn with_native_batch(mut self, native_batch: usize) -> Self {
        self.dispatch.microbatch = native_batch;
        self
    }

    /// The effective `native_batch` value (compatibility accessor).
    pub fn native_batch(&self) -> usize {
        self.dispatch.microbatch
    }
}

struct XlaBackend {
    engine: EngineHandle,
    registry: Arc<Registry>,
}

impl BatchBackend for XlaBackend {
    // XLA executables are compiled for the fixed `shape.batch`, so the
    // padding rows must run regardless of `n_real`.
    fn run(&self, shape: &BatchShape, padded: &Rows, _n_real: usize) -> anyhow::Result<Rows> {
        // Artifacts are compiled for f32 only; the router never routes an
        // f64 request here and the batcher's queue identity carries the
        // dtype, so anything else reaching this backend is a plumbing bug.
        let padded = padded
            .as_f32()
            .map_err(|_| anyhow::anyhow!("the XLA backend serves f32 batches only"))?;
        let kind = match shape.kind {
            KIND_SIG => ArtifactKind::Sig,
            KIND_LOGSIG => ArtifactKind::LogSig,
            KIND_SIGGRAD => ArtifactKind::SigGrad,
            other => anyhow::bail!("unknown batch kind {other}"),
        };
        let entry = self
            .registry
            .find(kind, shape.batch, shape.length, shape.d, shape.depth)
            .ok_or_else(|| anyhow::anyhow!("artifact disappeared for {shape:?}"))?;
        let values = match kind {
            ArtifactKind::Sig | ArtifactKind::LogSig => {
                self.engine.forward(entry, padded.to_vec())?
            }
            ArtifactKind::SigGrad => {
                // Rows are path || cotangent; de-interleave into the two
                // positional inputs.
                let in_path = shape.length * shape.d;
                let sig_len: usize = (1..=shape.depth).map(|k| shape.d.pow(k as u32)).sum();
                let row = in_path + sig_len;
                let mut paths = vec![0.0f32; shape.batch * in_path];
                let mut cots = vec![0.0f32; shape.batch * sig_len];
                for b in 0..shape.batch {
                    let r = &padded[b * row..(b + 1) * row];
                    paths[b * in_path..(b + 1) * in_path].copy_from_slice(&r[..in_path]);
                    cots[b * sig_len..(b + 1) * sig_len].copy_from_slice(&r[in_path..]);
                }
                self.engine.grad(entry, paths, cots)?
            }
            ArtifactKind::Train => anyhow::bail!("train artifacts are not batched"),
        };
        Ok(values.into())
    }
}

/// Native batch backend: executes a flushed microbatch of same-spec
/// signature *or logsignature* requests as one lane-fused sweep over the
/// *real* rows only (no static-shape constraint, so the padding slots are
/// never computed). Each signature row is bitwise identical to a
/// stand-alone [`crate::signature::signature`] call; each logsignature row
/// is bitwise identical to the direct scalar serve (the same signature
/// sweep plus the same per-row log + projection epilogue, through the
/// shared Words-basis plan cache).
struct NativeLaneBackend {
    threads: usize,
    planner: Arc<ExecPlanner>,
    metrics: Arc<Metrics>,
    /// Shared Words-basis plan cache (see [`WordsPlanCache`]).
    plans: Arc<WordsPlanCache>,
}

impl BatchBackend for NativeLaneBackend {
    fn run(&self, shape: &BatchShape, padded: &Rows, n_real: usize) -> anyhow::Result<Rows> {
        use std::sync::atomic::Ordering;
        anyhow::ensure!(
            shape.kind == KIND_SIG_NATIVE || shape.kind == KIND_LOGSIG_NATIVE,
            "unexpected native batch kind"
        );
        let spec = SigSpec::new(shape.d, shape.depth)?;
        // No static-shape constraint here: compute only the real rows (a
        // sparse flush must not pay for the padding slots). The plan comes
        // from the execution planner; a lone-row flush is guaranteed the
        // scalar reference sweep — a request's bits must not depend on
        // whether traffic happened to coalesce with it.
        let rows = n_real.clamp(1, shape.batch);
        let work = WorkShape {
            batch: rows,
            points: shape.length,
            d: shape.d,
            depth: shape.depth,
            dtype: shape.prec,
        };
        let plan = self.planner.plan_native_flush(rows, &work);
        match plan {
            ExecPlan::Scalar => self.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed),
            ExecPlan::StreamParallel { .. } => {
                self.metrics.dispatch_stream_parallel.fetch_add(1, Ordering::Relaxed)
            }
            ExecPlan::LaneFused { .. } => {
                self.metrics.dispatch_lane_fused.fetch_add(1, Ordering::Relaxed)
            }
        };
        let cfg = SigConfig { threads: self.threads, ..SigConfig::serial() };
        if shape.kind == KIND_LOGSIG_NATIVE {
            let lplan = self.plans.get(shape.d, shape.depth)?;
            anyhow::ensure!(
                shape.out_dim == lplan.dim(),
                "logsig microbatch out_dim {} does not match the plan dimension {}",
                shape.out_dim,
                lplan.dim()
            );
            // One generic body: the queue's dtype picks the element type
            // here — and the whole pipeline (lane sweeps, log, Words
            // projection) runs at that width on the rows as submitted.
            // Precision is part of the queue identity
            // ([`BatchShape::prec`]), so a flush is homogeneous by
            // construction.
            return with_elem!(shape.prec, E, {
                let real = &E::rows_as_slice(padded)?[..rows * shape.in_row()];
                let out =
                    logsignature_batch_planned(real, rows, shape.length, &spec, &lplan, &cfg, plan)?;
                Ok(E::rows_from(out))
            });
        }
        with_elem!(shape.prec, E, {
            let real = &E::rows_as_slice(padded)?[..rows * shape.in_row()];
            let out = signature_batch_planned(real, rows, shape.length, &spec, &cfg, plan)?;
            Ok(E::rows_from(out))
        })
    }
}

/// The coordinator: router + batchers + sessions + planner + metrics.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    registry: Option<Arc<Registry>>,
    engine: Option<EngineHandle>,
    batcher: Option<Batcher>,
    /// Lane-fused microbatcher for native signature requests
    /// ([`DispatchConfig::microbatch`]).
    native_batcher: Option<Batcher>,
    /// Lane-fused batcher for stateful session feeds
    /// ([`DispatchConfig::feed_lanes`]).
    feed_lane: Option<FeedLane>,
    sessions: Arc<SessionManager>,
    /// The execution planner: strategy selection plus the observed
    /// shape-mix histogram all native dispatch flows through.
    planner: Arc<ExecPlanner>,
    metrics: Arc<Metrics>,
    /// Words-basis logsignature plans ([`WordsPlanCache`]), shared with
    /// the native microbatch backend so one build serves direct and
    /// batched rows alike.
    plans: Arc<WordsPlanCache>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let planner = Arc::new(ExecPlanner::with_mix_window(
            cfg.native_threads,
            cfg.dispatch.mix_window,
        ));
        let (registry, engine, batcher) = match &cfg.artifact_dir {
            Some(dir) if dir.join("MANIFEST.json").exists() => {
                let (engine, registry) = EngineHandle::spawn(dir.clone())?;
                let registry = Arc::new(registry);
                let backend = Arc::new(XlaBackend {
                    engine: engine.clone(),
                    registry: Arc::clone(&registry),
                });
                let batcher = Batcher::new(backend, Arc::clone(&metrics), cfg.linger);
                (Some(registry), Some(engine), Some(batcher))
            }
            _ => (None, None, None),
        };
        let plans = Arc::new(WordsPlanCache::new());
        let native_batcher = if cfg.dispatch.microbatch >= 2 {
            Some(Batcher::new(
                Arc::new(NativeLaneBackend {
                    threads: cfg.native_threads,
                    planner: Arc::clone(&planner),
                    metrics: Arc::clone(&metrics),
                    plans: Arc::clone(&plans),
                }),
                Arc::clone(&metrics),
                cfg.linger,
            ))
        } else {
            None
        };
        let sessions =
            Arc::new(SessionManager::with_config(Arc::clone(&metrics), cfg.session.clone())?);
        // The feed lane rides the same escape hatch as the microbatcher:
        // `microbatch = 0` (the old `native_batch = 0`) means no native
        // request of any kind ever waits out a linger.
        let feed_lane = if cfg.dispatch.feed_lanes && cfg.dispatch.microbatch >= 2 {
            Some(FeedLane::new(Arc::clone(&sessions), cfg.linger))
        } else {
            None
        };
        Ok(Coordinator {
            sessions,
            registry,
            engine,
            batcher,
            native_batcher,
            feed_lane,
            planner,
            metrics,
            cfg,
            plans,
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }

    /// The coordinator's execution planner (strategy decisions + the
    /// observed shape mix).
    pub fn planner(&self) -> &ExecPlanner {
        &self.planner
    }

    /// Refresh the shape-mix gauge from the planner's histogram.
    fn publish_shape_mix(&self) {
        self.metrics
            .shape_mix_shapes
            .store(self.planner.mix().distinct() as u64, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn has_xla(&self) -> bool {
        self.batcher.is_some()
    }

    pub fn engine(&self) -> Option<&EngineHandle> {
        self.engine.as_ref()
    }

    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    fn plan(&self, d: usize, depth: usize) -> anyhow::Result<Arc<LogSigPlan>> {
        self.plans.get(d, depth)
    }

    /// Shared serving path for stateless native `Signature` /
    /// `LogSignature` requests: record the shape into the planner's mix,
    /// quote the adaptive per-shape capacity, and either coalesce into the
    /// lane-fused microbatcher (capacity >= 2) or run `direct` — the
    /// scalar reference computation, bitwise identical to a microbatched
    /// lone row. One implementation, **generic over the element type**, so
    /// a fix to the capacity quote or the batcher plumbing can never make
    /// the two request kinds — or the two precisions — diverge: the
    /// precision was dispatched exactly once, before this call, and
    /// everything here runs at `E`'s native width.
    #[allow(clippy::too_many_arguments)]
    fn serve_native_stateless<E: Elem>(
        &self,
        key: ShapeKey,
        kind: u8,
        stream: usize,
        d: usize,
        depth: usize,
        out_dim: usize,
        path: Vec<E>,
        direct: impl FnOnce(Vec<E>) -> anyhow::Result<Vec<E>>,
    ) -> anyhow::Result<Rows> {
        use std::sync::atomic::Ordering;
        self.planner.record_shape(key);
        self.publish_shape_mix();
        // Capacity 1 = serve directly, no linger; the planner adapts it
        // per shape after warm-up when adaptive dispatch is on.
        let capacity = match &self.native_batcher {
            Some(_) if self.cfg.dispatch.adaptive => {
                self.planner.microbatch_capacity(self.cfg.dispatch.microbatch, key)
            }
            Some(_) => self.cfg.dispatch.microbatch,
            None => 0,
        };
        if let (Some(nb), true) = (&self.native_batcher, capacity >= 2) {
            let shape = BatchShape {
                kind,
                batch: capacity,
                length: stream,
                d,
                depth,
                prec: E::PRECISION,
                in_dim: stream * d,
                out_dim,
            };
            let rx = nb.submit(shape, E::rows_from(path))?;
            return rx
                .recv()
                .map_err(|_| anyhow::anyhow!("native batcher dropped request"))?;
        }
        self.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed);
        direct(path).map(E::rows_from)
    }

    /// Serve one request synchronously, routing per configuration.
    pub fn call(&self, req: Request) -> anyhow::Result<Response> {
        use std::sync::atomic::Ordering;
        let t0 = Instant::now();
        let kind = req.kind();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let result = self.route(req);
        // Into the global mean and this kind's log2 histogram (the
        // serve CLIs print p50/p90/p99 per kind off the latter).
        self.metrics.record_latency(kind, t0.elapsed());
        if result.is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn route(&self, mut req: Request) -> anyhow::Result<Response> {
        use std::sync::atomic::Ordering;
        // Streaming (stateful) requests: served by the session table on
        // the native engine, never batched. (`&mut` so the feed lane can
        // move the point buffer out instead of cloning it; stateless
        // requests pass through untouched.)
        if let Some(resp) = self.route_stream(&mut req)? {
            return Ok(resp);
        }
        // Try the XLA path when configured and an artifact matches.
        // (`&mut` so a routed request can move its buffers into the
        // batcher instead of cloning; once an artifact matched, the
        // native fallback below never sees the request again.)
        if self.cfg.prefer_xla {
            if let (Some(reg), Some(batcher)) = (&self.registry, &self.batcher) {
                // XLA artifacts are compiled for f32 — f64 requests fall
                // through to the native engine (the only backend with a
                // precision axis).
                let routed = match &mut req {
                    Request::Signature { path, stream, d, depth }
                        if path.precision() == Precision::F32 =>
                    {
                        reg.find_batchable(ArtifactKind::Sig, 1, *stream, *d, *depth).map(|e| {
                            let shape = BatchShape {
                                kind: KIND_SIG,
                                batch: e.batch,
                                length: *stream,
                                d: *d,
                                depth: *depth,
                                prec: Precision::F32,
                                in_dim: *stream * *d,
                                out_dim: e.out_dim,
                            };
                            batcher.submit(shape, std::mem::take(path))
                        })
                    }
                    Request::LogSignature { path, stream, d, depth }
                        if path.precision() == Precision::F32 =>
                    {
                        reg.find_batchable(ArtifactKind::LogSig, 1, *stream, *d, *depth).map(|e| {
                            self.metrics.logsig_requests.fetch_add(1, Ordering::Relaxed);
                            let shape = BatchShape {
                                kind: KIND_LOGSIG,
                                batch: e.batch,
                                length: *stream,
                                d: *d,
                                depth: *depth,
                                prec: Precision::F32,
                                in_dim: *stream * *d,
                                out_dim: e.out_dim,
                            };
                            batcher.submit(shape, std::mem::take(path))
                        })
                    }
                    Request::SignatureGrad { path, stream, d, depth, cotangent }
                        if path.precision() == Precision::F32
                            && cotangent.precision() == Precision::F32 =>
                    {
                        reg.find_batchable(ArtifactKind::SigGrad, 1, *stream, *d, *depth).map(
                            |e| {
                                let mut row = std::mem::take(path);
                                row.extend_from(cotangent)
                                    .expect("both grad buffers are f32 (guard above)");
                                let shape = BatchShape {
                                    kind: KIND_SIGGRAD,
                                    batch: e.batch,
                                    length: *stream,
                                    d: *d,
                                    depth: *depth,
                                    prec: Precision::F32,
                                    in_dim: row.len(),
                                    out_dim: e.out_dim,
                                };
                                batcher.submit(shape, row)
                            },
                        )
                    }
                    // Streaming requests were already dispatched above;
                    // f64 rows route native (the only typed backend).
                    _ => None,
                };
                if let Some(rx) = routed {
                    let rx = rx?;
                    let values = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("batcher dropped request"))??;
                    self.metrics.xla_requests.fetch_add(1, Ordering::Relaxed);
                    return Ok(Response {
                        precision: values.precision(),
                        values,
                        backend: Backend::Xla,
                        session: None,
                        window_slide: None,
                        window_remaining: None,
                    });
                }
            }
        }
        // Native path. All shapes are validated up front so malformed
        // requests are an `Err` here, never a panic on a serving thread.
        // The element type is dispatched from the row buffer **exactly
        // once** per arm (`with_elem!`); past that point everything is
        // `Elem`-generic and the two precisions cannot diverge.
        let values = match req {
            Request::Signature { path, stream, d, depth } => {
                let spec = SigSpec::with_dtype(d, depth, path.precision())?;
                anyhow::ensure!(path.len() == stream * d, "bad path buffer");
                anyhow::ensure!(stream >= 2, "a path needs at least two points, got {stream}");
                // Lane-fused microbatching via the shared stateless path:
                // same-spec requests gathered within the linger window
                // execute as one interleaved sweep, each row bitwise
                // identical to a stand-alone signature call. The shape key
                // carries the dtype, so f32 and f64 traffic of one shape
                // adapts — and batches — independently.
                with_elem!(spec.dtype(), E, {
                    self.serve_native_stateless::<E>(
                        ShapeKey::signature(d, depth, stream).with_dtype(spec.dtype()),
                        KIND_SIG_NATIVE,
                        stream,
                        d,
                        depth,
                        spec.sig_len(),
                        E::rows_into(path)?,
                        |p| signature_with(&p, stream, &spec, &SigConfig::serial()),
                    )?
                })
            }
            Request::LogSignature { path, stream, d, depth } => {
                let spec = SigSpec::with_dtype(d, depth, path.precision())?;
                anyhow::ensure!(path.len() == stream * d, "bad path buffer");
                anyhow::ensure!(stream >= 2, "a path needs at least two points, got {stream}");
                self.metrics.logsig_requests.fetch_add(1, Ordering::Relaxed);
                // Logsignature parity: same shared path, keyed under its
                // own logsig kind (sig and logsig adapt — and batch —
                // independently), with a per-row log + Words-projection
                // epilogue on the flushed sweep. `native_batch = 0`
                // disables batching here too. The epilogue is generic over
                // the element precision, so f64 rows run log + projection
                // at f64 natively, in their own microbatch queue
                // (`with_dtype`).
                let lplan = self.plan(d, depth)?;
                with_elem!(spec.dtype(), E, {
                    self.serve_native_stateless::<E>(
                        ShapeKey::logsignature(d, depth, stream).with_dtype(spec.dtype()),
                        KIND_LOGSIG_NATIVE,
                        stream,
                        d,
                        depth,
                        lplan.dim(),
                        E::rows_into(path)?,
                        |p| logsignature_with(&p, stream, &spec, &lplan, &SigConfig::serial()),
                    )?
                })
            }
            Request::SignatureGrad { path, stream, d, depth, cotangent } => {
                let spec = SigSpec::with_dtype(d, depth, path.precision())?;
                anyhow::ensure!(
                    cotangent.precision() == path.precision(),
                    "cotangent rows are {} but the path is {}",
                    cotangent.precision().label(),
                    path.precision().label()
                );
                // Shape validation happens inside the VJP. Per-request
                // stream parallelism is capped by the dispatch config: the
                // coordinator already serves requests concurrently (one
                // caller thread each), so uncapped native_threads here
                // would multiply into requests x cores scoped workers
                // under load. Within that budget the planner decides
                // whether the chunked Chen-identity backward engages.
                let threads =
                    self.cfg.native_threads.min(self.cfg.dispatch.grad_stream_threads.max(1));
                // This plan is derived for the dispatch counter only; the
                // VJP re-derives the identical plan internally. The two
                // agree because this request carries no basepoint/initial
                // (effective points == stream) and both use `threads`.
                let plan = ExecPlanner::new(threads).plan_backward(&WorkShape {
                    batch: 1,
                    points: stream,
                    d,
                    depth,
                    dtype: spec.dtype(),
                });
                match plan {
                    ExecPlan::StreamParallel { .. } => self
                        .metrics
                        .dispatch_stream_parallel
                        .fetch_add(1, Ordering::Relaxed),
                    _ => self.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed),
                };
                let cfg = SigConfig { threads, ..SigConfig::serial() };
                // The reversibility-based backward runs entirely at the
                // rows' native width; the gradient comes back at the same
                // width.
                with_elem!(spec.dtype(), E, {
                    let path = E::rows_into(path)?;
                    let cot = E::rows_into(cotangent)?;
                    E::rows_from(signature_vjp_with(&path, stream, &spec, &cfg, &cot)?.grad_path)
                })
            }
            Request::OpenStream { .. }
            | Request::Feed { .. }
            | Request::QueryInterval { .. }
            | Request::LogSigQueryInterval { .. }
            | Request::CloseStream { .. }
            | Request::OpenWindow { .. }
            | Request::PollWindow { .. } => unreachable!("handled by route_stream"),
        };
        self.metrics.native_requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Response {
            precision: values.precision(),
            values,
            backend: Backend::Native,
            session: None,
            window_slide: None,
            window_remaining: None,
        })
    }

    /// Serve a streaming request against the session table; `Ok(None)` for
    /// stateless requests (which fall through to the backends, untouched).
    fn route_stream(&self, req: &mut Request) -> anyhow::Result<Option<Response>> {
        // Classify exhaustively (no catch-all): a new Request variant must
        // be consciously filed as stateless here or handled below.
        match req {
            Request::Signature { .. }
            | Request::LogSignature { .. }
            | Request::SignatureGrad { .. } => return Ok(None),
            Request::OpenStream { .. }
            | Request::Feed { .. }
            | Request::QueryInterval { .. }
            | Request::LogSigQueryInterval { .. }
            | Request::CloseStream { .. }
            | Request::OpenWindow { .. }
            | Request::PollWindow { .. } => {}
        }
        // Counted before serving, so failed streaming requests are still
        // attributed to the streaming surface.
        self.metrics
            .stream_requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut window_remaining = None;
        let (values, session, window_slide) = match req {
            Request::OpenStream { points, stream, d, depth } => {
                // The seed rows' element width becomes the session's
                // recorded dtype: every later feed must match it, and
                // every response comes back at it.
                let spec = SigSpec::with_dtype(*d, *depth, points.precision())?;
                anyhow::ensure!(points.len() == *stream * *d, "bad point buffer");
                // One call returning both id and seed signature: a racing
                // eviction after the insert must not turn a successful
                // open into an "unknown session" error.
                let (id, sig) = self.sessions.open_with_signature(&spec, points, *stream)?;
                (sig, Some(id), None)
            }
            Request::OpenWindow { points, stream, d, depth, window } => {
                let spec = SigSpec::with_dtype(*d, *depth, points.precision())?;
                anyhow::ensure!(points.len() == *stream * *d, "bad point buffer");
                if window.logsig.is_some() {
                    self.metrics
                        .logsig_requests
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                // A windowed session is a future feeder: record its feed
                // shape into the planner's observed mix now, so the feed
                // lane's capacity decisions see windowed traffic coming
                // (same key the later feeds will carry).
                self.planner
                    .record_shape(ShapeKey::feed(*d, *depth).with_dtype(spec.dtype()));
                self.publish_shape_mix();
                let (id, sig) = self.sessions.open_window(&spec, points, *stream, *window)?;
                (sig, Some(id), None)
            }
            Request::PollWindow { session, max_slides } => {
                let (first, rows, left) = self.sessions.poll_window_page(*session, *max_slides)?;
                window_remaining = Some(left);
                (rows, Some(*session), Some(first))
            }
            Request::Feed { session, points, count } => {
                let sig = if let Some(lane) = &self.feed_lane {
                    // Resolve the session's spec first: an unknown session
                    // errors here instead of after a linger, and the spec
                    // — `(d, depth, dtype)`, so f32 and f64 sessions never
                    // share a sweep — keys the lane group. The planner
                    // only opens a lane once >= 2 distinct sessions feed
                    // this spec; a lone feeder gets capacity 1 and stays
                    // on the direct scalar path (no linger — feeds are
                    // latency-direct by default).
                    let spec = self.sessions.session_spec(*session)?;
                    let key = (spec.d(), spec.depth(), spec.dtype());
                    let capacity = self.planner.feed_lane_capacity(
                        self.cfg.dispatch.microbatch,
                        ShapeKey::feed(spec.d(), spec.depth()).with_dtype(spec.dtype()),
                        session.0,
                    );
                    self.publish_shape_mix();
                    if capacity >= 2 {
                        // Move the payload into the lane (no copy; this
                        // request is consumed by the streaming path).
                        let points = std::mem::take(points);
                        let rx = lane.submit(key, capacity, *session, points, *count)?;
                        rx.recv()
                            .map_err(|_| anyhow::anyhow!("feed lane dropped request"))??
                    } else {
                        self.sessions.feed(*session, points, *count)?
                    }
                } else {
                    self.sessions.feed(*session, points, *count)?
                };
                (sig, Some(*session), None)
            }
            Request::QueryInterval { session, i, j } => {
                (self.sessions.query(*session, *i, *j)?, Some(*session), None)
            }
            Request::LogSigQueryInterval { session, i, j } => {
                self.metrics
                    .logsig_requests
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                // Resolve the session once; the plan comes from the
                // coordinator's cache keyed by the session's (d, depth).
                let out = self
                    .sessions
                    .logsig_query_with(*session, *i, *j, |spec| self.plan(spec.d(), spec.depth()))?;
                (out, Some(*session), None)
            }
            Request::CloseStream { session } => {
                // Resolve the spec before the close so the planner can
                // drop this session from the spec's feeder ring: a
                // surviving lone feeder must fall back to the direct path
                // on its next feed, not after the closed peer ages out of
                // the recency window.
                let spec = self.sessions.session_spec(*session).ok();
                self.sessions.close(*session)?;
                // An empty buffer, still typed at the session's dtype so
                // the response's precision stays truthful.
                let empty =
                    Rows::zeros(spec.as_ref().map_or(Precision::F32, |s| s.dtype()), 0);
                if let Some(spec) = spec {
                    self.planner.forget_feeder(
                        ShapeKey::feed(spec.d(), spec.depth()).with_dtype(spec.dtype()),
                        session.0,
                    );
                }
                (empty, Some(*session), None)
            }
            Request::Signature { .. }
            | Request::LogSignature { .. }
            | Request::SignatureGrad { .. } => unreachable!("stateless; returned above"),
        };
        // The precision is read off the result rows — a session's recorded
        // dtype, not an assumption (f64 sessions answer `F64` here).
        Ok(Some(Response {
            precision: values.precision(),
            values,
            backend: Backend::Native,
            session,
            window_slide,
            window_remaining,
        }))
    }

    /// Serve a whole batch concurrently (used by examples and benches):
    /// spawns one caller thread per request so the dynamic batcher can
    /// coalesce them.
    pub fn call_many(&self, reqs: Vec<Request>) -> Vec<anyhow::Result<Response>> {
        std::thread::scope(|scope| {
            let handles: Vec<_> =
                reqs.into_iter().map(|r| scope.spawn(move || self.call(r))).collect();
            handles.into_iter().map(|h| h.join().expect("caller thread")).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    fn native() -> Coordinator {
        Coordinator::new(CoordinatorConfig::native_only()).unwrap()
    }

    /// Widen f32 test fixtures to exact f64 values (value-preserving, so
    /// the f64 oracles are well-defined without generating f64 fixtures).
    fn widen(v: &[f32]) -> Vec<f64> {
        v.iter().copied().map(f64::from).collect()
    }

    #[test]
    fn native_signature_roundtrip() {
        let c = native();
        let mut rng = Rng::new(1);
        let path = rng.normal_vec(8 * 2, 0.4);
        let resp = c
            .call(Request::Signature { path: path.clone().into(), stream: 8, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(resp.backend, Backend::Native);
        assert_eq!(resp.precision, Precision::F32);
        let spec = SigSpec::new(2, 3).unwrap();
        assert_close(resp.values.as_f32().unwrap(), &signature(&path, 8, &spec), 1e-6, 1e-7);
        assert_eq!(c.metrics().snapshot().native_requests, 1);
    }

    #[test]
    fn native_logsignature_dimension() {
        let c = native();
        let mut rng = Rng::new(2);
        let path = rng.normal_vec(6 * 3, 0.4);
        let resp = c
            .call(Request::LogSignature { path: path.into(), stream: 6, d: 3, depth: 3 })
            .unwrap();
        assert_eq!(resp.values.len(), crate::words::witt_dimension(3, 3));
    }

    #[test]
    fn native_grad_roundtrip() {
        let c = native();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(3);
        let path = rng.normal_vec(5 * 2, 0.4);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let resp = c
            .call(Request::SignatureGrad {
                path: path.clone().into(),
                stream: 5,
                d: 2,
                depth: 3,
                cotangent: cot.clone().into(),
            })
            .unwrap();
        // Short stream: the router's parallel config falls back to the
        // serial sweep, so this is bitwise the serial VJP.
        assert_close(
            resp.values.as_f32().unwrap(),
            &crate::signature::signature_vjp(&path, 5, &spec, &cot),
            1e-6,
            1e-7,
        );
    }

    #[test]
    fn native_grad_long_stream_uses_parallel_backward() {
        let c = native();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(30);
        let stream = 96;
        let path = rng.normal_vec(stream * 2, 0.1);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let resp = c
            .call(Request::SignatureGrad {
                path: path.clone().into(),
                stream,
                d: 2,
                depth: 3,
                cotangent: cot.clone().into(),
            })
            .unwrap();
        let serial = crate::signature::signature_vjp(&path, stream, &spec, &cot);
        assert_close(resp.values.as_f32().unwrap(), &serial, 2e-3, 1e-4);
        // Mismatched cotangent shape is a clean error, not a panic.
        assert!(c
            .call(Request::SignatureGrad {
                path: path.into(),
                stream,
                d: 2,
                depth: 3,
                cotangent: vec![0.0f32; spec.sig_len() - 1].into(),
            })
            .is_err());
    }

    #[test]
    fn bad_shapes_error_and_count() {
        let c = native();
        let bad =
            c.call(Request::Signature { path: vec![0.0f32; 3].into(), stream: 8, d: 2, depth: 3 });
        assert!(bad.is_err());
        assert_eq!(c.metrics().snapshot().errors, 1);
    }

    #[test]
    fn call_many_native() {
        let c = native();
        let mut rng = Rng::new(4);
        let reqs: Vec<Request> = (0..6)
            .map(|_| Request::Signature {
                path: rng.normal_vec(8 * 2, 0.4).into(),
                stream: 8,
                d: 2,
                depth: 3,
            })
            .collect();
        let resps = c.call_many(reqs);
        assert_eq!(resps.len(), 6);
        for r in resps {
            assert!(r.is_ok());
        }
        assert_eq!(c.metrics().snapshot().requests, 6);
    }

    #[test]
    fn streaming_requests_served_through_call() {
        let c = native();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(8);
        let all = rng.normal_vec(16 * 2, 0.3);

        let open = c
            .call(Request::OpenStream {
                points: all[..6 * 2].to_vec().into(),
                stream: 6,
                d: 2,
                depth: 3,
            })
            .unwrap();
        assert_eq!(open.backend, Backend::Native);
        assert_eq!(open.precision, Precision::F32);
        let sid = open.session.expect("open returns a session id");
        assert_close(open.values.as_f32().unwrap(), &signature(&all[..6 * 2], 6, &spec), 1e-6, 1e-7);

        let fed = c
            .call(Request::Feed { session: sid, points: all[6 * 2..].to_vec().into(), count: 10 })
            .unwrap();
        assert_close(fed.values.as_f32().unwrap(), &signature(&all, 16, &spec), 2e-3, 1e-4);

        // Interval query crossing the feed boundary.
        let q = c.call(Request::QueryInterval { session: sid, i: 3, j: 12 }).unwrap();
        assert_close(
            q.values.as_f32().unwrap(),
            &signature(&all[3 * 2..13 * 2], 10, &spec),
            5e-3,
            5e-4,
        );

        // Logsig query uses the coordinator's cached words-basis plan.
        let lq = c.call(Request::LogSigQueryInterval { session: sid, i: 3, j: 12 }).unwrap();
        assert_eq!(lq.values.len(), crate::words::witt_dimension(2, 3));

        let snap = c.metrics().snapshot();
        assert_eq!(snap.stream_requests, 4);
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.open_sessions, 1);
        assert!(snap.session_bytes > 0);

        c.call(Request::CloseStream { session: sid }).unwrap();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.open_sessions, 0);
        assert_eq!(snap.session_bytes, 0);
        // Requests against a closed session error and count once.
        assert!(c.call(Request::QueryInterval { session: sid, i: 0, j: 3 }).is_err());
        assert_eq!(c.metrics().snapshot().errors, 1);
    }

    #[test]
    fn session_budget_enforced_through_coordinator_config() {
        let spec = SigSpec::new(2, 3).unwrap();
        // Room for about three 8-point sessions; measure the per-session
        // storage on a throwaway Path rather than hard-coding its layout.
        let per = crate::path::Path::new(&spec, &[0.0f32; 8 * 2], 8)
            .unwrap()
            .storage_bytes();
        let c = Coordinator::new(CoordinatorConfig {
            session: SessionConfig {
                budget_bytes: Some(3 * per + per / 2),
                ..Default::default()
            },
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        let mut rng = Rng::new(9);
        let mut sids = vec![];
        for _ in 0..5 {
            let resp = c
                .call(Request::OpenStream {
                    points: rng.normal_vec(8 * 2, 0.3).into(),
                    stream: 8,
                    d: 2,
                    depth: 3,
                })
                .unwrap();
            sids.push(resp.session.unwrap());
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.sessions_opened, 5);
        assert_eq!(snap.sessions_evicted, 2);
        assert_eq!(snap.open_sessions, 3);
        assert!(snap.session_bytes as usize <= 3 * per + per / 2);
        // The two oldest sessions were evicted, in order.
        assert!(c.call(Request::QueryInterval { session: sids[0], i: 0, j: 7 }).is_err());
        assert!(c.call(Request::QueryInterval { session: sids[1], i: 0, j: 7 }).is_err());
        for &sid in &sids[2..] {
            assert!(c.call(Request::QueryInterval { session: sid, i: 0, j: 7 }).is_ok());
        }
    }

    /// A batch backend that always fails (for error-accounting tests).
    struct FailBackend;

    impl BatchBackend for FailBackend {
        fn run(&self, _shape: &BatchShape, _padded: &Rows, _n_real: usize) -> anyhow::Result<Rows> {
            anyhow::bail!("backend down")
        }
    }

    #[test]
    fn batch_backend_failure_counts_once_per_request() {
        // Regression for the double count: `execute_batch` used to bump
        // `errors` per failed batch *and* `call` bumped it again per
        // request. Two requests failing in one batch must yield errors=2
        // (one each) and batch_failures=1.
        use crate::runtime::ArtifactEntry;
        let metrics = Arc::new(Metrics::default());
        let spec = SigSpec::new(2, 3).unwrap();
        let registry = Arc::new(Registry {
            dir: PathBuf::from("/nonexistent"),
            entries: vec![ArtifactEntry {
                file: "mock".into(),
                kind: ArtifactKind::Sig,
                batch: 2,
                length: 4,
                d: 2,
                depth: 3,
                out_dim: spec.sig_len(),
                pallas: false,
                hidden: 0,
                d_out: 0,
            }],
        });
        // Generous linger: both caller threads must land in one pending
        // batch even if thread spawn stalls; the batch fills at 2 rows, so
        // the failure path executes inline and never waits this long.
        let batcher =
            Batcher::new(Arc::new(FailBackend), Arc::clone(&metrics), Duration::from_millis(250));
        let c = Coordinator {
            cfg: CoordinatorConfig {
                artifact_dir: None,
                prefer_xla: true,
                ..CoordinatorConfig::native_only()
            },
            registry: Some(registry),
            engine: None,
            batcher: Some(batcher),
            native_batcher: None,
            feed_lane: None,
            sessions: Arc::new(SessionManager::new(Arc::clone(&metrics))),
            planner: Arc::new(ExecPlanner::new(2)),
            metrics,
            plans: Arc::new(WordsPlanCache::new()),
        };
        let mut rng = Rng::new(10);
        let reqs: Vec<Request> = (0..2)
            .map(|_| Request::Signature {
                path: rng.normal_vec(4 * 2, 0.3).into(),
                stream: 4,
                d: 2,
                depth: 3,
            })
            .collect();
        for r in c.call_many(reqs) {
            assert!(r.is_err());
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.errors, 2, "one error per failed request");
        assert_eq!(snap.batch_failures, 1, "one failed batch execution");
    }

    #[test]
    fn native_microbatch_coalesces_same_spec_requests() {
        // Six concurrent same-spec requests inside one linger window must
        // execute as ONE lane-fused microbatch (metrics: 1 batch, 6 real
        // rows), each caller receiving the bitwise per-path signature.
        let c = Coordinator::new(
            CoordinatorConfig {
                // Generous linger: all six caller threads must land in one
                // pending batch even if thread spawn stalls; the batch
                // never fills (6 < 8), so the flusher fires it at the
                // deadline.
                linger: Duration::from_millis(250),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(12);
        let paths: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(8 * 2, 0.4)).collect();
        let reqs: Vec<Request> = paths
            .iter()
            .map(|p| Request::Signature { path: p.clone().into(), stream: 8, d: 2, depth: 3 })
            .collect();
        let resps = c.call_many(reqs);
        for (p, r) in paths.iter().zip(&resps) {
            let r = r.as_ref().expect("response");
            assert_eq!(r.backend, Backend::Native);
            assert_eq!(r.values, signature(p, 8, &spec), "lane row != per-path signature");
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.native_requests, 6);
        assert_eq!(snap.batches, 1, "same-spec requests should coalesce into one microbatch");
        assert_eq!(snap.real_rows, 6);
        assert_eq!(snap.padded_rows, 8);
    }

    #[test]
    fn native_logsig_microbatch_coalesces_same_spec_requests_bitwise() {
        // The PR 5 acceptance test: six concurrent same-spec LogSignature
        // requests inside one linger window must execute as ONE lane-fused
        // microbatch (1 batch, 6 real rows), each caller receiving the
        // Words-basis logsignature bitwise identical to a scalar serve.
        let c = Coordinator::new(
            CoordinatorConfig {
                // Generous linger: all six caller threads must land in one
                // pending batch even if thread spawn stalls; the batch
                // never fills (6 < 8), so the flusher fires it.
                linger: Duration::from_millis(250),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(22);
        let paths: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(8 * 2, 0.4)).collect();
        let reqs: Vec<Request> = paths
            .iter()
            .map(|p| Request::LogSignature { path: p.clone().into(), stream: 8, d: 2, depth: 3 })
            .collect();
        let resps = c.call_many(reqs);
        for (p, r) in paths.iter().zip(&resps) {
            let r = r.as_ref().expect("response");
            assert_eq!(r.backend, Backend::Native);
            let scalar =
                logsignature_with(p, 8, &spec, &plan, &SigConfig::serial()).unwrap();
            assert_eq!(r.values, scalar, "microbatched logsig row != scalar serve");
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.logsig_requests, 6);
        assert_eq!(snap.batches, 1, "same-spec logsig requests share one microbatch");
        assert_eq!(snap.real_rows, 6);
        assert_eq!(snap.padded_rows, 8);
    }

    #[test]
    fn sig_and_logsig_of_one_shape_batch_separately() {
        // Same (d, depth, stream) but different kinds: a Signature and a
        // LogSignature request must never share a microbatch (different
        // output widths and epilogues), yet both still serve exactly.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(10),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(23);
        let p = rng.normal_vec(6 * 2, 0.4);
        let resps = c.call_many(vec![
            Request::Signature { path: p.clone().into(), stream: 6, d: 2, depth: 3 },
            Request::LogSignature { path: p.clone().into(), stream: 6, d: 2, depth: 3 },
        ]);
        assert_eq!(resps[0].as_ref().unwrap().values, signature(&p, 6, &spec));
        assert_eq!(
            resps[1].as_ref().unwrap().values,
            logsignature_with(&p, 6, &spec, &plan, &SigConfig::serial()).unwrap()
        );
        assert_eq!(c.metrics().snapshot().batches, 2, "kinds must not share a queue");
    }

    #[test]
    fn f32_and_f64_of_one_shape_never_share_a_microbatch() {
        // One logical shape, two element widths. The dtype keys both the
        // planner's shape mix and the batcher queue, so the two requests
        // flush as TWO microbatches — an f32 request round-trips without
        // ever sharing a queue with f64 — and the f64 row is the *native*
        // f64 sweep, answered in f64 (no downcast anywhere).
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(10),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(24);
        let p = rng.normal_vec(6 * 2, 0.4);
        let wide = widen(&p);
        let resps = c.call_many(vec![
            Request::Signature { path: p.clone().into(), stream: 6, d: 2, depth: 3 },
            Request::Signature { path: wide.clone().into(), stream: 6, d: 2, depth: 3 },
        ]);
        let r32 = resps[0].as_ref().unwrap();
        let r64 = resps[1].as_ref().unwrap();
        assert_eq!(r32.precision, Precision::F32);
        assert_eq!(r64.precision, Precision::F64);
        assert_eq!(r32.values, signature(&p, 6, &spec));
        let want64 = signature_with(&wide, 6, &spec, &SigConfig::serial()).unwrap();
        assert_eq!(r64.values, want64, "f64 row != the native f64 oracle");
        assert_eq!(c.metrics().snapshot().batches, 2, "precisions must not share a queue");
    }

    #[test]
    fn native_microbatch_coalesces_f64_rows_bitwise() {
        // The lane plans execute natively at f64 too: six concurrent f64
        // requests of one spec coalesce into ONE lane-fused microbatch,
        // and every row is bitwise the stand-alone native f64 serve —
        // coalescing must never change a caller's bits, in either
        // precision.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(250),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(25);
        let paths: Vec<Vec<f64>> = (0..6).map(|_| widen(&rng.normal_vec(8 * 3, 0.4))).collect();
        let reqs: Vec<Request> = paths
            .iter()
            .map(|p| Request::Signature { path: p.clone().into(), stream: 8, d: 3, depth: 3 })
            .collect();
        let resps = c.call_many(reqs);
        for (p, r) in paths.iter().zip(&resps) {
            let r = r.as_ref().expect("response");
            assert_eq!(r.backend, Backend::Native);
            assert_eq!(r.precision, Precision::F64);
            let want = signature_with(p, 8, &spec, &SigConfig::serial()).unwrap();
            assert_eq!(r.values, want, "f64 lane row != stand-alone native f64 serve");
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.batches, 1, "same-spec f64 requests share one microbatch");
        assert_eq!(snap.real_rows, 6);
    }

    #[test]
    fn f64_serves_direct_grad_and_logsig() {
        // `native_batch = 0`: the escape hatch applies to f64 rows too —
        // direct serve, no linger. Gradient requests run the f64 backward
        // and answer the gradient in f64; logsignature runs the generic
        // log + Words-projection epilogue natively at f64. No surface
        // upcasts or downcasts.
        let c = Coordinator::new(CoordinatorConfig::native_only().with_native_batch(0)).unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(26);
        let wide = widen(&rng.normal_vec(5 * 2, 0.4));

        let resp = c
            .call(Request::Signature { path: wide.clone().into(), stream: 5, d: 2, depth: 3 })
            .unwrap();
        let want = signature_with(&wide, 5, &spec, &SigConfig::serial()).unwrap();
        assert_eq!(resp.values, want);
        assert_eq!(resp.precision, Precision::F64);

        let wide_cot = widen(&rng.normal_vec(spec.sig_len(), 1.0));
        let g = c
            .call(Request::SignatureGrad {
                path: wide.clone().into(),
                stream: 5,
                d: 2,
                depth: 3,
                cotangent: wide_cot.clone().into(),
            })
            .unwrap();
        // Short stream: the plan falls back to the serial sweep, so this
        // is bitwise the native f64 VJP.
        let want_g = signature_vjp_with(&wide, 5, &spec, &SigConfig::serial(), &wide_cot)
            .unwrap()
            .grad_path;
        assert_eq!(g.values, want_g);
        assert_eq!(g.precision, Precision::F64);

        // A cotangent at the wrong width is a hard error, not a cast.
        assert!(c
            .call(Request::SignatureGrad {
                path: wide.clone().into(),
                stream: 5,
                d: 2,
                depth: 3,
                cotangent: vec![0.0f32; spec.sig_len()].into(),
            })
            .is_err());

        let lresp = c
            .call(Request::LogSignature { path: wide.clone().into(), stream: 5, d: 2, depth: 3 })
            .unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let want_l = logsignature_with(&wide, 5, &spec, &plan, &SigConfig::serial()).unwrap();
        assert_eq!(lresp.values, want_l, "direct f64 logsig != native f64 oracle");
        assert_eq!(lresp.precision, Precision::F64);
    }

    #[test]
    fn f64_logsig_microbatch_coalesces_and_matches_f64_oracle() {
        // The f64 logsignature traffic owns its own microbatch queue
        // (`with_dtype(F64)` on the logsig shape key). Six concurrent
        // same-spec f64 LogSignature requests must execute as ONE
        // lane-fused f64 microbatch, each row bitwise equal to the
        // stand-alone native f64 serve, answered in f64.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(250),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(27);
        let paths: Vec<Vec<f64>> = (0..6).map(|_| widen(&rng.normal_vec(8 * 2, 0.4))).collect();
        let reqs: Vec<Request> = paths
            .iter()
            .map(|p| Request::LogSignature { path: p.clone().into(), stream: 8, d: 2, depth: 3 })
            .collect();
        let resps = c.call_many(reqs);
        for (p, r) in paths.iter().zip(&resps) {
            let r = r.as_ref().expect("response");
            assert_eq!(r.backend, Backend::Native);
            assert_eq!(r.precision, Precision::F64);
            let want = logsignature_with(p, 8, &spec, &plan, &SigConfig::serial()).unwrap();
            assert_eq!(r.values, want, "f64 logsig lane row != stand-alone native f64 serve");
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.logsig_requests, 6);
        assert_eq!(snap.batches, 1, "same-spec f64 logsig requests share one microbatch");
        assert_eq!(snap.real_rows, 6);
    }

    #[test]
    fn native_microbatch_separates_ragged_shapes() {
        // A ragged mix (different stream lengths) cannot share a lane
        // sweep: the batcher keys on shape, so each shape flushes as its
        // own microbatch and every caller still gets its exact result.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(10),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(8),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(13);
        let short = rng.normal_vec(5 * 2, 0.4);
        let long = rng.normal_vec(9 * 2, 0.4);
        let resps = c.call_many(vec![
            Request::Signature { path: short.clone().into(), stream: 5, d: 2, depth: 3 },
            Request::Signature { path: long.clone().into(), stream: 9, d: 2, depth: 3 },
        ]);
        let r0 = resps[0].as_ref().unwrap();
        let r1 = resps[1].as_ref().unwrap();
        assert_eq!(r0.values, signature(&short, 5, &spec));
        assert_eq!(r1.values, signature(&long, 9, &spec));
        assert_eq!(c.metrics().snapshot().batches, 2);
    }

    #[test]
    fn native_batch_zero_escape_hatch_survives_the_planner() {
        // Regression: the documented `native_batch = 0` escape hatch must
        // keep its meaning through the adaptive planner — every native
        // request (stateless *and* streaming feed) computes directly,
        // never waiting out a linger. The linger is set absurdly high so
        // any accidental batcher involvement trips the wall-clock bound.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_secs(30),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(0),
        )
        .unwrap();
        assert_eq!(c.cfg.native_batch(), 0, "compatibility accessor");
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(14);
        let path = rng.normal_vec(6 * 2, 0.4);
        let t0 = Instant::now();
        let resp = c
            .call(Request::Signature { path: path.clone().into(), stream: 6, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(resp.values, signature(&path, 6, &spec));
        // LogSignature rides the same escape hatch: direct scalar serve,
        // never the batcher.
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let lresp = c
            .call(Request::LogSignature { path: path.clone().into(), stream: 6, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(
            lresp.values,
            logsignature_with(&path, 6, &spec, &plan, &SigConfig::serial()).unwrap()
        );
        // Streaming feeds bypass the feed lane too.
        let open = c
            .call(Request::OpenStream {
                points: rng.normal_vec(4 * 2, 0.3).into(),
                stream: 4,
                d: 2,
                depth: 3,
            })
            .unwrap();
        let sid = open.session.unwrap();
        c.call(Request::Feed { session: sid, points: rng.normal_vec(2 * 2, 0.3).into(), count: 2 })
            .unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "direct dispatch must never wait out the linger"
        );
        let snap = c.metrics().snapshot();
        assert_eq!(snap.batches, 0, "no microbatching when disabled");
        assert_eq!(snap.feed_lane_batches, 0, "no feed lane when disabled");
        assert!(snap.dispatch_scalar >= 2, "direct requests count as scalar dispatch");
    }

    #[test]
    fn adaptive_dispatch_rare_shapes_skip_the_linger() {
        // After warm-up, a shape that is a sliver of recent traffic gets
        // capacity 1 from the planner: it executes directly (no batcher,
        // no linger) while the dominant shape keeps microbatching.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(1),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(16),
        )
        .unwrap();
        let mut rng = Rng::new(15);
        // Warm the mix with a dominant shape (sequential lone requests:
        // each lingers ~1ms and flushes as its own one-row batch).
        for _ in 0..24 {
            c.call(Request::Signature {
                path: rng.normal_vec(8 * 2, 0.4).into(),
                stream: 8,
                d: 2,
                depth: 3,
            })
            .unwrap();
        }
        let batches_before = c.metrics().snapshot().batches;
        assert!(batches_before > 0, "dominant shape goes through the microbatcher");
        // A rare shape (1 of ~25 recent, share < 1/16) now serves direct.
        let scalar_before = c.metrics().snapshot().dispatch_scalar;
        let rare = rng.normal_vec(9 * 3, 0.4);
        let spec = SigSpec::new(3, 4).unwrap();
        let resp = c
            .call(Request::Signature { path: rare.clone().into(), stream: 9, d: 3, depth: 4 })
            .unwrap();
        assert_eq!(resp.values, signature(&rare, 9, &spec), "direct path is still exact");
        let snap = c.metrics().snapshot();
        assert_eq!(snap.batches, batches_before, "rare shape must not enter the batcher");
        assert!(snap.dispatch_scalar > scalar_before);
        assert!(snap.shape_mix_shapes >= 2, "the mix gauge sees both shapes");
    }

    #[test]
    fn feed_lane_coalesces_cross_session_feeds_bitwise() {
        // Two sessions streaming the same spec: once the planner has seen
        // both, their concurrent feeds coalesce into one lane-fused
        // Path::update_batch sweep — and every returned signature is
        // bitwise identical to scalar feeding the same points.
        let c = Coordinator::new(
            CoordinatorConfig {
                linger: Duration::from_millis(250),
                ..CoordinatorConfig::native_only()
            }
            .with_native_batch(16),
        )
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(16);
        let seed_a: Rows = rng.normal_vec(4 * 2, 0.3).into();
        let seed_b: Rows = rng.normal_vec(4 * 2, 0.3).into();
        let sid_a = c
            .call(Request::OpenStream { points: seed_a.clone(), stream: 4, d: 2, depth: 3 })
            .unwrap()
            .session
            .unwrap();
        let sid_b = c
            .call(Request::OpenStream { points: seed_b.clone(), stream: 4, d: 2, depth: 3 })
            .unwrap()
            .session
            .unwrap();
        // Scalar twins for the bitwise oracle.
        let twin = SessionManager::new(Arc::new(Metrics::default()));
        let tid_a = twin.open(&spec, &seed_a, 4).unwrap();
        let tid_b = twin.open(&spec, &seed_b, 4).unwrap();
        // Round 1 (sequential): teaches the planner this spec has two
        // distinct feeders; lone feeds stay scalar and direct.
        let warm_a: Rows = rng.normal_vec(2 * 2, 0.3).into();
        let warm_b: Rows = rng.normal_vec(3 * 2, 0.3).into();
        let r_a = c
            .call(Request::Feed { session: sid_a, points: warm_a.clone(), count: 2 })
            .unwrap();
        let r_b = c
            .call(Request::Feed { session: sid_b, points: warm_b.clone(), count: 3 })
            .unwrap();
        assert_eq!(r_a.values, twin.feed(tid_a, &warm_a, 2).unwrap());
        assert_eq!(r_b.values, twin.feed(tid_b, &warm_b, 3).unwrap());
        // Round 2 (concurrent, ragged counts): both feeds enter the lane
        // and flush as ONE fused sweep.
        let chunk_a: Rows = rng.normal_vec(3 * 2, 0.3).into();
        let chunk_b: Rows = rng.normal_vec(2, 0.3).into();
        let resps = c.call_many(vec![
            Request::Feed { session: sid_a, points: chunk_a.clone(), count: 3 },
            Request::Feed { session: sid_b, points: chunk_b.clone(), count: 1 },
        ]);
        let want_a = twin.feed(tid_a, &chunk_a, 3).unwrap();
        let want_b = twin.feed(tid_b, &chunk_b, 1).unwrap();
        assert_eq!(resps[0].as_ref().unwrap().values, want_a, "lane feed != scalar feed");
        assert_eq!(resps[1].as_ref().unwrap().values, want_b, "lane feed != scalar feed");
        let snap = c.metrics().snapshot();
        assert_eq!(snap.feed_lane_batches, 1, "concurrent same-spec feeds share one sweep");
        // Later interval queries agree bitwise too: the fused sweep left
        // identical precomputed state behind.
        let q = c.call(Request::QueryInterval { session: sid_a, i: 1, j: 8 }).unwrap();
        assert_eq!(q.values, twin.query(tid_a, 1, 8).unwrap());
    }

    #[test]
    fn malformed_forward_requests_error_not_panic() {
        // stream < 2 and short buffers must reach the caller as Err on
        // every native forward surface — batched and direct alike.
        for native_batch in [0usize, 8] {
            let c =
                Coordinator::new(CoordinatorConfig::native_only().with_native_batch(native_batch))
                    .unwrap();
            assert!(c
                .call(Request::Signature {
                    path: vec![0.0f32; 2].into(),
                    stream: 1,
                    d: 2,
                    depth: 3,
                })
                .is_err());
            assert!(c
                .call(Request::LogSignature {
                    path: vec![0.0f32; 2].into(),
                    stream: 1,
                    d: 2,
                    depth: 3,
                })
                .is_err());
            assert!(c
                .call(Request::Signature {
                    path: vec![0.0f32; 3].into(),
                    stream: 2,
                    d: 2,
                    depth: 3,
                })
                .is_err());
        }
    }

    #[test]
    fn missing_artifact_dir_falls_back_to_native() {
        let c = Coordinator::new(CoordinatorConfig {
            artifact_dir: Some(PathBuf::from("/definitely/not/here")),
            ..Default::default()
        })
        .unwrap();
        assert!(!c.has_xla());
        let mut rng = Rng::new(5);
        let resp = c
            .call(Request::Signature {
                path: rng.normal_vec(4 * 2, 0.3).into(),
                stream: 4,
                d: 2,
                depth: 2,
            })
            .unwrap();
        assert_eq!(resp.backend, Backend::Native);
    }

    #[test]
    fn f64_sessions_serve_native_width_through_the_coordinator() {
        // The stateful surface end to end at f64: a session opened with
        // f64 rows records the dtype, every response comes back in f64
        // rows, and each one is bitwise the direct f64 Path oracle. A
        // feed at the wrong width is a hard error that leaves the session
        // untouched.
        let c = native();
        let spec = SigSpec::with_dtype(2, 3, Precision::F64).unwrap();
        let mut rng = Rng::new(31);
        let seed = widen(&rng.normal_vec(5 * 2, 0.3));
        let chunk = widen(&rng.normal_vec(3 * 2, 0.3));

        let open = c
            .call(Request::OpenStream { points: seed.clone().into(), stream: 5, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(open.precision, Precision::F64);
        let sid = open.session.unwrap();
        let mut oracle = crate::path::Path::<f64>::new(&spec, &seed, 5).unwrap();
        assert_eq!(open.values, oracle.signature());

        let fed = c
            .call(Request::Feed { session: sid, points: chunk.clone().into(), count: 3 })
            .unwrap();
        oracle.update(&chunk, 3).unwrap();
        assert_eq!(fed.precision, Precision::F64);
        assert_eq!(fed.values, oracle.signature(), "f64 feed != f64 Path oracle");

        let q = c.call(Request::QueryInterval { session: sid, i: 1, j: 6 }).unwrap();
        assert_eq!(q.precision, Precision::F64);
        assert_eq!(q.values, oracle.query(1, 6).unwrap(), "f64 query != f64 Path oracle");

        let lq = c.call(Request::LogSigQueryInterval { session: sid, i: 1, j: 6 }).unwrap();
        assert_eq!(lq.precision, Precision::F64);
        assert_eq!(lq.values.len(), crate::words::witt_dimension(2, 3));

        // Cross-precision feed: rejected, session state unchanged.
        assert!(c
            .call(Request::Feed { session: sid, points: vec![0.0f32; 2 * 2].into(), count: 2 })
            .is_err());
        assert_eq!(c.sessions().session_len(sid).unwrap(), 8);

        // Close answers an (empty) f64 buffer — the dtype stays truthful
        // on every streaming response.
        let closed = c.call(Request::CloseStream { session: sid }).unwrap();
        assert_eq!(closed.precision, Precision::F64);
        assert!(closed.values.is_empty());
    }

    #[test]
    fn rolling_window_matches_per_query_through_the_coordinator() {
        // The tentpole contract at the request surface: every slide a
        // windowed session emits is bitwise the per-query answer a plain
        // (untruncated) twin session gives over the same interval.
        let c = native();
        let mut rng = Rng::new(33);
        let total = 23usize;
        let all = rng.normal_vec(total * 2, 0.3);
        let wspec = WindowSpec { len: 6, stride: 2, logsig: None };
        let seed: Rows = all[..4 * 2].to_vec().into();
        let open = c
            .call(Request::OpenWindow {
                points: seed.clone(),
                stream: 4,
                d: 2,
                depth: 3,
                window: wspec,
            })
            .unwrap();
        let sid = open.session.unwrap();
        // The open response is the seed signature, exactly like OpenStream.
        let spec = SigSpec::new(2, 3).unwrap();
        let oracle = crate::path::Path::<f32>::new(&spec, &all[..4 * 2], 4).unwrap();
        assert_eq!(open.values, oracle.signature());
        let twin = c
            .call(Request::OpenStream { points: seed, stream: 4, d: 2, depth: 3 })
            .unwrap()
            .session
            .unwrap();
        let dim = spec.sig_len();
        let mut slides: Vec<(u64, Vec<f32>)> = vec![];
        let mut fed = 4usize;
        for &cnt in &[3usize, 1, 4, 2, 5, 4] {
            let chunk: Rows = all[fed * 2..(fed + cnt) * 2].to_vec().into();
            c.call(Request::Feed { session: sid, points: chunk.clone(), count: cnt }).unwrap();
            c.call(Request::Feed { session: twin, points: chunk, count: cnt }).unwrap();
            fed += cnt;
            // Drain in pages of at most 2 slides: the cap bounds every
            // response's payload and `window_remaining` counts down to 0,
            // with the pages reassembling the full drain exactly.
            loop {
                let r = c
                    .call(Request::PollWindow { session: sid, max_slides: Some(2) })
                    .unwrap();
                assert!(r.values.len() <= 2 * dim, "page exceeded its cap");
                let mut k = r.window_slide.unwrap();
                for row in r.values.as_f32().unwrap().chunks(dim) {
                    slides.push((k, row.to_vec()));
                    k += 1;
                }
                if r.window_remaining.unwrap() == 0 {
                    break;
                }
            }
        }
        assert_eq!(fed, total);
        // Every complete window emitted exactly once, in order, across
        // the ragged polls.
        assert_eq!(slides.len(), (total - wspec.len) / wspec.stride + 1);
        for (idx, (k, _)) in slides.iter().enumerate() {
            assert_eq!(*k, idx as u64, "slides arrive in order without gaps");
        }
        for (k, row) in &slides {
            let i = *k as usize * wspec.stride;
            let j = i + wspec.len - 1;
            let want = c.call(Request::QueryInterval { session: twin, i, j }).unwrap();
            assert_eq!(&row[..], want.values.as_f32().unwrap(), "slide {k} != [{i}, {j}]");
        }
        // The windowed session still reports its absolute stream length,
        // and an empty poll names the next future slide.
        assert_eq!(c.sessions().session_len(sid).unwrap(), total);
        let empty = c.call(Request::PollWindow { session: sid, max_slides: None }).unwrap();
        assert!(empty.values.is_empty());
        assert_eq!(empty.window_slide, Some(slides.len() as u64));
        assert_eq!(empty.window_remaining, Some(0));
    }

    #[test]
    fn logsig_windows_and_window_error_paths() {
        let c = native();
        let mut rng = Rng::new(34);
        let all = rng.normal_vec(12 * 2, 0.3);
        let wspec = WindowSpec { len: 5, stride: 3, logsig: Some(LogSigBasis::Words) };
        // A seed of 12 points already completes slides 0..=2 (right ends
        // 4, 7, 10): open-then-poll sees them without any feed.
        let open = c
            .call(Request::OpenWindow {
                points: all.clone().into(),
                stream: 12,
                d: 2,
                depth: 3,
                window: wspec,
            })
            .unwrap();
        let sid = open.session.unwrap();
        let twin = c
            .call(Request::OpenStream { points: all.into(), stream: 12, d: 2, depth: 3 })
            .unwrap()
            .session
            .unwrap();
        let r = c.call(Request::PollWindow { session: sid, max_slides: None }).unwrap();
        assert_eq!(r.window_slide, Some(0));
        assert_eq!(r.window_remaining, Some(0));
        let dim = crate::words::witt_dimension(2, 3);
        assert_eq!(r.values.len(), 3 * dim);
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        for (k, row) in r.values.as_f32().unwrap().chunks(dim).enumerate() {
            let i = k * wspec.stride;
            let want = c.sessions().logsig_query(twin, i, i + wspec.len - 1, &plan).unwrap();
            assert_eq!(row, want.as_f32().unwrap(), "logsig slide {k}");
        }
        // Polling a plain stream is a clean error, as is a malformed spec.
        assert!(c.call(Request::PollWindow { session: twin, max_slides: None }).is_err());
        assert!(c
            .call(Request::OpenWindow {
                points: vec![0.0f32; 2 * 2].into(),
                stream: 2,
                d: 2,
                depth: 3,
                window: WindowSpec { len: 1, stride: 1, logsig: None },
            })
            .is_err());
        let snap = c.metrics().snapshot();
        assert_eq!(snap.window_polls, 1);
        assert_eq!(snap.window_slides, 3);
        // The per-kind latency histograms saw the window traffic.
        assert!(snap.render_latency().contains("poll_window="), "{}", snap.render_latency());
        assert!(snap.render_latency().contains("open_window="), "{}", snap.render_latency());
    }
}
