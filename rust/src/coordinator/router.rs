//! The request router: the coordinator's front door.
//!
//! Each request is routed to the XLA backend when an AOT artifact with a
//! matching shape exists (going through the dynamic batcher), and to the
//! native Rust engine otherwise. The native path is also the fallback when
//! no artifact directory is present, so the coordinator is fully usable
//! without running `make artifacts`.
//!
//! Native `Signature` requests are themselves microbatched
//! ([`CoordinatorConfig::native_batch`]): same-spec requests gathered
//! within one linger window execute as a single **lane-fused** sweep
//! through [`crate::ta::batch`] — vectorised across the batch — instead of
//! N independent per-path signatures.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{BatchBackend, BatchShape, Batcher};
use super::metrics::Metrics;
use super::session::{SessionConfig, SessionId, SessionManager};
use crate::logsignature::{logsignature_from_sig, LogSigBasis, LogSigPlan};
use crate::runtime::{ArtifactKind, EngineHandle, Registry};
use crate::signature::{signature_batch, signature_vjp_with, signature_with, SigConfig};
#[cfg(test)]
use crate::signature::signature;
use crate::ta::SigSpec;

/// Kinds encoded into [`BatchShape::kind`].
const KIND_SIG: u8 = 0;
const KIND_LOGSIG: u8 = 1;
const KIND_SIGGRAD: u8 = 2;
/// Native lane-fused signature microbatch (no artifact involved).
const KIND_SIG_NATIVE: u8 = 3;

/// A request against the coordinator.
#[derive(Clone, Debug)]
pub enum Request {
    /// `Sig^depth(path)` for one `(stream, d)` path.
    Signature { path: Vec<f32>, stream: usize, d: usize, depth: usize },
    /// Words-basis `LogSig^depth(path)`.
    LogSignature { path: Vec<f32>, stream: usize, d: usize, depth: usize },
    /// VJP: cotangent on the signature -> gradient on the path.
    SignatureGrad {
        path: Vec<f32>,
        stream: usize,
        d: usize,
        depth: usize,
        cotangent: Vec<f32>,
    },
    /// Open a streaming session seeded with an initial path (>= 2 points).
    /// The response carries the new id in [`Response::session`] and the
    /// signature of the seed path in `values`.
    OpenStream { points: Vec<f32>, stream: usize, d: usize, depth: usize },
    /// Append points to a session ("keeping the signature up-to-date",
    /// §5.5, eq. 7); returns the whole-stream signature so far.
    Feed { session: SessionId, points: Vec<f32>, count: usize },
    /// O(1)-in-L interval signature query against a session's stream
    /// (0-based inclusive endpoints, `i < j < len`).
    QueryInterval { session: SessionId, i: usize, j: usize },
    /// Words-basis logsignature interval query (served from the
    /// coordinator's cached `LogSigPlan` for the session's spec).
    LogSigQueryInterval { session: SessionId, i: usize, j: usize },
    /// Close a session, releasing its precomputed storage.
    CloseStream { session: SessionId },
}

/// Which backend served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Native,
    Xla,
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    pub values: Vec<f32>,
    pub backend: Backend,
    /// Set on streaming responses: the session the request addressed
    /// (`OpenStream` returns the freshly allocated id here).
    pub session: Option<SessionId>,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Artifact directory; `None` => native-only coordinator.
    pub artifact_dir: Option<PathBuf>,
    /// Route to XLA when possible (otherwise XLA is only used when asked
    /// explicitly by benchmarks).
    pub prefer_xla: bool,
    /// Dynamic batcher linger.
    pub linger: Duration,
    /// Threads for native batch work.
    pub native_threads: usize,
    /// Native microbatch capacity: when `>= 2`, stateless `Signature`
    /// requests that miss the XLA path are gathered by a dynamic batcher
    /// (same `linger`), and a flushed microbatch of same-spec requests
    /// runs as **one lane-fused sweep** ([`crate::ta::batch`]) instead of
    /// N independent signatures — the CPU serving hot path for many short
    /// streams at small `d`. Requests whose shapes differ batch
    /// separately (the batcher keys on shape), so a ragged mix degrades
    /// gracefully to per-shape microbatches. The standard dynamic-
    /// batching trade applies (identical to the XLA path): an uncontended
    /// request waits out the `linger` before its lone-row batch flushes,
    /// buying throughput under concurrent load at the cost of idle-path
    /// latency — latency-sensitive single-stream callers should set `0`
    /// (disables microbatching: each request computes directly, no
    /// linger) or shrink `linger`.
    pub native_batch: usize,
    /// Streaming-session knobs: table sharding, the resident-memory budget
    /// (`session.budget_bytes`, enforced by LRU eviction of idle
    /// sessions), and the idle TTL (`session.ttl`, enforced by a
    /// background sweeper). Defaults to unbounded.
    pub session: SessionConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: Some(crate::runtime::default_artifact_dir()),
            prefer_xla: true,
            linger: Duration::from_millis(2),
            native_threads: crate::substrate::pool::default_threads(),
            native_batch: crate::signature::LANE_BLOCK,
            session: SessionConfig::default(),
        }
    }
}

impl CoordinatorConfig {
    /// A native-only configuration (no artifacts, no PJRT).
    pub fn native_only() -> Self {
        CoordinatorConfig { artifact_dir: None, prefer_xla: false, ..Default::default() }
    }
}

struct XlaBackend {
    engine: EngineHandle,
    registry: Arc<Registry>,
}

impl BatchBackend for XlaBackend {
    // XLA executables are compiled for the fixed `shape.batch`, so the
    // padding rows must run regardless of `n_real`.
    fn run(&self, shape: &BatchShape, padded: &[f32], _n_real: usize) -> anyhow::Result<Vec<f32>> {
        let kind = match shape.kind {
            KIND_SIG => ArtifactKind::Sig,
            KIND_LOGSIG => ArtifactKind::LogSig,
            KIND_SIGGRAD => ArtifactKind::SigGrad,
            other => anyhow::bail!("unknown batch kind {other}"),
        };
        let entry = self
            .registry
            .find(kind, shape.batch, shape.length, shape.d, shape.depth)
            .ok_or_else(|| anyhow::anyhow!("artifact disappeared for {shape:?}"))?;
        match kind {
            ArtifactKind::Sig | ArtifactKind::LogSig => {
                self.engine.forward(entry, padded.to_vec())
            }
            ArtifactKind::SigGrad => {
                // Rows are path || cotangent; de-interleave into the two
                // positional inputs.
                let in_path = shape.length * shape.d;
                let sig_len: usize = (1..=shape.depth).map(|k| shape.d.pow(k as u32)).sum();
                let row = in_path + sig_len;
                let mut paths = vec![0.0f32; shape.batch * in_path];
                let mut cots = vec![0.0f32; shape.batch * sig_len];
                for b in 0..shape.batch {
                    let r = &padded[b * row..(b + 1) * row];
                    paths[b * in_path..(b + 1) * in_path].copy_from_slice(&r[..in_path]);
                    cots[b * sig_len..(b + 1) * sig_len].copy_from_slice(&r[in_path..]);
                }
                self.engine.grad(entry, paths, cots)
            }
            ArtifactKind::Train => anyhow::bail!("train artifacts are not batched"),
        }
    }
}

/// Native batch backend: executes a flushed microbatch of same-spec
/// signature requests as one lane-fused sweep over the *real* rows only
/// (no static-shape constraint, so the padding slots are never computed).
/// Each row's result is bitwise identical to a stand-alone
/// [`crate::signature::signature`] call.
struct NativeLaneBackend {
    threads: usize,
}

impl BatchBackend for NativeLaneBackend {
    fn run(&self, shape: &BatchShape, padded: &[f32], n_real: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(shape.kind == KIND_SIG_NATIVE, "unexpected native batch kind");
        let spec = SigSpec::new(shape.d, shape.depth)?;
        // No static-shape constraint here: compute only the real rows
        // (a sparse flush must not pay for the padding slots). A lone-row
        // flush runs serially — signature_batch's batch-1 fallback would
        // otherwise engage the chunked stream reduction on long streams,
        // and a request's bits must not depend on whether traffic
        // happened to coalesce with it.
        let rows = n_real.clamp(1, shape.batch);
        let threads = if rows == 1 { 1 } else { self.threads };
        signature_batch(&padded[..rows * shape.in_row()], rows, shape.length, &spec, threads)
    }
}

/// The coordinator: router + batchers + sessions + metrics.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    registry: Option<Arc<Registry>>,
    engine: Option<EngineHandle>,
    batcher: Option<Batcher>,
    /// Lane-fused microbatcher for native signature requests
    /// ([`CoordinatorConfig::native_batch`]).
    native_batcher: Option<Batcher>,
    sessions: SessionManager,
    metrics: Arc<Metrics>,
    plans: Mutex<HashMap<(usize, usize), Arc<LogSigPlan>>>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let (registry, engine, batcher) = match &cfg.artifact_dir {
            Some(dir) if dir.join("MANIFEST.json").exists() => {
                let (engine, registry) = EngineHandle::spawn(dir.clone())?;
                let registry = Arc::new(registry);
                let backend = Arc::new(XlaBackend {
                    engine: engine.clone(),
                    registry: Arc::clone(&registry),
                });
                let batcher = Batcher::new(backend, Arc::clone(&metrics), cfg.linger);
                (Some(registry), Some(engine), Some(batcher))
            }
            _ => (None, None, None),
        };
        let native_batcher = if cfg.native_batch >= 2 {
            Some(Batcher::new(
                Arc::new(NativeLaneBackend { threads: cfg.native_threads }),
                Arc::clone(&metrics),
                cfg.linger,
            ))
        } else {
            None
        };
        Ok(Coordinator {
            sessions: SessionManager::with_config(Arc::clone(&metrics), cfg.session.clone()),
            registry,
            engine,
            batcher,
            native_batcher,
            metrics,
            cfg,
            plans: Mutex::new(HashMap::new()),
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }

    pub fn has_xla(&self) -> bool {
        self.batcher.is_some()
    }

    pub fn engine(&self) -> Option<&EngineHandle> {
        self.engine.as_ref()
    }

    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    fn plan(&self, d: usize, depth: usize) -> anyhow::Result<Arc<LogSigPlan>> {
        let mut plans = self.plans.lock().unwrap();
        if let Some(p) = plans.get(&(d, depth)) {
            // Cache integrity: an entry filed under the wrong key must
            // error, never silently gather wrong indices. Field checks
            // only — no SigSpec construction on the hot hit path.
            anyhow::ensure!(
                p.spec().d() == d && p.spec().depth() == depth,
                "plan cache corrupted: entry for (d={d}, depth={depth}) was built for \
                 (d={}, depth={})",
                p.spec().d(),
                p.spec().depth()
            );
            return Ok(Arc::clone(p));
        }
        let spec = SigSpec::new(d, depth)?;
        let plan = Arc::new(LogSigPlan::new(&spec, LogSigBasis::Words)?);
        plans.insert((d, depth), Arc::clone(&plan));
        Ok(plan)
    }

    /// Serve one request synchronously, routing per configuration.
    pub fn call(&self, req: Request) -> anyhow::Result<Response> {
        use std::sync::atomic::Ordering;
        let t0 = Instant::now();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let result = self.route(req);
        self.metrics.record_latency(t0.elapsed());
        if result.is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    fn route(&self, req: Request) -> anyhow::Result<Response> {
        use std::sync::atomic::Ordering;
        // Streaming (stateful) requests: served by the session table on
        // the native engine, never batched.
        if let Some(resp) = self.route_stream(&req)? {
            return Ok(resp);
        }
        // Try the XLA path when configured and an artifact matches.
        if self.cfg.prefer_xla {
            if let (Some(reg), Some(batcher)) = (&self.registry, &self.batcher) {
                let routed = match &req {
                    Request::Signature { path, stream, d, depth } => reg
                        .find_batchable(ArtifactKind::Sig, 1, *stream, *d, *depth)
                        .map(|e| {
                            let shape = BatchShape {
                                kind: KIND_SIG,
                                batch: e.batch,
                                length: *stream,
                                d: *d,
                                depth: *depth,
                                in_dim: stream * d,
                                out_dim: e.out_dim,
                            };
                            batcher.submit(shape, path)
                        }),
                    Request::LogSignature { path, stream, d, depth } => reg
                        .find_batchable(ArtifactKind::LogSig, 1, *stream, *d, *depth)
                        .map(|e| {
                            let shape = BatchShape {
                                kind: KIND_LOGSIG,
                                batch: e.batch,
                                length: *stream,
                                d: *d,
                                depth: *depth,
                                in_dim: stream * d,
                                out_dim: e.out_dim,
                            };
                            batcher.submit(shape, path)
                        }),
                    Request::SignatureGrad { path, stream, d, depth, cotangent } => reg
                        .find_batchable(ArtifactKind::SigGrad, 1, *stream, *d, *depth)
                        .map(|e| {
                            let mut row = path.clone();
                            row.extend_from_slice(cotangent);
                            let shape = BatchShape {
                                kind: KIND_SIGGRAD,
                                batch: e.batch,
                                length: *stream,
                                d: *d,
                                depth: *depth,
                                in_dim: row.len(),
                                out_dim: e.out_dim,
                            };
                            batcher.submit(shape, &row)
                        }),
                    // Streaming requests were already dispatched above.
                    _ => None,
                };
                if let Some(rx) = routed {
                    let rx = rx?;
                    let values = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("batcher dropped request"))??;
                    self.metrics.xla_requests.fetch_add(1, Ordering::Relaxed);
                    return Ok(Response { values, backend: Backend::Xla, session: None });
                }
            }
        }
        // Native path. All shapes are validated up front so malformed
        // requests are an `Err` here, never a panic on a serving thread.
        let values = match req {
            Request::Signature { path, stream, d, depth } => {
                let spec = SigSpec::new(d, depth)?;
                anyhow::ensure!(path.len() == stream * d, "bad path buffer");
                anyhow::ensure!(stream >= 2, "a path needs at least two points, got {stream}");
                if let Some(nb) = &self.native_batcher {
                    // Lane-fused microbatching: same-spec requests gathered
                    // within the linger window execute as one interleaved
                    // sweep; the result per row is bitwise identical to a
                    // stand-alone signature call.
                    let shape = BatchShape {
                        kind: KIND_SIG_NATIVE,
                        batch: self.cfg.native_batch,
                        length: stream,
                        d,
                        depth,
                        in_dim: stream * d,
                        out_dim: spec.sig_len(),
                    };
                    let rx = nb.submit(shape, &path)?;
                    let values = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("native batcher dropped request"))??;
                    self.metrics.native_requests.fetch_add(1, Ordering::Relaxed);
                    return Ok(Response { values, backend: Backend::Native, session: None });
                }
                signature_with(&path, stream, &spec, &SigConfig::serial())?
            }
            Request::LogSignature { path, stream, d, depth } => {
                let spec = SigSpec::new(d, depth)?;
                anyhow::ensure!(path.len() == stream * d, "bad path buffer");
                let sig = signature_with(&path, stream, &spec, &SigConfig::serial())?;
                logsignature_from_sig(&sig, &spec, self.plan(d, depth)?.as_ref())?
            }
            Request::SignatureGrad { path, stream, d, depth, cotangent } => {
                let spec = SigSpec::new(d, depth)?;
                // Shape validation happens inside the VJP; long streams run
                // the chunked Chen-identity backward. Per-request stream
                // parallelism is capped: the coordinator already serves
                // requests concurrently (one caller thread each), so
                // uncapped native_threads here would multiply into
                // requests × cores scoped workers under load.
                let threads = self.cfg.native_threads.min(4);
                let cfg = SigConfig { threads, ..SigConfig::serial() };
                signature_vjp_with(&path, stream, &spec, &cfg, &cotangent)?.grad_path
            }
            Request::OpenStream { .. }
            | Request::Feed { .. }
            | Request::QueryInterval { .. }
            | Request::LogSigQueryInterval { .. }
            | Request::CloseStream { .. } => unreachable!("handled by route_stream"),
        };
        self.metrics.native_requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Response { values, backend: Backend::Native, session: None })
    }

    /// Serve a streaming request against the session table; `Ok(None)` for
    /// stateless requests (which fall through to the backends).
    fn route_stream(&self, req: &Request) -> anyhow::Result<Option<Response>> {
        // Classify exhaustively (no catch-all): a new Request variant must
        // be consciously filed as stateless here or handled below.
        match req {
            Request::Signature { .. }
            | Request::LogSignature { .. }
            | Request::SignatureGrad { .. } => return Ok(None),
            Request::OpenStream { .. }
            | Request::Feed { .. }
            | Request::QueryInterval { .. }
            | Request::LogSigQueryInterval { .. }
            | Request::CloseStream { .. } => {}
        }
        // Counted before serving, so failed streaming requests are still
        // attributed to the streaming surface.
        self.metrics
            .stream_requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (values, session) = match req {
            Request::OpenStream { points, stream, d, depth } => {
                let spec = SigSpec::new(*d, *depth)?;
                anyhow::ensure!(points.len() == stream * d, "bad point buffer");
                // One call returning both id and seed signature: a racing
                // eviction after the insert must not turn a successful
                // open into an "unknown session" error.
                let (id, sig) = self.sessions.open_with_signature(&spec, points, *stream)?;
                (sig, Some(id))
            }
            Request::Feed { session, points, count } => {
                (self.sessions.feed(*session, points, *count)?, Some(*session))
            }
            Request::QueryInterval { session, i, j } => {
                (self.sessions.query(*session, *i, *j)?, Some(*session))
            }
            Request::LogSigQueryInterval { session, i, j } => {
                // Resolve the session once; the plan comes from the
                // coordinator's cache keyed by the session's (d, depth).
                let out = self
                    .sessions
                    .logsig_query_with(*session, *i, *j, |spec| self.plan(spec.d(), spec.depth()))?;
                (out, Some(*session))
            }
            Request::CloseStream { session } => {
                self.sessions.close(*session)?;
                (Vec::new(), Some(*session))
            }
            Request::Signature { .. }
            | Request::LogSignature { .. }
            | Request::SignatureGrad { .. } => unreachable!("stateless; returned above"),
        };
        Ok(Some(Response { values, backend: Backend::Native, session }))
    }

    /// Serve a whole batch concurrently (used by examples and benches):
    /// spawns one caller thread per request so the dynamic batcher can
    /// coalesce them.
    pub fn call_many(&self, reqs: Vec<Request>) -> Vec<anyhow::Result<Response>> {
        std::thread::scope(|scope| {
            let handles: Vec<_> =
                reqs.into_iter().map(|r| scope.spawn(move || self.call(r))).collect();
            handles.into_iter().map(|h| h.join().expect("caller thread")).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    fn native() -> Coordinator {
        Coordinator::new(CoordinatorConfig::native_only()).unwrap()
    }

    #[test]
    fn native_signature_roundtrip() {
        let c = native();
        let mut rng = Rng::new(1);
        let path = rng.normal_vec(8 * 2, 0.4);
        let resp = c
            .call(Request::Signature { path: path.clone(), stream: 8, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(resp.backend, Backend::Native);
        let spec = SigSpec::new(2, 3).unwrap();
        assert_close(&resp.values, &signature(&path, 8, &spec), 1e-6, 1e-7);
        assert_eq!(c.metrics().snapshot().native_requests, 1);
    }

    #[test]
    fn native_logsignature_dimension() {
        let c = native();
        let mut rng = Rng::new(2);
        let path = rng.normal_vec(6 * 3, 0.4);
        let resp = c
            .call(Request::LogSignature { path, stream: 6, d: 3, depth: 3 })
            .unwrap();
        assert_eq!(resp.values.len(), crate::words::witt_dimension(3, 3));
    }

    #[test]
    fn native_grad_roundtrip() {
        let c = native();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(3);
        let path = rng.normal_vec(5 * 2, 0.4);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let resp = c
            .call(Request::SignatureGrad {
                path: path.clone(),
                stream: 5,
                d: 2,
                depth: 3,
                cotangent: cot.clone(),
            })
            .unwrap();
        // Short stream: the router's parallel config falls back to the
        // serial sweep, so this is bitwise the serial VJP.
        assert_close(
            &resp.values,
            &crate::signature::signature_vjp(&path, 5, &spec, &cot),
            1e-6,
            1e-7,
        );
    }

    #[test]
    fn native_grad_long_stream_uses_parallel_backward() {
        let c = native();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(30);
        let stream = 96;
        let path = rng.normal_vec(stream * 2, 0.1);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let resp = c
            .call(Request::SignatureGrad {
                path: path.clone(),
                stream,
                d: 2,
                depth: 3,
                cotangent: cot.clone(),
            })
            .unwrap();
        let serial = crate::signature::signature_vjp(&path, stream, &spec, &cot);
        assert_close(&resp.values, &serial, 2e-3, 1e-4);
        // Mismatched cotangent shape is a clean error, not a panic.
        assert!(c
            .call(Request::SignatureGrad {
                path,
                stream,
                d: 2,
                depth: 3,
                cotangent: vec![0.0; spec.sig_len() - 1],
            })
            .is_err());
    }

    #[test]
    fn bad_shapes_error_and_count() {
        let c = native();
        assert!(c.call(Request::Signature { path: vec![0.0; 3], stream: 8, d: 2, depth: 3 }).is_err());
        assert_eq!(c.metrics().snapshot().errors, 1);
    }

    #[test]
    fn call_many_native() {
        let c = native();
        let mut rng = Rng::new(4);
        let reqs: Vec<Request> = (0..6)
            .map(|_| Request::Signature {
                path: rng.normal_vec(8 * 2, 0.4),
                stream: 8,
                d: 2,
                depth: 3,
            })
            .collect();
        let resps = c.call_many(reqs);
        assert_eq!(resps.len(), 6);
        for r in resps {
            assert!(r.is_ok());
        }
        assert_eq!(c.metrics().snapshot().requests, 6);
    }

    #[test]
    fn streaming_requests_served_through_call() {
        let c = native();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(8);
        let all = rng.normal_vec(16 * 2, 0.3);

        let open = c
            .call(Request::OpenStream { points: all[..6 * 2].to_vec(), stream: 6, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(open.backend, Backend::Native);
        let sid = open.session.expect("open returns a session id");
        assert_close(&open.values, &signature(&all[..6 * 2], 6, &spec), 1e-6, 1e-7);

        let fed = c
            .call(Request::Feed { session: sid, points: all[6 * 2..].to_vec(), count: 10 })
            .unwrap();
        assert_close(&fed.values, &signature(&all, 16, &spec), 2e-3, 1e-4);

        // Interval query crossing the feed boundary.
        let q = c.call(Request::QueryInterval { session: sid, i: 3, j: 12 }).unwrap();
        assert_close(&q.values, &signature(&all[3 * 2..13 * 2], 10, &spec), 5e-3, 5e-4);

        // Logsig query uses the coordinator's cached words-basis plan.
        let lq = c.call(Request::LogSigQueryInterval { session: sid, i: 3, j: 12 }).unwrap();
        assert_eq!(lq.values.len(), crate::words::witt_dimension(2, 3));

        let snap = c.metrics().snapshot();
        assert_eq!(snap.stream_requests, 4);
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.open_sessions, 1);
        assert!(snap.session_bytes > 0);

        c.call(Request::CloseStream { session: sid }).unwrap();
        let snap = c.metrics().snapshot();
        assert_eq!(snap.open_sessions, 0);
        assert_eq!(snap.session_bytes, 0);
        // Requests against a closed session error and count once.
        assert!(c.call(Request::QueryInterval { session: sid, i: 0, j: 3 }).is_err());
        assert_eq!(c.metrics().snapshot().errors, 1);
    }

    #[test]
    fn session_budget_enforced_through_coordinator_config() {
        let spec = SigSpec::new(2, 3).unwrap();
        // Room for about three 8-point sessions; measure the per-session
        // storage on a throwaway Path rather than hard-coding its layout.
        let per = crate::path::Path::new(&spec, &[0.0f32; 8 * 2], 8)
            .unwrap()
            .storage_bytes();
        let c = Coordinator::new(CoordinatorConfig {
            session: SessionConfig {
                budget_bytes: Some(3 * per + per / 2),
                ..Default::default()
            },
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        let mut rng = Rng::new(9);
        let mut sids = vec![];
        for _ in 0..5 {
            let resp = c
                .call(Request::OpenStream {
                    points: rng.normal_vec(8 * 2, 0.3),
                    stream: 8,
                    d: 2,
                    depth: 3,
                })
                .unwrap();
            sids.push(resp.session.unwrap());
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.sessions_opened, 5);
        assert_eq!(snap.sessions_evicted, 2);
        assert_eq!(snap.open_sessions, 3);
        assert!(snap.session_bytes as usize <= 3 * per + per / 2);
        // The two oldest sessions were evicted, in order.
        assert!(c.call(Request::QueryInterval { session: sids[0], i: 0, j: 7 }).is_err());
        assert!(c.call(Request::QueryInterval { session: sids[1], i: 0, j: 7 }).is_err());
        for &sid in &sids[2..] {
            assert!(c.call(Request::QueryInterval { session: sid, i: 0, j: 7 }).is_ok());
        }
    }

    /// A batch backend that always fails (for error-accounting tests).
    struct FailBackend;

    impl BatchBackend for FailBackend {
        fn run(
            &self,
            _shape: &BatchShape,
            _padded: &[f32],
            _n_real: usize,
        ) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("backend down")
        }
    }

    #[test]
    fn batch_backend_failure_counts_once_per_request() {
        // Regression for the double count: `execute_batch` used to bump
        // `errors` per failed batch *and* `call` bumped it again per
        // request. Two requests failing in one batch must yield errors=2
        // (one each) and batch_failures=1.
        use crate::runtime::ArtifactEntry;
        let metrics = Arc::new(Metrics::default());
        let spec = SigSpec::new(2, 3).unwrap();
        let registry = Arc::new(Registry {
            dir: PathBuf::from("/nonexistent"),
            entries: vec![ArtifactEntry {
                file: "mock".into(),
                kind: ArtifactKind::Sig,
                batch: 2,
                length: 4,
                d: 2,
                depth: 3,
                out_dim: spec.sig_len(),
                pallas: false,
                hidden: 0,
                d_out: 0,
            }],
        });
        // Generous linger: both caller threads must land in one pending
        // batch even if thread spawn stalls; the batch fills at 2 rows, so
        // the failure path executes inline and never waits this long.
        let batcher =
            Batcher::new(Arc::new(FailBackend), Arc::clone(&metrics), Duration::from_millis(250));
        let c = Coordinator {
            cfg: CoordinatorConfig {
                artifact_dir: None,
                prefer_xla: true,
                ..CoordinatorConfig::native_only()
            },
            registry: Some(registry),
            engine: None,
            batcher: Some(batcher),
            native_batcher: None,
            sessions: SessionManager::new(Arc::clone(&metrics)),
            metrics,
            plans: Mutex::new(HashMap::new()),
        };
        let mut rng = Rng::new(10);
        let reqs: Vec<Request> = (0..2)
            .map(|_| Request::Signature {
                path: rng.normal_vec(4 * 2, 0.3),
                stream: 4,
                d: 2,
                depth: 3,
            })
            .collect();
        for r in c.call_many(reqs) {
            assert!(r.is_err());
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.errors, 2, "one error per failed request");
        assert_eq!(snap.batch_failures, 1, "one failed batch execution");
    }

    #[test]
    fn native_microbatch_coalesces_same_spec_requests() {
        // Six concurrent same-spec requests inside one linger window must
        // execute as ONE lane-fused microbatch (metrics: 1 batch, 6 real
        // rows), each caller receiving the bitwise per-path signature.
        let c = Coordinator::new(CoordinatorConfig {
            native_batch: 8,
            // Generous linger: all six caller threads must land in one
            // pending batch even if thread spawn stalls; the batch never
            // fills (6 < 8), so the flusher fires it at the deadline.
            linger: Duration::from_millis(250),
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(12);
        let paths: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(8 * 2, 0.4)).collect();
        let reqs: Vec<Request> = paths
            .iter()
            .map(|p| Request::Signature { path: p.clone(), stream: 8, d: 2, depth: 3 })
            .collect();
        let resps = c.call_many(reqs);
        for (p, r) in paths.iter().zip(&resps) {
            let r = r.as_ref().expect("response");
            assert_eq!(r.backend, Backend::Native);
            assert_eq!(r.values, signature(p, 8, &spec), "lane row != per-path signature");
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.native_requests, 6);
        assert_eq!(snap.batches, 1, "same-spec requests should coalesce into one microbatch");
        assert_eq!(snap.real_rows, 6);
        assert_eq!(snap.padded_rows, 8);
    }

    #[test]
    fn native_microbatch_separates_ragged_shapes() {
        // A ragged mix (different stream lengths) cannot share a lane
        // sweep: the batcher keys on shape, so each shape flushes as its
        // own microbatch and every caller still gets its exact result.
        let c = Coordinator::new(CoordinatorConfig {
            native_batch: 8,
            linger: Duration::from_millis(10),
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(13);
        let short = rng.normal_vec(5 * 2, 0.4);
        let long = rng.normal_vec(9 * 2, 0.4);
        let resps = c.call_many(vec![
            Request::Signature { path: short.clone(), stream: 5, d: 2, depth: 3 },
            Request::Signature { path: long.clone(), stream: 9, d: 2, depth: 3 },
        ]);
        let r0 = resps[0].as_ref().unwrap();
        let r1 = resps[1].as_ref().unwrap();
        assert_eq!(r0.values, signature(&short, 5, &spec));
        assert_eq!(r1.values, signature(&long, 9, &spec));
        assert_eq!(c.metrics().snapshot().batches, 2);
    }

    #[test]
    fn native_batching_disabled_serves_directly() {
        let c = Coordinator::new(CoordinatorConfig {
            native_batch: 0,
            ..CoordinatorConfig::native_only()
        })
        .unwrap();
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(14);
        let path = rng.normal_vec(6 * 2, 0.4);
        let resp = c
            .call(Request::Signature { path: path.clone(), stream: 6, d: 2, depth: 3 })
            .unwrap();
        assert_eq!(resp.values, signature(&path, 6, &spec));
        assert_eq!(c.metrics().snapshot().batches, 0, "no microbatching when disabled");
    }

    #[test]
    fn malformed_forward_requests_error_not_panic() {
        // stream < 2 and short buffers must reach the caller as Err on
        // every native forward surface — batched and direct alike.
        for native_batch in [0usize, 8] {
            let c = Coordinator::new(CoordinatorConfig {
                native_batch,
                ..CoordinatorConfig::native_only()
            })
            .unwrap();
            assert!(c
                .call(Request::Signature { path: vec![0.0; 2], stream: 1, d: 2, depth: 3 })
                .is_err());
            assert!(c
                .call(Request::LogSignature { path: vec![0.0; 2], stream: 1, d: 2, depth: 3 })
                .is_err());
            assert!(c
                .call(Request::Signature { path: vec![0.0; 3], stream: 2, d: 2, depth: 3 })
                .is_err());
        }
    }

    #[test]
    fn missing_artifact_dir_falls_back_to_native() {
        let c = Coordinator::new(CoordinatorConfig {
            artifact_dir: Some(PathBuf::from("/definitely/not/here")),
            ..Default::default()
        })
        .unwrap();
        assert!(!c.has_xla());
        let mut rng = Rng::new(5);
        let resp = c
            .call(Request::Signature { path: rng.normal_vec(4 * 2, 0.3), stream: 4, d: 2, depth: 2 })
            .unwrap();
        assert_eq!(resp.backend, Backend::Native);
    }
}
