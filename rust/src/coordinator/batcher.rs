//! Dynamic row batcher: gathers same-shaped requests, pads to the
//! executable's fixed batch size, executes once, scatters the rows back.
//!
//! XLA executables are compiled for static shapes, so serving variable
//! traffic requires exactly this component — it is the signature-serving
//! analogue of the continuous batcher in LLM serving systems. The native
//! lane-fused microbatcher (`Signature` *and* `LogSignature` requests)
//! rides the same type with a different backend.
//!
//! The pending-queue / condvar / deadline machinery lives in the unified
//! [`super::flusher::GroupBatcher`]; this module is the row-shaped
//! instantiation — its executor assembles the padded row matrix, runs the
//! [`BatchBackend`], and scatters per-row results (or the batch error) to
//! every caller's channel.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::flusher::{GroupBatcher, GroupExecutor};
use super::metrics::Metrics;
use crate::ta::{Precision, Rows};

/// Shape key of a batchable computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BatchShape {
    /// "sig" | "logsig" semantics are carried by the backend; the batcher
    /// only needs distinct keys.
    pub kind: u8,
    /// Fixed batch capacity of the backing executable.
    pub batch: usize,
    pub length: usize,
    pub d: usize,
    pub depth: usize,
    /// Element precision of the batch: every row submitted under this
    /// shape is a [`Rows`] buffer of this precision, end to end (no wire
    /// upcast/downcast). Part of the queue identity, so f32 and f64
    /// requests of one logical shape never share a microbatch (their
    /// results differ bitwise).
    pub prec: Precision,
    /// Input row width (e.g. `length * d` for sig, `length * d + sig_len`
    /// for grad rows that carry a cotangent).
    pub in_dim: usize,
    /// Output row width.
    pub out_dim: usize,
}

impl BatchShape {
    pub fn in_row(&self) -> usize {
        self.in_dim
    }
}

/// Executes one padded batch. Implemented by the XLA engine (production),
/// the native lane-fused backend, and mock backends (tests).
pub trait BatchBackend: Send + Sync + 'static {
    /// Run one batch. `padded` is typed at `shape.prec` and only its first
    /// `n_real` rows carry real requests; the rest are zero padding for
    /// fixed-shape backends. Backends free of the static-shape constraint
    /// (the native lane engine) may compute just the real rows — the
    /// result must be typed at `shape.prec`, hold at least
    /// `n_real * shape.out_dim` values, and rows beyond `n_real` are
    /// never read.
    fn run(&self, shape: &BatchShape, padded: &Rows, n_real: usize) -> anyhow::Result<Rows>;
}

type RowSender = mpsc::Sender<anyhow::Result<Rows>>;

/// Queue identity of a shape: everything except the batch capacity. The
/// adaptive planner may hand later submitters of the same logical shape a
/// different capacity, and they must still coalesce into the pending batch
/// (whose capacity the first submitter fixed) rather than fork a parallel
/// queue.
fn queue_key(shape: &BatchShape) -> BatchShape {
    BatchShape { batch: 0, ..*shape }
}

/// The row-shaped [`GroupExecutor`]: pads the gathered rows to the group
/// capacity, runs the backend once, and scatters per-row results.
struct RowExecutor {
    backend: Arc<dyn BatchBackend>,
    metrics: Arc<Metrics>,
}

impl GroupExecutor for RowExecutor {
    /// The capacity-stripped shape ([`queue_key`]).
    type Key = BatchShape;
    type Item = (Rows, RowSender);

    fn execute(&self, key: BatchShape, capacity: usize, items: Vec<Self::Item>) {
        use std::sync::atomic::Ordering;
        let shape = BatchShape { batch: capacity, ..key };
        let n_real = items.len();
        // Every row was precision-checked at submit, so the gather is
        // homogeneous by construction at the queue's dtype.
        let mut padded = Rows::zeros(shape.prec, 0);
        let mut senders = Vec::with_capacity(n_real);
        for (row, tx) in items {
            padded.extend_from(&row).expect("queue rows share the shape's precision");
            senders.push(tx);
        }
        padded.resize(shape.batch * shape.in_row());
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics.real_rows.fetch_add(n_real as u64, Ordering::Relaxed);
        self.metrics.padded_rows.fetch_add(shape.batch as u64, Ordering::Relaxed);
        match self.backend.run(&shape, &padded, n_real) {
            Ok(out) => {
                debug_assert!(out.len() >= n_real * shape.out_dim);
                debug_assert_eq!(out.precision(), shape.prec);
                for (i, tx) in senders.into_iter().enumerate() {
                    let row = out.slice(i * shape.out_dim..(i + 1) * shape.out_dim);
                    let _ = tx.send(Ok(row));
                }
            }
            Err(e) => {
                // One *batch* failure; the per-request `errors` counter is
                // bumped by `Coordinator::call` when the error reaches each
                // caller, so counting it here too would double-count.
                self.metrics.batch_failures.fetch_add(1, Ordering::Relaxed);
                for tx in senders {
                    let _ = tx.send(Err(anyhow::anyhow!("batch execution failed: {e}")));
                }
            }
        }
    }
}

/// The dynamic row batcher: a [`GroupBatcher`] instantiation keyed on the
/// capacity-stripped [`BatchShape`]. Submit rows; receive each row's
/// result on its own channel once the batch executes (full, or linger
/// elapsed).
pub struct Batcher {
    inner: GroupBatcher<RowExecutor>,
}

impl Batcher {
    pub fn new(backend: Arc<dyn BatchBackend>, metrics: Arc<Metrics>, linger: Duration) -> Batcher {
        let executor = Arc::new(RowExecutor { backend, metrics });
        Batcher { inner: GroupBatcher::new("signax-batcher", executor, linger) }
    }

    /// Submit one request row. Returns a receiver for this row's output.
    /// If the batch fills, it is executed on the calling thread (keeping
    /// tail latency off the flusher); otherwise the flusher handles it at
    /// the linger deadline.
    ///
    /// Takes the row by value: it moves into the pending group untouched,
    /// so the only copy on the hot path is the executor's gather into the
    /// padded batch matrix — the same single copy the pre-unification
    /// batcher paid.
    pub fn submit(
        &self,
        shape: BatchShape,
        row: Rows,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Rows>>> {
        anyhow::ensure!(row.len() == shape.in_row(), "row has wrong width");
        anyhow::ensure!(
            row.precision() == shape.prec,
            "row precision {} does not match the shape's {}",
            row.precision().label(),
            shape.prec.label()
        );
        let (tx, rx) = mpsc::channel();
        self.inner.submit(queue_key(&shape), shape.batch, (row, tx))?;
        Ok(rx)
    }

    /// Force-flush everything (used on shutdown and by tests).
    pub fn flush(&self) {
        self.inner.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::super::rows::with_elem;
    use super::*;
    use crate::substrate::propcheck::property;
    use crate::ta::Elem;

    /// A mock backend computing signatures natively row by row (at the
    /// queue's precision); errors when `fail` is set.
    struct MockBackend {
        fail: bool,
    }

    impl BatchBackend for MockBackend {
        fn run(&self, shape: &BatchShape, padded: &Rows, _n_real: usize) -> anyhow::Result<Rows> {
            anyhow::ensure!(!self.fail, "mock failure");
            let spec = crate::ta::SigSpec::new(shape.d, shape.depth).unwrap();
            with_elem!(shape.prec, E, {
                let p = E::rows_as_slice(padded)?;
                let mut out = vec![E::ZERO; shape.batch * shape.out_dim];
                for b in 0..shape.batch {
                    let row = &p[b * shape.in_row()..(b + 1) * shape.in_row()];
                    let sig = crate::signature::signature(row, shape.length, &spec);
                    out[b * shape.out_dim..(b + 1) * shape.out_dim].copy_from_slice(&sig);
                }
                Ok(E::rows_from(out))
            })
        }
    }

    fn shape(batch: usize) -> BatchShape {
        let spec = crate::ta::SigSpec::new(2, 3).unwrap();
        BatchShape {
            kind: 0,
            batch,
            length: 4,
            d: 2,
            depth: 3,
            prec: Precision::F32,
            in_dim: 4 * 2,
            out_dim: spec.sig_len(),
        }
    }

    #[test]
    fn full_batch_executes_inline() {
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::new(
            Arc::new(MockBackend { fail: false }),
            Arc::clone(&metrics),
            Duration::from_secs(60), // linger long: only fullness triggers
        );
        let sh = shape(3);
        let spec = crate::ta::SigSpec::new(2, 3).unwrap();
        let mut rxs = vec![];
        let mut expected = vec![];
        let mut rng = crate::substrate::rng::Rng::new(1);
        for _ in 0..3 {
            let row = rng.normal_vec(sh.in_row(), 0.5);
            expected.push(crate::signature::signature(&row, 4, &spec));
            rxs.push(batcher.submit(sh, row.into()).unwrap());
        }
        for (rx, exp) in rxs.into_iter().zip(expected) {
            let got = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            crate::substrate::propcheck::assert_close(got.as_f32().unwrap(), &exp, 1e-6, 1e-7);
        }
        let s = metrics.snapshot();
        assert_eq!(s.batches, 1);
        assert_eq!(s.real_rows, 3);
        assert_eq!(s.padded_rows, 3);
    }

    #[test]
    fn linger_flushes_partial_batch() {
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::new(
            Arc::new(MockBackend { fail: false }),
            Arc::clone(&metrics),
            Duration::from_millis(20),
        );
        let sh = shape(8); // capacity 8, we submit 2
        let mut rng = crate::substrate::rng::Rng::new(2);
        let row = rng.normal_vec(sh.in_row(), 0.5);
        let rx = batcher.submit(sh, row.into()).unwrap();
        let rx2 = batcher.submit(sh, rng.normal_vec(sh.in_row(), 0.5).into()).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(got.len(), sh.out_dim);
        assert!(rx2.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        assert!(metrics.padding_ratio() > 0.5); // 6 of 8 rows were padding
    }

    #[test]
    fn padding_never_leaks_between_requests() {
        // Property: each row's result equals the stand-alone computation,
        // independent of batch packing order and fill level.
        property("batcher no-leak", 10, |g| {
            let batch_cap = g.usize_in(2, 6);
            let n_req = g.usize_in(1, batch_cap);
            g.label(format!("cap={batch_cap} n={n_req}"));
            let metrics = Arc::new(Metrics::default());
            let batcher = Batcher::new(
                Arc::new(MockBackend { fail: false }),
                metrics,
                Duration::from_millis(5),
            );
            let sh = shape(batch_cap);
            let spec = crate::ta::SigSpec::new(2, 3).unwrap();
            let mut rxs = vec![];
            let mut expected = vec![];
            for _ in 0..n_req {
                let row = g.normal_vec(sh.in_row(), 0.5);
                expected.push(crate::signature::signature(&row, 4, &spec));
                rxs.push(batcher.submit(sh, row.into()).unwrap());
            }
            for (rx, exp) in rxs.into_iter().zip(expected) {
                let got = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
                crate::substrate::propcheck::assert_close(got.as_f32().unwrap(), &exp, 1e-6, 1e-7);
            }
        });
    }

    #[test]
    fn backend_failure_propagates_to_every_caller() {
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::new(
            Arc::new(MockBackend { fail: true }),
            Arc::clone(&metrics),
            Duration::from_millis(5),
        );
        let sh = shape(2);
        let mut rng = crate::substrate::rng::Rng::new(3);
        let rx1 = batcher.submit(sh, rng.normal_vec(sh.in_row(), 0.5).into()).unwrap();
        let rx2 = batcher.submit(sh, rng.normal_vec(sh.in_row(), 0.5).into()).unwrap();
        assert!(rx1.recv_timeout(Duration::from_secs(5)).unwrap().is_err());
        assert!(rx2.recv_timeout(Duration::from_secs(5)).unwrap().is_err());
        // One failed batch execution; request-level errors are counted by
        // `Coordinator::call` (once per affected request), not here.
        let snap = metrics.snapshot();
        assert_eq!(snap.batch_failures, 1);
        assert_eq!(snap.errors, 0);
    }

    /// A backend that sleeps once (the first run) then becomes fast — used
    /// to catch the flusher mid-execution.
    struct SlowOnceBackend {
        slept: std::sync::atomic::AtomicBool,
    }

    impl BatchBackend for SlowOnceBackend {
        fn run(&self, shape: &BatchShape, _padded: &Rows, _n_real: usize) -> anyhow::Result<Rows> {
            if !self.slept.swap(true, std::sync::atomic::Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(450));
            }
            Ok(Rows::zeros(shape.prec, shape.batch * shape.out_dim))
        }
    }

    #[test]
    fn submit_during_flush_is_not_delayed_by_a_stale_deadline() {
        // Regression for the missed-wakeup bug: a submit landing while the
        // flusher is mid-`execute_batch` loses its notify, and the old
        // flusher then slept on a deadline computed *before* execution —
        // flushing the new batch at up to 2x linger late. Timeline with
        // linger = 300ms and a 450ms first execution: A's batch flushes at
        // ~300ms and executes until ~750ms; B lands at ~375ms (deadline
        // ~675ms). Fixed flusher: B flushes when the execution ends,
        // waited ~375ms. Stale-deadline flusher: B waits a further full
        // linger after the execution, waited ~675ms. The 550ms bound sits
        // between the two with >=125ms headroom either side for CI jitter.
        let linger = Duration::from_millis(300);
        let batcher = Batcher::new(
            Arc::new(SlowOnceBackend { slept: std::sync::atomic::AtomicBool::new(false) }),
            Arc::new(Metrics::default()),
            linger,
        );
        let sh = shape(8); // never fills: only the linger flushes it
        let mut rng = crate::substrate::rng::Rng::new(9);
        let row = rng.normal_vec(sh.in_row(), 0.5);
        let _rx_a = batcher.submit(sh, row.clone().into()).unwrap();
        std::thread::sleep(Duration::from_millis(375));
        let t0 = std::time::Instant::now();
        let rx_b = batcher.submit(sh, row.into()).unwrap();
        assert!(rx_b.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_millis(550),
            "batch flushed only after {waited:?} (stale linger deadline)"
        );
    }

    #[test]
    fn capacity_changes_still_coalesce_into_one_batch() {
        // The adaptive planner may hand two submitters of the same logical
        // shape different capacities; the queue keys on the shape minus
        // capacity, so they must land in one pending batch whose capacity
        // is the first submitter's.
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::new(
            Arc::new(MockBackend { fail: false }),
            Arc::clone(&metrics),
            Duration::from_secs(60), // only fullness flushes
        );
        let first = shape(2);
        let mut second = shape(2);
        second.batch = 8; // planner "widened" the capacity mid-window
        let mut rng = crate::substrate::rng::Rng::new(21);
        let rx1 = batcher.submit(first, rng.normal_vec(first.in_row(), 0.5).into()).unwrap();
        // Fills the capacity-2 pending batch despite asking for 8.
        let rx2 = batcher.submit(second, rng.normal_vec(second.in_row(), 0.5).into()).unwrap();
        assert!(rx1.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        assert!(rx2.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        let snap = metrics.snapshot();
        assert_eq!(snap.batches, 1, "same logical shape must share one queue");
        assert_eq!(snap.real_rows, 2);
        assert_eq!(snap.padded_rows, 2, "executed at the first submitter's capacity");
    }

    #[test]
    fn wrong_row_width_or_precision_rejected() {
        let batcher = Batcher::new(
            Arc::new(MockBackend { fail: false }),
            Arc::new(Metrics::default()),
            Duration::from_millis(5),
        );
        assert!(batcher.submit(shape(2), vec![0.0f32; 3].into()).is_err());
        // An f64 row under an f32-keyed shape is a hard error, not a cast.
        let sh = shape(2);
        assert!(batcher.submit(sh, vec![0.0f64; sh.in_row()].into()).is_err());
    }

    #[test]
    fn distinct_shapes_batched_separately() {
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::new(
            Arc::new(MockBackend { fail: false }),
            Arc::clone(&metrics),
            Duration::from_millis(10),
        );
        let sh_a = shape(1);
        let mut sh_b = shape(1);
        sh_b.length = 6;
        sh_b.in_dim = 6 * 2;
        sh_b.kind = 0;
        // Same logical shape as `sh_a`, different compute precision: the
        // precision is part of the queue identity.
        let mut sh_c = shape(1);
        sh_c.prec = Precision::F64;
        let mut rng = crate::substrate::rng::Rng::new(4);
        let wide: Vec<f64> =
            rng.normal_vec(sh_c.in_row(), 0.5).into_iter().map(f64::from).collect();
        let rx_a = batcher.submit(sh_a, rng.normal_vec(sh_a.in_row(), 0.5).into()).unwrap();
        let rx_b = batcher.submit(sh_b, rng.normal_vec(sh_b.in_row(), 0.5).into()).unwrap();
        let rx_c = batcher.submit(sh_c, wide.into()).unwrap();
        assert!(rx_a.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        assert!(rx_b.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        let got_c = rx_c.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(got_c.precision(), Precision::F64, "f64 queue answers in f64");
        assert_eq!(metrics.snapshot().batches, 3);
    }
}
