//! Streaming sessions: "keeping the signature up-to-date" (§5.5, eq. 7).
//!
//! A session owns a [`crate::path::Path`]; feeding new points extends the
//! precomputed expanding/inverted signatures incrementally (fused ops
//! only), and interval queries stay O(1) at any moment. This is the
//! serving-side state behind the coordinator's streaming requests
//! (`OpenStream` / `Feed` / `QueryInterval` / `LogSigQueryInterval` /
//! `CloseStream`).
//!
//! Sessions are **natively typed**: each session records its element
//! precision at open (the spec's [`SigSpec::dtype`]) and holds a
//! `Path<f32>` or `Path<f64>` accordingly ([`ResidentPath`]'s variants).
//! Points arrive and signatures leave as typed [`Rows`] — an f64 session
//! never sees an f32 intermediate, and feeding rows of the wrong
//! precision is a per-call error, not a cast. Lane-fused feed batches
//! group by `(d, depth, dtype)`, so a sweep is always homogeneous in
//! element type.
//!
//! Scalability and memory bounds:
//!
//! - The table is **sharded**: session ids map onto independent
//!   `Mutex<HashMap>` shards, and the values are `Arc<Mutex<Path>>`, so a
//!   shard lock is only ever held for a map lookup — never across a `Path`
//!   operation. Feeds to distinct sessions run fully in parallel.
//! - `Path` storage is O(L) per session (the trade the paper makes for
//!   O(1) queries), so a serving process must bound it: an optional
//!   **byte budget** ([`SessionConfig::budget_bytes`], measured with
//!   [`Path::storage_bytes`]) is enforced by evicting the least recently
//!   used idle sessions, and an optional **idle TTL**
//!   ([`SessionConfig::ttl`]) is enforced by a background sweeper thread.
//!
//! Session lifecycle and durability (the [`crate::state`] layer):
//!
//! - Each session's slot is **Resident** (hot `Path`), **Spilled** (state
//!   serialized into a [`crate::state::SessionStore`] blob; only spec,
//!   length, and byte size stay in memory), or **Defunct** (closed or
//!   destroyed). With a spill store configured
//!   ([`SessionConfig::spill`]), LRU eviction and TTL expiry *spill*
//!   instead of destroying: the session stays in the table and the next
//!   touch transparently reloads it — **bitwise**, via the `Path` codec.
//!   Without a store, eviction destroys state exactly as before.
//! - An operation racing an eviction is safe by construction: spilling
//!   `try_lock`s the slot and skips busy sessions, and an operation that
//!   finds its slot spilled reloads before proceeding.
//! - Errors are precise about why a session is gone: never-opened ids,
//!   closed ids, and destroyed-by-eviction ids produce distinct messages
//!   (closed/evicted ids leave tombstones; these are a few bytes each
//!   and bounded by the number of sessions ever retired).
//! - With [`crate::state::SpillConfig::Disk`] (the CLI's `--state-dir`),
//!   every open/feed/close also appends to a write-behind feed-delta log
//!   ([`crate::state::FeedLog`]), fsync-batched by the sweeper thread.
//!   On construction the manager replays that log and recovers every
//!   session bitwise (`Path` extension is exactly resumable), so a
//!   restarted server answers interval queries identically.
//!
//! Rolling-window sessions ([`SessionManager::open_window`] /
//! [`SessionManager::poll_window`]): a session opened with a
//! [`WindowSpec`] carries a [`RollingWindow`] alongside its `Path`. Every
//! feed advances it — one O(1) `I_i ⊠ S_j` per newly-complete slide —
//! and the window's retention policy truncates the dead prefix through
//! [`Path::truncate_front`], so a windowed session holds O(window)
//! bytes no matter how long its stream runs. Emitted slides buffer in
//! the window's `pending` rows (counted against the byte budget,
//! spilled and WAL-recovered with the rest of the state, since their
//! source points may already be truncated) until a poll drains them;
//! polls are themselves WAL-logged so a warm restart re-delivers
//! exactly the undelivered suffix. Polls are pageable: `PollWindow`
//! takes an optional `max_slides` cap and the response carries a
//! `window_remaining` continuation count, with the WAL `Poll` record
//! logging the *delivered-up-to* cursor of the actual page so paged
//! drains replay exactly like full ones.
//!
//! Slide advancement is lane-fused like feeding: after a feed-lane
//! flush ([`SessionManager::feed_batch`] / `feed_wave`), windowed
//! sessions in the flushed group whose windows share a
//! `(d, depth, dtype, logsig)` key advance together through one
//! [`RollingWindow::advance_batch`] sweep over the lane-interleaved
//! Chen kernels ([`crate::ta::batch`]) — gated by
//! [`ExecPlanner::plan_window_sweep`] (scalar below 2 lanes) and
//! bitwise identical per session to the scalar `advance` loop.
//! [`Metrics`] counts the sweeps (`window_slide_batches`) and the
//! slides they carried (`window_slides_batched`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::exec::{ExecPlan, ExecPlanner, WorkShape};
use crate::logsignature::LogSigPlan;
use crate::path::{Path, RollingWindow, WindowSpec};
use crate::state::{
    deserialize_session, serialize_session_into, session_serialized_len, FeedLog, SessionStore,
    SpillConfig, WalRecord,
};
use crate::ta::{Elem, Precision, Rows, SigSpec};

/// Opaque session handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionId(pub u64);

/// Tuning knobs for the session table (see [`SessionManager`]).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Number of independent map shards. More shards reduce contention on
    /// open/close/lookup under many concurrent clients.
    pub shards: usize,
    /// Budget for resident precomputed storage across all sessions, in
    /// bytes ([`Path::storage_bytes`]); `None` = unbounded. When an open
    /// or feed pushes the total over budget, least-recently-used *other*
    /// sessions are evicted until the total fits again. The session just
    /// touched is never evicted by its own enforcement, and sessions with
    /// an operation in flight are skipped — so a single session larger
    /// than the whole budget is allowed to remain.
    pub budget_bytes: Option<usize>,
    /// Evict sessions idle for longer than this; `None` = no TTL. Enforced
    /// by a background sweeper thread owned by the manager.
    pub ttl: Option<Duration>,
    /// How often the sweeper checks for expired sessions (and flushes the
    /// feed-delta log when one is configured).
    pub sweep_interval: Duration,
    /// Where eviction sends session state. [`SpillConfig::None`] destroys
    /// it (the original behaviour); `Memory`/`Disk` spill it for
    /// transparent reload, and `Disk` additionally logs feeds for warm
    /// restart.
    pub spill: SpillConfig,
    /// First session id this manager issues. Ids start at 1.
    pub first_id: u64,
    /// Stride between issued ids. A sharded deployment gives shard `k`
    /// (0-based) `first_id = k + 1, id_stride = n`, so ids stay unique
    /// across shards and [`crate::state::Placement::locate`] finds the
    /// owner arithmetically.
    pub id_stride: u64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            shards: 16,
            budget_bytes: None,
            ttl: None,
            sweep_interval: Duration::from_millis(250),
            spill: SpillConfig::None,
            first_id: 1,
            id_stride: 1,
        }
    }
}

/// Why a session is no longer serviceable (tombstone for error taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Gone {
    /// Explicitly closed by the client.
    Closed,
    /// Destroyed by budget/TTL eviction with no spill store configured.
    Evicted,
}

/// A session's monomorphic state: the `Path` plus, for sessions opened
/// with [`SessionManager::open_window`], the rolling-window emission
/// state riding on it.
struct TypedSession<E: Elem> {
    path: Path<E>,
    window: Option<RollingWindow<E>>,
}

impl<E: Elem> TypedSession<E> {
    fn build(
        spec: &SigSpec,
        points: &[E],
        stream: usize,
        window: Option<WindowSpec>,
    ) -> anyhow::Result<TypedSession<E>> {
        let mut s = TypedSession {
            path: Path::new(spec, points, stream)?,
            window: match window {
                Some(w) => Some(RollingWindow::new(spec, w)?),
                None => None,
            },
        };
        // A seed path of >= len points already completes some windows;
        // emit them now so open-then-poll sees them.
        s.advance_window()?;
        Ok(s)
    }

    /// Emit newly-complete slides and apply retention; no-op for plain
    /// streaming sessions.
    fn advance_window(&mut self) -> anyhow::Result<()> {
        if let Some(w) = &mut self.window {
            w.advance(&mut self.path)?;
        }
        Ok(())
    }

    /// Drain up to `max_slides` undelivered slides (`None` = the whole
    /// backlog): `(first, delivered-up-to, rows, slides still pending)`.
    fn poll(&mut self, max_slides: Option<u64>) -> anyhow::Result<(u64, u64, Vec<E>, u64)> {
        let w = self.window.as_mut().ok_or_else(|| {
            anyhow::anyhow!("session has no rolling window (opened as a plain stream)")
        })?;
        let (first, rows) = match max_slides {
            Some(cap) => w.poll_limited(usize::try_from(cap).unwrap_or(usize::MAX)),
            None => w.poll(),
        };
        let upto = first + (rows.len() / w.out_dim()) as u64;
        Ok((first, upto, rows, w.pending_rows() as u64))
    }

    /// Path buffers plus buffered undelivered window rows — pending
    /// output is state (its source points may be truncated), so it
    /// counts against the byte budget like everything else resident.
    fn storage_bytes(&self) -> usize {
        self.path.storage_bytes() + self.window.as_ref().map_or(0, |w| w.pending_bytes())
    }
}

/// A resident session's state at its native element width. Serving-facing
/// accessors speak typed [`Rows`]; the two variants are the only place the
/// session layer distinguishes f32 from f64 state, and every arm is
/// cast-free — each delegates to the `Elem`-generic `Path` /
/// `RollingWindow` methods at the session's own precision.
enum ResidentPath {
    F32(TypedSession<f32>),
    F64(TypedSession<f64>),
}

impl ResidentPath {
    /// Build a path from typed seed rows; the rows' precision must match
    /// the spec's dtype (a mismatch is an error, never a cast).
    fn new(spec: &SigSpec, points: &Rows, stream: usize) -> anyhow::Result<ResidentPath> {
        ResidentPath::new_with_window(spec, points, stream, None)
    }

    /// Build a session, optionally with rolling-window state advanced
    /// over the seed path.
    fn new_with_window(
        spec: &SigSpec,
        points: &Rows,
        stream: usize,
        window: Option<WindowSpec>,
    ) -> anyhow::Result<ResidentPath> {
        anyhow::ensure!(
            points.precision() == spec.dtype(),
            "open rows are {} but the spec's dtype is {}",
            points.precision().label(),
            spec.dtype().label()
        );
        Ok(match points {
            Rows::F32(p) => ResidentPath::F32(TypedSession::build(spec, p, stream, window)?),
            Rows::F64(p) => ResidentPath::F64(TypedSession::build(spec, p, stream, window)?),
        })
    }

    /// Reload from a spill blob (path plus any window section). The dtype
    /// comes from the slot's cold metadata (spilled slots keep their spec
    /// in memory), so the codec is asked for exactly the width that was
    /// serialized.
    fn deserialize(dtype: Precision, blob: &[u8]) -> anyhow::Result<ResidentPath> {
        Ok(match dtype {
            Precision::F32 => {
                let (path, window) = deserialize_session(blob)?;
                ResidentPath::F32(TypedSession { path, window })
            }
            Precision::F64 => {
                let (path, window) = deserialize_session(blob)?;
                ResidentPath::F64(TypedSession { path, window })
            }
        })
    }

    fn spec(&self) -> &SigSpec {
        match self {
            ResidentPath::F32(s) => s.path.spec(),
            ResidentPath::F64(s) => s.path.spec(),
        }
    }

    fn len(&self) -> usize {
        match self {
            ResidentPath::F32(s) => s.path.len(),
            ResidentPath::F64(s) => s.path.len(),
        }
    }

    fn storage_bytes(&self) -> usize {
        match self {
            ResidentPath::F32(s) => s.storage_bytes(),
            ResidentPath::F64(s) => s.storage_bytes(),
        }
    }

    fn serialized_len(&self) -> usize {
        match self {
            ResidentPath::F32(s) => session_serialized_len(&s.path, s.window.as_ref()),
            ResidentPath::F64(s) => session_serialized_len(&s.path, s.window.as_ref()),
        }
    }

    fn serialize_into(&self, out: &mut Vec<u8>) {
        match self {
            ResidentPath::F32(s) => serialize_session_into(&s.path, s.window.as_ref(), out),
            ResidentPath::F64(s) => serialize_session_into(&s.path, s.window.as_ref(), out),
        }
    }

    /// Extend with typed rows, then advance any rolling window. Scalar
    /// feeds and WAL replay both come through here, so a warm restart
    /// emits (and truncates) exactly what the live process did.
    fn update(&mut self, points: &Rows, count: usize) -> anyhow::Result<()> {
        match self {
            ResidentPath::F32(s) => {
                s.path.update(f32::rows_as_slice(points)?, count)?;
                s.advance_window()
            }
            ResidentPath::F64(s) => {
                s.path.update(f64::rows_as_slice(points)?, count)?;
                s.advance_window()
            }
        }
    }

    /// Advance any rolling window after an out-of-band path extension
    /// (the lane-fused sweep extends via `Path::update_batch`, which
    /// does not know about windows).
    fn advance_window(&mut self) -> anyhow::Result<()> {
        match self {
            ResidentPath::F32(s) => s.advance_window(),
            ResidentPath::F64(s) => s.advance_window(),
        }
    }

    /// Drain up to `max_slides` undelivered window slides (`None` = all):
    /// `(first slide index, delivered-up-to, rows, slides still pending)`.
    /// Errors for sessions opened without a window.
    fn poll(&mut self, max_slides: Option<u64>) -> anyhow::Result<(u64, u64, Rows, u64)> {
        Ok(match self {
            ResidentPath::F32(s) => {
                let (first, upto, rows, left) = s.poll(max_slides)?;
                (first, upto, rows.into(), left)
            }
            ResidentPath::F64(s) => {
                let (first, upto, rows, left) = s.poll(max_slides)?;
                (first, upto, rows.into(), left)
            }
        })
    }

    /// Replay a logged poll (drop rows a pre-crash client already got).
    fn mark_delivered(&mut self, upto: u64) {
        match self {
            ResidentPath::F32(s) => {
                if let Some(w) = &mut s.window {
                    w.mark_delivered(upto);
                }
            }
            ResidentPath::F64(s) => {
                if let Some(w) = &mut s.window {
                    w.mark_delivered(upto);
                }
            }
        }
    }

    fn signature(&self) -> Rows {
        match self {
            ResidentPath::F32(s) => s.path.signature().into(),
            ResidentPath::F64(s) => s.path.signature().into(),
        }
    }

    fn query(&self, i: usize, j: usize) -> anyhow::Result<Rows> {
        match self {
            ResidentPath::F32(s) => Ok(s.path.query(i, j)?.into()),
            ResidentPath::F64(s) => Ok(s.path.query(i, j)?.into()),
        }
    }

    fn logsig_query(&self, i: usize, j: usize, plan: &LogSigPlan) -> anyhow::Result<Rows> {
        match self {
            ResidentPath::F32(s) => Ok(s.path.logsig_query(i, j, plan)?.into()),
            ResidentPath::F64(s) => Ok(s.path.logsig_query(i, j, plan)?.into()),
        }
    }
}

/// Element-typed access into a [`ResidentPath`], for code that has already
/// grouped sessions into dtype-homogeneous runs (the lane-fused feed
/// sweep) and needs the monomorphic `Path<E>` lanes back out.
trait TypedPath: Elem {
    fn path_mut(rp: &mut ResidentPath) -> &mut Path<Self>;
    /// Split borrow for the batched slide sweep: the path together with
    /// its rolling window (when the session is windowed), mutably at once.
    fn lanes_mut(rp: &mut ResidentPath) -> (&mut Path<Self>, Option<&mut RollingWindow<Self>>);
}

impl TypedPath for f32 {
    fn path_mut(rp: &mut ResidentPath) -> &mut Path<f32> {
        match rp {
            ResidentPath::F32(s) => &mut s.path,
            ResidentPath::F64(_) => unreachable!("run grouped by dtype"),
        }
    }

    fn lanes_mut(rp: &mut ResidentPath) -> (&mut Path<f32>, Option<&mut RollingWindow<f32>>) {
        match rp {
            ResidentPath::F32(s) => (&mut s.path, s.window.as_mut()),
            ResidentPath::F64(_) => unreachable!("run grouped by dtype"),
        }
    }
}

impl TypedPath for f64 {
    fn path_mut(rp: &mut ResidentPath) -> &mut Path<f64> {
        match rp {
            ResidentPath::F64(s) => &mut s.path,
            ResidentPath::F32(_) => unreachable!("run grouped by dtype"),
        }
    }

    fn lanes_mut(rp: &mut ResidentPath) -> (&mut Path<f64>, Option<&mut RollingWindow<f64>>) {
        match rp {
            ResidentPath::F64(s) => (&mut s.path, s.window.as_mut()),
            ResidentPath::F32(_) => unreachable!("run grouped by dtype"),
        }
    }
}

/// Where a session's state currently lives. Transitions happen only under
/// the slot mutex: Resident ⇄ Spilled (spill / transparent reload), and
/// either → Defunct (close, or destroy-on-evict without a store).
enum Slot {
    /// Hot: the precomputed `Path` is in memory, at its native width.
    Resident(ResidentPath),
    /// Cold: state lives in the spill store; enough metadata stays here
    /// to answer spec/length/dtype lookups without a reload.
    Spilled { spec: SigSpec, stream: usize, bytes: usize },
    /// Gone for good; in-flight operations holding the `Arc` see why.
    Defunct(Gone),
}

/// Lock-free mirror of the `Slot` variant (maintained under the slot
/// lock) so eviction/TTL scans can filter candidates without locking.
const STATE_RESIDENT: u8 = 0;
const STATE_SPILLED: u8 = 1;
const STATE_DEFUNCT: u8 = 2;

/// One live session. The slot mutex is the only lock held during actual
/// signature work; the bookkeeping fields are atomics so eviction scans
/// never block serving threads.
struct Session {
    slot: Mutex<Slot>,
    /// Mirror of the slot variant ([`STATE_RESIDENT`] &c).
    state: AtomicU8,
    /// Last accounted [`Path::storage_bytes`] (updated under the slot
    /// lock, so the resident total stays consistent with eviction).
    bytes: AtomicUsize,
    /// Manager-wide monotonic clock value at last touch (LRU order).
    touch: AtomicU64,
    /// Milliseconds since manager start at last touch (TTL clock).
    last_used_ms: AtomicU64,
}

/// The path of a slot known to be resident (`ensure_resident` ran).
fn resident_path(slot: &mut Slot) -> &mut ResidentPath {
    match slot {
        Slot::Resident(p) => p,
        _ => unreachable!("slot made resident before use"),
    }
}

/// A live slot's spec, hot or cold (spilled slots keep it in memory).
fn slot_spec(slot: &Slot) -> &SigSpec {
    match slot {
        Slot::Resident(p) => p.spec(),
        Slot::Spilled { spec, .. } => spec,
        Slot::Defunct(_) => unreachable!("defunct slots error before spec lookup"),
    }
}

struct Inner {
    cfg: SessionConfig,
    shards: Vec<Mutex<HashMap<u64, Arc<Session>>>>,
    /// Tombstones for retired ids (why each is gone), sharded like the
    /// live table.
    tombstones: Vec<Mutex<HashMap<u64, Gone>>>,
    metrics: Arc<Metrics>,
    /// Spill destination for evicted sessions, when configured.
    store: Option<Arc<dyn SessionStore>>,
    /// Feed-delta log for warm restarts, when configured.
    wal: Option<FeedLog>,
    epoch: Instant,
    clock: AtomicU64,
    /// Total resident `Path::storage_bytes` across live sessions.
    resident: AtomicUsize,
    /// Total bytes currently spilled to the store.
    spilled: AtomicUsize,
    shutdown: Mutex<bool>,
    wake: Condvar,
}

impl Inner {
    fn shard(&self, id: u64) -> &Mutex<HashMap<u64, Arc<Session>>> {
        &self.shards[(id as usize) % self.shards.len()]
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn touch(&self, sess: &Session) {
        sess.touch.store(self.clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        sess.last_used_ms.store(self.now_ms(), Ordering::Relaxed);
    }

    fn tombstone_shard(&self, id: u64) -> &Mutex<HashMap<u64, Gone>> {
        &self.tombstones[(id as usize) % self.tombstones.len()]
    }

    /// The precise reason an id is not in the live table.
    fn gone_error(&self, id: SessionId) -> anyhow::Error {
        match self.tombstone_shard(id.0).lock().unwrap().get(&id.0) {
            Some(g) => self.defunct_error(id, *g),
            None => anyhow::anyhow!("unknown session {id:?} (never opened)"),
        }
    }

    fn defunct_error(&self, id: SessionId, gone: Gone) -> anyhow::Error {
        match gone {
            Gone::Closed => anyhow::anyhow!("session {id:?} is closed"),
            Gone::Evicted => anyhow::anyhow!(
                "session {id:?} was evicted (idle under memory pressure; \
                 a spill store, e.g. serve-stream --state-dir, keeps evicted \
                 sessions reloadable)"
            ),
        }
    }

    fn get(&self, id: SessionId) -> anyhow::Result<Arc<Session>> {
        if let Some(sess) = self.shard(id.0).lock().unwrap().get(&id.0) {
            return Ok(Arc::clone(sess));
        }
        Err(self.gone_error(id))
    }

    fn remove(&self, id: u64) -> Option<Arc<Session>> {
        self.shard(id).lock().unwrap().remove(&id)
    }

    /// Append to the feed-delta log, when one is configured. Buffered
    /// write-behind: durable after the sweeper's next flush. Called with
    /// the relevant slot lock held, so log order matches apply order.
    fn log_wal(&self, rec: &WalRecord) {
        if let Some(wal) = &self.wal {
            match wal.append(rec) {
                Ok(()) => {
                    self.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => eprintln!("signax: WAL append failed (durability degraded): {e}"),
            }
        }
    }

    fn flush_wal(&self) {
        if let Some(wal) = &self.wal {
            if let Err(e) = wal.flush() {
                eprintln!("signax: WAL flush failed (durability degraded): {e}");
            }
        }
    }

    /// Make a slot resident, transparently reloading it from the spill
    /// store if it was evicted cold. Returns whether a reload happened
    /// (the caller re-enforces the budget after releasing the lock, since
    /// the reload just grew the resident total). Errors carry the precise
    /// lifecycle reason for defunct slots.
    fn ensure_resident(
        &self,
        id: SessionId,
        sess: &Session,
        slot: &mut Slot,
    ) -> anyhow::Result<bool> {
        match slot {
            Slot::Resident(_) => Ok(false),
            Slot::Defunct(g) => Err(self.defunct_error(id, *g)),
            Slot::Spilled { spec, bytes, .. } => {
                let (dtype, bytes) = (spec.dtype(), *bytes);
                let store = self.store.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("session {id:?} is spilled but no spill store is configured")
                })?;
                let blob = store.get(id.0)?.ok_or_else(|| {
                    anyhow::anyhow!("spilled session {id:?} is missing from the spill store")
                })?;
                let path = ResidentPath::deserialize(dtype, &blob)?;
                // The blob is now redundant (state is hot again); dropping
                // it keeps the spilled-bytes gauge honest.
                let _ = store.remove(id.0);
                *slot = Slot::Resident(path);
                sess.state.store(STATE_RESIDENT, Ordering::Relaxed);
                sess.bytes.store(bytes, Ordering::Relaxed);
                self.resident.fetch_add(bytes, Ordering::Relaxed);
                self.spilled.fetch_sub(bytes, Ordering::Relaxed);
                self.metrics.sessions_reloaded.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
        }
    }

    /// Lock a session's slot, make it resident (reloading if spilled),
    /// and run `f` on its typed path. Returns `f`'s result plus whether a
    /// reload happened.
    fn with_resident<R>(
        &self,
        id: SessionId,
        sess: &Session,
        f: impl FnOnce(&mut ResidentPath) -> anyhow::Result<R>,
    ) -> anyhow::Result<(R, bool)> {
        let mut slot = sess.slot.lock().unwrap();
        let reloaded = self.ensure_resident(id, sess, &mut slot)?;
        Ok((f(resident_path(&mut slot))?, reloaded))
    }

    /// Try to spill a resident session to the store (it stays in the
    /// table, cold). Returns the bytes moved off the resident total, 0 if
    /// the session was busy, already cold, or the store write failed (in
    /// which case it simply stays resident — never lose state to make
    /// room). `try_lock` is what resolves the eviction-vs-in-flight-op
    /// race: a session mid-operation is skipped, not destroyed.
    fn spill(&self, id: u64, sess: &Session) -> usize {
        let store = self.store.as_ref().expect("spill requires a store");
        let Ok(mut slot) = sess.slot.try_lock() else { return 0 };
        let Slot::Resident(path) = &*slot else { return 0 };
        let mut blob = Vec::with_capacity(path.serialized_len());
        path.serialize_into(&mut blob);
        let (spec, stream) = (path.spec().clone(), path.len());
        if let Err(e) = store.put(id, &blob) {
            eprintln!("signax: spill of session {id} failed (kept resident): {e}");
            return 0;
        }
        let bytes = sess.bytes.load(Ordering::Relaxed);
        *slot = Slot::Spilled { spec, stream, bytes };
        sess.state.store(STATE_SPILLED, Ordering::Relaxed);
        self.resident.fetch_sub(bytes, Ordering::Relaxed);
        self.spilled.fetch_add(bytes, Ordering::Relaxed);
        self.metrics.sessions_spilled.fetch_add(1, Ordering::Relaxed);
        bytes
    }

    /// Finish removing a session that is already out of the map: mark its
    /// slot defunct, release its bytes, and leave a tombstone saying why.
    /// Taking the slot lock serialises against any in-flight operation,
    /// whose accounting also runs under that lock — so a session's bytes
    /// are counted in `resident`/`spilled` exactly while it is live.
    fn retire(&self, id: u64, sess: &Session, gone: Gone) {
        {
            let mut slot = sess.slot.lock().unwrap();
            match std::mem::replace(&mut *slot, Slot::Defunct(gone)) {
                Slot::Resident(_) => {
                    self.resident.fetch_sub(sess.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
                }
                Slot::Spilled { bytes, .. } => {
                    self.spilled.fetch_sub(bytes, Ordering::Relaxed);
                    if let Some(store) = &self.store {
                        let _ = store.remove(id);
                    }
                }
                Slot::Defunct(prev) => {
                    *slot = Slot::Defunct(prev); // already retired; keep the first cause
                    return;
                }
            }
            sess.state.store(STATE_DEFUNCT, Ordering::Relaxed);
            self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
        }
        self.tombstone_shard(id).lock().unwrap().insert(id, gone);
    }

    /// Reconcile a session's accounted bytes with its current storage.
    /// Feeds grow the path, but window retention truncates the dead
    /// prefix and polls drain pending rows — the delta goes either way,
    /// so this must never assume growth (an unsigned subtract would
    /// wrap). Called under the slot lock, like all byte accounting.
    fn account_bytes(&self, sess: &Session, new_bytes: usize) {
        let old_bytes = sess.bytes.swap(new_bytes, Ordering::Relaxed);
        if new_bytes >= old_bytes {
            self.resident.fetch_add(new_bytes - old_bytes, Ordering::Relaxed);
        } else {
            self.resident.fetch_sub(old_bytes - new_bytes, Ordering::Relaxed);
        }
    }

    fn publish_gauges(&self) {
        self.metrics
            .session_bytes
            .store(self.resident.load(Ordering::Relaxed) as u64, Ordering::Relaxed);
        self.metrics
            .spilled_bytes
            .store(self.spilled.load(Ordering::Relaxed) as u64, Ordering::Relaxed);
    }

    /// Enforce the byte budget after the `exclude` sessions were touched,
    /// evicting idle sessions in LRU order until the resident total fits
    /// (`exclude` is one id for a scalar open/feed, the whole group for a
    /// lane-fused feed batch — none of the sessions just served may be
    /// evicted by their own enforcement).
    ///
    /// One scan per pass: candidates are snapshotted and sorted by touch
    /// once, then evicted down the list — O(N log N) per enforcement, not
    /// O(N) per eviction. Touches that land after the snapshot make the
    /// order approximate, which is acceptable for LRU. A victim whose
    /// `remove` is lost to a racing close/evict is simply skipped; the
    /// outer loop re-scans only when this pass evicted something yet the
    /// table is still over budget (so it terminates: each pass shrinks
    /// the table or ends the loop).
    ///
    /// Hysteresis: once over budget, eviction continues down to
    /// `budget - budget/8`, so the next budget/8 bytes of growth don't
    /// trigger a scan at all. Without the slack, a table sitting exactly
    /// at budget rescans all N sessions on every feed — O(N) per
    /// operation, which the million-session soak turns into a stall.
    fn enforce_budget(&self, exclude: &[u64]) {
        if let Some(budget) = self.cfg.budget_bytes {
            let floor = budget - budget / 8;
            while self.resident.load(Ordering::Relaxed) > budget {
                // Only resident sessions hold resident bytes; spilled and
                // defunct slots are filtered by the lock-free state mirror.
                let mut cands: Vec<(u64, u64)> = vec![];
                for shard in &self.shards {
                    let guard = shard.lock().unwrap();
                    for (&id, sess) in guard.iter() {
                        if !exclude.contains(&id)
                            && sess.state.load(Ordering::Relaxed) == STATE_RESIDENT
                        {
                            cands.push((sess.touch.load(Ordering::Relaxed), id));
                        }
                    }
                }
                cands.sort_unstable();
                let mut evicted_any = false;
                for &(_, id) in &cands {
                    if self.resident.load(Ordering::Relaxed) <= floor {
                        break;
                    }
                    let Some(sess) = self.shard(id).lock().unwrap().get(&id).cloned() else {
                        continue; // raced away: not a candidate
                    };
                    if self.store.is_some() {
                        // Spill, don't destroy: the session stays in the
                        // table, cold, reloadable on the next touch.
                        // `spill` skips busy sessions via try_lock.
                        if self.spill(id, &sess) > 0 {
                            evicted_any = true;
                        }
                    } else {
                        // No store: destroy, exactly the old behaviour.
                        // Eviction targets *idle* sessions — skip any whose
                        // slot mutex is held right now (a concurrent client
                        // is mid-operation on it; it is not LRU, its touch
                        // just hasn't landed yet from this thread's
                        // perspective).
                        if sess.slot.try_lock().is_err() {
                            continue;
                        }
                        if let Some(sess) = self.remove(id) {
                            self.retire(id, &sess, Gone::Evicted);
                            self.metrics.sessions_evicted.fetch_add(1, Ordering::Relaxed);
                            evicted_any = true;
                        }
                    }
                }
                if !evicted_any {
                    break; // only the just-touched session remains (or raced away)
                }
            }
        }
        self.publish_gauges();
    }

    /// One sweeper pass: flush the feed-delta log (fsync batching — this
    /// is what makes WAL appends write-behind), then expire sessions idle
    /// for longer than `cfg.ttl`. With a spill store, "expire" means
    /// spill: the state survives, cold.
    fn sweep(&self) {
        self.flush_wal();
        let Some(ttl) = self.cfg.ttl else { return };
        // Clamp: a sub-millisecond TTL must not truncate to 0, which would
        // make every session (idle time >= 0) expire on each pass.
        let ttl_ms = (ttl.as_millis() as u64).max(1);
        let now = self.now_ms();
        let mut expired: Vec<(u64, Arc<Session>)> = vec![];
        for shard in &self.shards {
            let guard = shard.lock().unwrap();
            for (&id, s) in guard.iter() {
                if s.state.load(Ordering::Relaxed) == STATE_RESIDENT
                    && now.saturating_sub(s.last_used_ms.load(Ordering::Relaxed)) >= ttl_ms
                {
                    expired.push((id, Arc::clone(s)));
                }
            }
        }
        if expired.is_empty() {
            return;
        }
        for (id, sess) in &expired {
            if self.store.is_some() {
                self.spill(*id, sess);
            } else if sess.slot.try_lock().is_ok() {
                if let Some(sess) = self.remove(*id) {
                    self.retire(*id, &sess, Gone::Evicted);
                    self.metrics.sessions_expired.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.publish_gauges();
    }
}

/// Concurrent, memory-bounded session table (see the module docs).
pub struct SessionManager {
    next_id: AtomicU64,
    inner: Arc<Inner>,
    sweeper: Option<std::thread::JoinHandle<()>>,
}

impl SessionManager {
    /// Unbounded manager with default sharding (no budget, no TTL, no
    /// persistence — nothing that can fail to construct).
    pub fn new(metrics: Arc<Metrics>) -> SessionManager {
        SessionManager::with_config(metrics, SessionConfig::default())
            .expect("default session config has no persistence to fail")
    }

    /// Build a manager; with [`SpillConfig::Disk`] this replays the
    /// feed-delta log first, recovering every session that was open when
    /// the previous process exited — bitwise, since `Path` extension is
    /// exactly resumable. Construction fails only on persistence errors
    /// (unreadable state dir, malformed log record).
    pub fn with_config(metrics: Arc<Metrics>, cfg: SessionConfig) -> anyhow::Result<SessionManager> {
        let store = cfg.spill.build_store()?;
        let wal_path = cfg.spill.wal_path();
        // Warm-restart recovery: replay the log into fresh Paths. Feeds
        // for closed/unknown ids are skipped; closes leave tombstones so
        // the error taxonomy survives restarts too.
        let mut recovered: HashMap<u64, ResidentPath> = HashMap::new();
        let mut closed_ids: Vec<u64> = vec![];
        let mut max_seen: u64 = 0;
        if let Some(wp) = &wal_path {
            for rec in FeedLog::replay(wp)? {
                match rec {
                    WalRecord::Open { id, d, depth, count, points } => {
                        max_seen = max_seen.max(id);
                        // The log frames rows at their native width; the
                        // recovered spec's dtype comes straight from the
                        // record's row precision.
                        let spec = SigSpec::with_dtype(
                            d as usize,
                            depth as usize,
                            points.precision(),
                        )?;
                        recovered.insert(id, ResidentPath::new(&spec, &points, count as usize)?);
                    }
                    WalRecord::OpenWindow { id, d, depth, count, points, window } => {
                        max_seen = max_seen.max(id);
                        let spec = SigSpec::with_dtype(
                            d as usize,
                            depth as usize,
                            points.precision(),
                        )?;
                        recovered.insert(
                            id,
                            ResidentPath::new_with_window(
                                &spec,
                                &points,
                                count as usize,
                                Some(window),
                            )?,
                        );
                    }
                    WalRecord::Feed { id, count, points } => {
                        // `update` re-advances any rolling window, so the
                        // recovered pending buffer matches what the
                        // pre-crash process had emitted.
                        if let Some(p) = recovered.get_mut(&id) {
                            p.update(&points, count as usize)?;
                        }
                    }
                    WalRecord::Poll { id, upto } => {
                        // Drop rows the pre-crash client already received;
                        // what remains pending is exactly the undelivered
                        // suffix.
                        if let Some(p) = recovered.get_mut(&id) {
                            p.mark_delivered(upto);
                        }
                    }
                    WalRecord::Close { id } => {
                        max_seen = max_seen.max(id);
                        recovered.remove(&id);
                        closed_ids.push(id);
                    }
                }
            }
            // Spill blobs are snapshots the log fully supersedes (every
            // feed is logged); clear stale ones from the previous run.
            if let Some(store) = &store {
                store.clear()?;
            }
        }
        let wal = match &wal_path {
            Some(wp) => Some(FeedLog::open(wp)?),
            None => None,
        };
        let first = cfg.first_id.max(1);
        let stride = cfg.id_stride.max(1);
        // Next id: past everything the log ever issued, on this shard's
        // stride lattice.
        let next_id = if max_seen < first {
            first
        } else {
            first + ((max_seen - first) / stride + 1) * stride
        };
        let shards = cfg.shards.max(1);
        let spawn_sweeper = cfg.ttl.is_some() || wal.is_some();
        let inner = Arc::new(Inner {
            cfg,
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            tombstones: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            metrics,
            store,
            wal,
            epoch: Instant::now(),
            clock: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            spilled: AtomicUsize::new(0),
            shutdown: Mutex::new(false),
            wake: Condvar::new(),
        });
        for (id, path) in recovered {
            let bytes = path.storage_bytes();
            let sess = Arc::new(Session {
                slot: Mutex::new(Slot::Resident(path)),
                state: AtomicU8::new(STATE_RESIDENT),
                bytes: AtomicUsize::new(bytes),
                touch: AtomicU64::new(0),
                last_used_ms: AtomicU64::new(0),
            });
            inner.touch(&sess);
            inner.resident.fetch_add(bytes, Ordering::Relaxed);
            inner.metrics.open_sessions.fetch_add(1, Ordering::Relaxed);
            inner.shard(id).lock().unwrap().insert(id, sess);
        }
        for id in closed_ids {
            inner.tombstone_shard(id).lock().unwrap().insert(id, Gone::Closed);
        }
        // The recovered set may already exceed the budget: spill back down.
        inner.enforce_budget(&[]);
        let sweeper = if spawn_sweeper {
            let inner = Arc::clone(&inner);
            Some(
                std::thread::Builder::new()
                    .name("signax-session-sweeper".into())
                    .spawn(move || loop {
                        let guard = inner.shutdown.lock().unwrap();
                        if *guard {
                            return;
                        }
                        let (guard, _) =
                            inner.wake.wait_timeout(guard, inner.cfg.sweep_interval).unwrap();
                        if *guard {
                            return;
                        }
                        drop(guard);
                        inner.sweep();
                    })
                    .expect("spawn session sweeper"),
            )
        } else {
            None
        };
        Ok(SessionManager { next_id: AtomicU64::new(next_id), inner, sweeper })
    }

    /// Open a session seeded with an initial path (>= 2 points). The rows'
    /// precision must match the spec's dtype; the session serves at that
    /// width for its whole life.
    pub fn open(&self, spec: &SigSpec, points: &Rows, stream: usize) -> anyhow::Result<SessionId> {
        self.open_with_signature(spec, points, stream).map(|(id, _)| id)
    }

    /// Open a session and also return the signature of the seed path.
    /// The signature is computed *before* the session becomes visible (and
    /// thus evictable), so a racing eviction under budget pressure cannot
    /// turn a successful open into an error.
    pub fn open_with_signature(
        &self,
        spec: &SigSpec,
        points: &Rows,
        stream: usize,
    ) -> anyhow::Result<(SessionId, Rows)> {
        let path = ResidentPath::new(spec, points, stream)?;
        self.install(path, |id| WalRecord::Open {
            id,
            d: spec.d() as u32,
            depth: spec.depth() as u32,
            count: stream as u32,
            points: points.clone(),
        })
    }

    /// Open a **rolling-window session**: the server keeps `window`'s
    /// sliding signatures (or logsignatures, per
    /// [`WindowSpec::logsig`]) up to date as points arrive — one O(1)
    /// `I_i ⊠ S_j` per slide — and retains only O(window) points per
    /// session, however long the stream runs. Windows already complete
    /// in the seed path are emitted immediately. Emitted slides buffer
    /// until [`SessionManager::poll_window`] drains them. Returns the
    /// seed path's whole-stream signature, like
    /// [`SessionManager::open_with_signature`].
    pub fn open_window(
        &self,
        spec: &SigSpec,
        points: &Rows,
        stream: usize,
        window: WindowSpec,
    ) -> anyhow::Result<(SessionId, Rows)> {
        let path = ResidentPath::new_with_window(spec, points, stream, Some(window))?;
        self.install(path, |id| WalRecord::OpenWindow {
            id,
            d: spec.d() as u32,
            depth: spec.depth() as u32,
            count: stream as u32,
            points: points.clone(),
            window,
        })
    }

    /// Shared tail of the open paths: issue an id, log the open record,
    /// and publish the session.
    fn install(
        &self,
        path: ResidentPath,
        record: impl FnOnce(u64) -> WalRecord,
    ) -> anyhow::Result<(SessionId, Rows)> {
        let bytes = path.storage_bytes();
        let sig = path.signature();
        let stride = self.inner.cfg.id_stride.max(1);
        let id = SessionId(self.next_id.fetch_add(stride, Ordering::Relaxed));
        // Log before the session becomes visible: no feed for this id can
        // be accepted (let alone logged) until open returns it.
        self.inner.log_wal(&record(id.0));
        let sess = Arc::new(Session {
            slot: Mutex::new(Slot::Resident(path)),
            state: AtomicU8::new(STATE_RESIDENT),
            bytes: AtomicUsize::new(bytes),
            touch: AtomicU64::new(0),
            last_used_ms: AtomicU64::new(0),
        });
        self.inner.touch(&sess);
        self.inner.resident.fetch_add(bytes, Ordering::Relaxed);
        // Gauges before the insert: once the session is in the map a racing
        // eviction may retire it (fetch_sub) immediately, so incrementing
        // afterwards could transiently underflow the gauge.
        self.inner.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.open_sessions.fetch_add(1, Ordering::Relaxed);
        self.inner.shard(id.0).lock().unwrap().insert(id.0, sess);
        self.inner.enforce_budget(&[id.0]);
        Ok((id, sig))
    }

    /// Feed new points (rows at the session's native precision); returns
    /// the signature over the whole stream so far, typed likewise.
    pub fn feed(&self, id: SessionId, points: &Rows, count: usize) -> anyhow::Result<Rows> {
        let sess = self.inner.get(id)?;
        // Touch at start as well as completion: a long-running update must
        // not look idle to LRU/TTL eviction while it is in flight.
        self.inner.touch(&sess);
        // `with_resident` transparently reloads a spilled session — a feed
        // that raced an eviction proceeds instead of erroring.
        let (sig, _) = self.inner.with_resident(id, &sess, |path| {
            path.update(points, count)?;
            // `update` grew the path, but a rolling window may have both
            // buffered new slides and truncated the dead prefix — so the
            // net storage delta can have either sign.
            self.inner.account_bytes(&sess, path.storage_bytes());
            self.inner.log_wal(&WalRecord::Feed {
                id: id.0,
                count: count as u32,
                points: points.clone(),
            });
            Ok(path.signature())
        })?;
        self.inner.touch(&sess);
        self.inner.metrics.session_updates.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed);
        self.inner.enforce_budget(&[id.0]);
        Ok(sig)
    }

    /// Drain a rolling-window session's undelivered slides: `(first,
    /// rows)`, where row `r` is slide `first + r` (covering points
    /// `[(first + r) * stride, (first + r) * stride + len - 1]`). Empty
    /// rows, with `first` naming the next future slide, when nothing is
    /// pending. The drain is WAL-logged, so a warm restart re-delivers
    /// exactly the rows no poll returned. Errors for sessions opened
    /// without a window.
    pub fn poll_window(&self, id: SessionId) -> anyhow::Result<(u64, Rows)> {
        let (first, rows, _) = self.poll_window_page(id, None)?;
        Ok((first, rows))
    }

    /// [`SessionManager::poll_window`] with a page cap: at most
    /// `max_slides` slides come back (`None` = the whole backlog), and the
    /// third element counts the slides **still pending** after this page
    /// (0 = drained) — a slow poller re-issues with the continuation
    /// cursor `first + rows / out_dim` implied until it reads 0. The WAL
    /// record logs exactly the delivered-up-to cursor, so paged drains
    /// replay precisely like full ones: a warm restart re-delivers
    /// exactly the suffix no page returned.
    pub fn poll_window_page(
        &self,
        id: SessionId,
        max_slides: Option<u64>,
    ) -> anyhow::Result<(u64, Rows, u64)> {
        let sess = self.inner.get(id)?;
        self.inner.touch(&sess);
        let ((first, upto, rows, left), reloaded) =
            self.inner.with_resident(id, &sess, |path| {
                let (first, upto, rows, left) = path.poll(max_slides)?;
                // The drained rows leave the pending buffer: accounted
                // storage shrinks. Log under the slot lock (apply order),
                // and only when something was actually delivered.
                self.inner.account_bytes(&sess, path.storage_bytes());
                if upto > first {
                    self.inner.log_wal(&WalRecord::Poll { id: id.0, upto });
                }
                Ok((first, upto, rows, left))
            })?;
        self.inner.touch(&sess);
        self.inner.metrics.window_polls.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.window_slides.fetch_add(upto - first, Ordering::Relaxed);
        if reloaded {
            self.inner.enforce_budget(&[id.0]);
        }
        Ok((first, rows, left))
    }

    /// Feed several sessions in one call, lane-fusing same-spec groups —
    /// the stateful analogue of the router's signature microbatch, backed
    /// by [`Path::update_batch`]. Returns one result per feed, in order;
    /// each is the whole-stream signature so far, **bitwise identical**
    /// to what a scalar [`SessionManager::feed`] of the same points would
    /// have returned (lanes replay the scalar op order). Failures are
    /// per-feed: an unknown/evicted session or malformed buffer errors
    /// its own entry while the rest of the group proceeds.
    ///
    /// A session appearing more than once is served its feeds in order
    /// (occurrence k runs in wave k), so coalescing cannot reorder one
    /// stream's points. Path locks are taken in ascending session-id
    /// order, so two overlapping batch feeds cannot deadlock.
    pub fn feed_batch(
        &self,
        feeds: Vec<(SessionId, Rows, usize)>,
    ) -> Vec<anyhow::Result<Rows>> {
        let n = feeds.len();
        let mut results: Vec<Option<anyhow::Result<Rows>>> = (0..n).map(|_| None).collect();
        // Wave-partition duplicates: occurrence k of a session id lands in
        // wave k, and waves run sequentially.
        let mut waves: Vec<Vec<usize>> = vec![];
        for idx in 0..n {
            let sid = feeds[idx].0;
            match waves.iter_mut().find(|w| w.iter().all(|&j| feeds[j].0 != sid)) {
                Some(w) => w.push(idx),
                None => waves.push(vec![idx]),
            }
        }
        for wave in &waves {
            self.feed_wave(&feeds, wave, &mut results);
        }
        let touched: Vec<u64> = feeds.iter().map(|f| f.0 .0).collect();
        self.inner.enforce_budget(&touched);
        results.into_iter().map(|r| r.expect("every feed resolved")).collect()
    }

    /// One wave of [`SessionManager::feed_batch`]: at most one feed per
    /// session.
    fn feed_wave(
        &self,
        feeds: &[(SessionId, Rows, usize)],
        wave: &[usize],
        results: &mut [Option<anyhow::Result<Rows>>],
    ) {
        // Resolve sessions; unknown ids error individually.
        let mut resolved: Vec<(usize, Arc<Session>)> = vec![];
        for &idx in wave {
            match self.inner.get(feeds[idx].0) {
                Ok(sess) => {
                    // Touch at start as well as completion, like a scalar
                    // feed: in-flight work must not look idle to LRU/TTL.
                    self.inner.touch(&sess);
                    resolved.push((idx, sess));
                }
                Err(e) => results[idx] = Some(Err(e)),
            }
        }
        // Lock slots in ascending session-id order: concurrent batch
        // feeds over overlapping session sets then acquire in the same
        // global order and cannot deadlock. Spilled lanes reload here,
        // under their own slot lock, exactly like a scalar feed.
        resolved.sort_by_key(|(idx, _)| feeds[*idx].0 .0);
        let mut locked: Vec<(usize, MutexGuard<'_, Slot>)> = vec![];
        for (idx, sess) in &resolved {
            let mut guard = sess.slot.lock().unwrap();
            if let Err(e) = self.inner.ensure_resident(feeds[*idx].0, sess, &mut guard) {
                results[*idx] = Some(Err(e));
                continue;
            }
            // Per-lane validation up front, so one malformed feed errors
            // alone instead of failing its whole lane group.
            let (_, points, count) = &feeds[*idx];
            let (d, dtype) = {
                let s = slot_spec(&guard);
                (s.d(), s.dtype())
            };
            if *count < 1 {
                results[*idx] = Some(Err(anyhow::anyhow!("no points to add")));
                continue;
            }
            if points.len() != count * d {
                results[*idx] = Some(Err(anyhow::anyhow!(
                    "feed buffer has {} values, expected count({count}) * channels({d})",
                    points.len()
                )));
                continue;
            }
            if points.precision() != dtype {
                results[*idx] = Some(Err(anyhow::anyhow!(
                    "feed rows are {} but session {:?} serves {}",
                    points.precision().label(),
                    feeds[*idx].0,
                    dtype.label()
                )));
                continue;
            }
            locked.push((*idx, guard));
        }
        // Group same-spec lanes into contiguous runs (the feed lane keys
        // submissions by `(d, depth, dtype)`, so this is normally one run;
        // a mixed batch still lane-fuses per spec, and never across
        // element precisions — every run is dtype-homogeneous).
        locked.sort_by_key(|(_, g)| {
            let s = slot_spec(g);
            (s.d(), s.depth(), s.dtype() == Precision::F64)
        });
        let mut start = 0usize;
        while start < locked.len() {
            let key = {
                let s = slot_spec(&locked[start].1);
                (s.d(), s.depth(), s.dtype())
            };
            let mut end = start + 1;
            while end < locked.len() {
                let s = slot_spec(&locked[end].1);
                if (s.d(), s.depth(), s.dtype()) != key {
                    break;
                }
                end += 1;
            }
            let run = &mut locked[start..end];
            let idxs: Vec<usize> = run.iter().map(|(idx, _)| *idx).collect();
            // One generic sweep, dispatched on the run's dtype exactly
            // once: the run is homogeneous, so `TypedPath::path_mut`
            // recovers the monomorphic lanes without a cast.
            fn update_run<E: TypedPath>(
                run: &mut [(usize, MutexGuard<'_, Slot>)],
                feeds: &[(SessionId, Rows, usize)],
                idxs: &[usize],
            ) -> anyhow::Result<()> {
                let mut paths: Vec<&mut Path<E>> = run
                    .iter_mut()
                    .map(|(_, g)| E::path_mut(resident_path(&mut **g)))
                    .collect();
                let slices: Vec<&[E]> = idxs
                    .iter()
                    .map(|&i| {
                        E::rows_as_slice(&feeds[i].1).expect("lane precision validated per feed")
                    })
                    .collect();
                let counts: Vec<usize> = idxs.iter().map(|&i| feeds[i].2).collect();
                Path::update_batch(&mut paths, &slices, &counts)
            }
            let outcome = match key.2 {
                Precision::F32 => update_run::<f32>(run, feeds, &idxs),
                Precision::F64 => update_run::<f64>(run, feeds, &idxs),
            };
            match outcome {
                Ok(()) => {
                    if idxs.len() >= 2 {
                        self.inner.metrics.feed_lane_batches.fetch_add(1, Ordering::Relaxed);
                        self.inner.metrics.dispatch_lane_fused.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.inner.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed);
                    }
                    // `update_batch` extended the lanes but knows nothing
                    // of windows; advance the run's windowed sessions now,
                    // in one planner-gated sweep. Two or more windowed
                    // lanes (necessarily one `(d, depth, dtype)` — the run
                    // is homogeneous, so f32/f64 never coalesce) advance
                    // through `RollingWindow::advance_batch`'s lane-fused
                    // Chen kernels; below that the scalar per-session
                    // advance runs. Either way each session emits exactly
                    // what a scalar feed of the same points would
                    // (bitwise — the batched kernels replay the scalar op
                    // order per lane).
                    fn advance_run<E: TypedPath>(
                        run: &mut [(usize, MutexGuard<'_, Slot>)],
                        key: (usize, usize, Precision),
                    ) -> anyhow::Result<(bool, usize)> {
                        let mut wpaths: Vec<&mut Path<E>> = Vec::new();
                        let mut wins: Vec<&mut RollingWindow<E>> = Vec::new();
                        for (_, g) in run.iter_mut() {
                            let (p, w) = E::lanes_mut(resident_path(&mut **g));
                            if let Some(w) = w {
                                wpaths.push(p);
                                wins.push(w);
                            }
                        }
                        let shape = WorkShape {
                            batch: wpaths.len(),
                            points: 0,
                            d: key.0,
                            depth: key.1,
                            dtype: key.2,
                        };
                        match ExecPlanner::new(1).plan_window_sweep(wpaths.len(), &shape) {
                            ExecPlan::Scalar => {
                                let mut slides = 0usize;
                                for (p, w) in wpaths.iter_mut().zip(wins.iter_mut()) {
                                    slides += w.advance(&mut **p)?;
                                }
                                Ok((false, slides))
                            }
                            _ => Ok((true, RollingWindow::advance_batch(&mut wpaths, &mut wins)?)),
                        }
                    }
                    let swept = match key.2 {
                        Precision::F32 => advance_run::<f32>(run, key),
                        Precision::F64 => advance_run::<f64>(run, key),
                    };
                    if let Ok((true, slides)) = &swept {
                        self.inner.metrics.window_slide_batches.fetch_add(1, Ordering::Relaxed);
                        self.inner
                            .metrics
                            .window_slides_batched
                            .fetch_add(*slides as u64, Ordering::Relaxed);
                    }
                    for (idx, guard) in run.iter_mut() {
                        // Accounting under this slot's lock, exactly like
                        // a scalar feed.
                        let (_, sess) = resolved
                            .iter()
                            .find(|(ri, _)| *ri == *idx)
                            .expect("locked lane was resolved");
                        let path = resident_path(&mut **guard);
                        self.inner.account_bytes(sess, path.storage_bytes());
                        self.inner.metrics.session_updates.fetch_add(1, Ordering::Relaxed);
                        // Log while the slot lock is held, like a scalar
                        // feed, so WAL order matches apply order per id.
                        let (sid, points, count) = &feeds[*idx];
                        self.inner.log_wal(&WalRecord::Feed {
                            id: sid.0,
                            count: *count as u32,
                            points: points.clone(),
                        });
                        results[*idx] = Some(match &swept {
                            Ok(_) => Ok(path.signature()),
                            // A window invariant violation is collective
                            // (the sweep is all-or-nothing), so it fails
                            // the whole run — like an `update_batch`
                            // failure, and just as unreachable in
                            // practice.
                            Err(e) => Err(anyhow::anyhow!("window advance failed: {e}")),
                        });
                    }
                }
                Err(e) => {
                    for &idx in &idxs {
                        results[idx] = Some(Err(anyhow::anyhow!("lane-fused feed failed: {e}")));
                    }
                }
            }
            start = end;
        }
        drop(locked);
        // Completion touches (LRU order reflects the work just done).
        for (_, sess) in &resolved {
            self.inner.touch(sess);
        }
    }

    /// O(1) interval query against a session's stream (reloading the
    /// session transparently if it was spilled). Typed at the session's
    /// native precision.
    pub fn query(&self, id: SessionId, i: usize, j: usize) -> anyhow::Result<Rows> {
        let sess = self.inner.get(id)?;
        let (out, reloaded) = self.inner.with_resident(id, &sess, |path| path.query(i, j))?;
        self.inner.touch(&sess);
        if reloaded {
            self.inner.enforce_budget(&[id.0]);
        }
        Ok(out)
    }

    /// Logsignature interval query.
    pub fn logsig_query(
        &self,
        id: SessionId,
        i: usize,
        j: usize,
        plan: &LogSigPlan,
    ) -> anyhow::Result<Rows> {
        let sess = self.inner.get(id)?;
        let (out, reloaded) =
            self.inner.with_resident(id, &sess, |path| path.logsig_query(i, j, plan))?;
        self.inner.touch(&sess);
        if reloaded {
            self.inner.enforce_budget(&[id.0]);
        }
        Ok(out)
    }

    /// Logsignature interval query resolving the session only once:
    /// `plan_for` receives the session's spec and returns the (typically
    /// cached) plan — this is the coordinator's hot path, which keys its
    /// plan cache by the session's `(d, depth)`.
    pub fn logsig_query_with<F>(
        &self,
        id: SessionId,
        i: usize,
        j: usize,
        plan_for: F,
    ) -> anyhow::Result<Rows>
    where
        F: FnOnce(&SigSpec) -> anyhow::Result<Arc<LogSigPlan>>,
    {
        let sess = self.inner.get(id)?;
        // Only the O(1) interval query runs under the slot lock; plan
        // resolution (which may take the coordinator's global plan-cache
        // mutex, or build a plan) and the log projection run outside it,
        // so concurrent queries/feeds never serialize on either lock.
        let ((sig, spec), reloaded) = self
            .inner
            .with_resident(id, &sess, |path| Ok((path.query(i, j)?, path.spec().clone())))?;
        self.inner.touch(&sess);
        if reloaded {
            self.inner.enforce_budget(&[id.0]);
        }
        let plan = plan_for(&spec)?;
        // The log + basis projection runs at the signature's own width.
        match &sig {
            Rows::F32(s) => {
                Ok(crate::logsignature::logsignature_from_sig(s, &spec, plan.as_ref())?.into())
            }
            Rows::F64(s) => {
                Ok(crate::logsignature::logsignature_from_sig(s, &spec, plan.as_ref())?.into())
            }
        }
    }

    /// The signature of a session's whole stream so far, typed at the
    /// session's native precision.
    pub fn signature(&self, id: SessionId) -> anyhow::Result<Rows> {
        let sess = self.inner.get(id)?;
        let (out, reloaded) =
            self.inner.with_resident(id, &sess, |path| Ok(path.signature()))?;
        self.inner.touch(&sess);
        if reloaded {
            self.inner.enforce_budget(&[id.0]);
        }
        Ok(out)
    }

    /// Number of points a session currently holds. Served from cold
    /// metadata for spilled sessions — no reload.
    pub fn session_len(&self, id: SessionId) -> anyhow::Result<usize> {
        let sess = self.inner.get(id)?;
        let slot = sess.slot.lock().unwrap();
        match &*slot {
            Slot::Resident(p) => Ok(p.len()),
            Slot::Spilled { stream, .. } => Ok(*stream),
            Slot::Defunct(g) => Err(self.inner.defunct_error(id, *g)),
        }
    }

    /// The `SigSpec` a session was opened with. Served from cold metadata
    /// for spilled sessions — no reload.
    pub fn session_spec(&self, id: SessionId) -> anyhow::Result<SigSpec> {
        let sess = self.inner.get(id)?;
        let slot = sess.slot.lock().unwrap();
        match &*slot {
            Slot::Resident(p) => Ok(p.spec().clone()),
            Slot::Spilled { spec, .. } => Ok(spec.clone()),
            Slot::Defunct(g) => Err(self.inner.defunct_error(id, *g)),
        }
    }

    /// Close and drop a session (hot or spilled); its spill blob is
    /// removed and a `Close` record logged, so neither reload nor warm
    /// restart can resurrect it.
    pub fn close(&self, id: SessionId) -> anyhow::Result<()> {
        let sess = self.inner.remove(id.0).ok_or_else(|| self.inner.gone_error(id))?;
        self.inner.retire(id.0, &sess, Gone::Closed);
        self.inner.log_wal(&WalRecord::Close { id: id.0 });
        self.inner.publish_gauges();
        Ok(())
    }

    /// Sessions currently in the table — resident *or* spilled (a spilled
    /// session is still open; it just lives cold).
    pub fn open_count(&self) -> usize {
        self.inner.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Bytes of precomputed storage currently resident across sessions.
    pub fn resident_bytes(&self) -> usize {
        self.inner.resident.load(Ordering::Relaxed)
    }

    /// Bytes currently spilled to the session store.
    pub fn spilled_bytes(&self) -> usize {
        self.inner.spilled.load(Ordering::Relaxed)
    }

    /// Flush the feed-delta log now (tests and orderly shutdown; the
    /// sweeper does this on its own cadence).
    pub fn flush_wal(&self) {
        self.inner.flush_wal();
    }
}

impl Drop for SessionManager {
    fn drop(&mut self) {
        *self.inner.shutdown.lock().unwrap() = true;
        self.inner.wake.notify_all();
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
        // Orderly shutdown drains the write-behind buffer (the FeedLog's
        // own Drop also flushes, as a backstop once the Arc unwinds).
        self.inner.flush_wal();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signature::signature;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    fn mgr() -> SessionManager {
        SessionManager::new(Arc::new(Metrics::default()))
    }

    /// Storage bytes of a fresh session of `stream` points (for sizing
    /// budgets deterministically in tests) — measured on a throwaway
    /// `Path` so the tests stay agnostic to its storage layout.
    fn session_bytes(spec: &SigSpec, stream: usize) -> usize {
        Path::new(spec, &vec![0.0f32; stream * spec.d()], stream).unwrap().storage_bytes()
    }

    #[test]
    fn feed_matches_whole_path_signature() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(1);
        let all = rng.normal_vec(12 * 2, 0.4);
        let id = m.open(&spec, &all[..4 * 2].to_vec().into(), 4).unwrap();
        let sig1 = m.feed(id, &all[4 * 2..8 * 2].to_vec().into(), 4).unwrap();
        assert_close(sig1.as_f32().unwrap(), &signature(&all[..8 * 2], 8, &spec), 2e-3, 1e-4);
        let sig2 = m.feed(id, &all[8 * 2..].to_vec().into(), 4).unwrap();
        assert_close(sig2.as_f32().unwrap(), &signature(&all, 12, &spec), 2e-3, 1e-4);
        assert_eq!(m.session_len(id).unwrap(), 12);
        assert_eq!(m.session_spec(id).unwrap(), spec);
    }

    #[test]
    fn queries_span_fed_chunks() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(2);
        let all = rng.normal_vec(10 * 2, 0.4);
        let id = m.open(&spec, &all[..5 * 2].to_vec().into(), 5).unwrap();
        m.feed(id, &all[5 * 2..].to_vec().into(), 5).unwrap();
        // Interval crossing the update boundary.
        let q = m.query(id, 3, 8).unwrap();
        assert_close(q.as_f32().unwrap(), &signature(&all[3 * 2..9 * 2], 6, &spec), 5e-3, 5e-4);
        // Whole-stream signature accessor agrees with recomputation.
        let whole = m.signature(id).unwrap();
        assert_close(whole.as_f32().unwrap(), &signature(&all, 10, &spec), 2e-3, 1e-4);
        // Logsig interval query (direct-plan and resolve-once variants).
        let plan =
            crate::logsignature::LogSigPlan::new(&spec, crate::logsignature::LogSigBasis::Words)
                .unwrap();
        let lq = m.logsig_query(id, 3, 8, &plan).unwrap();
        assert_eq!(lq.len(), crate::words::witt_dimension(2, 3));
        let lq2 = m
            .logsig_query_with(id, 3, 8, |spec| {
                Ok(Arc::new(crate::logsignature::LogSigPlan::new(
                    spec,
                    crate::logsignature::LogSigBasis::Words,
                )?))
            })
            .unwrap();
        assert_eq!(lq, lq2);
    }

    #[test]
    fn feed_batch_matches_scalar_feeds_bitwise() {
        use crate::substrate::propcheck::property;
        // Serving contract: coalescing same-spec feeds into one lane-fused
        // sweep must not change any session's bits — returned signatures,
        // later queries, and the resident-byte accounting all match a
        // manager fed scalar, feed for feed (ragged counts included).
        property("feed_batch == scalar feeds bitwise", 8, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let lanes = g.usize_in(2, 5);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let spec = SigSpec::new(d, n).unwrap();
            let fused = mgr();
            let scalar = mgr();
            let mut ids = vec![];
            for _ in 0..lanes {
                let seed_len = g.usize_in(2, 6);
                let pts: Rows = g.normal_vec(seed_len * d, 0.3).into();
                let fid = fused.open(&spec, &pts, seed_len).unwrap();
                let sid = scalar.open(&spec, &pts, seed_len).unwrap();
                ids.push((fid, sid));
            }
            for _ in 0..3 {
                let feeds: Vec<(SessionId, Rows, usize)> = ids
                    .iter()
                    .map(|&(fid, _)| {
                        let count = g.usize_in(1, 6);
                        (fid, g.normal_vec(count * d, 0.3).into(), count)
                    })
                    .collect();
                let got = fused.feed_batch(feeds.clone());
                for (k, ((_, sid), (_, pts, count))) in ids.iter().zip(&feeds).enumerate() {
                    let want = scalar.feed(*sid, pts, *count).unwrap();
                    assert_eq!(
                        got[k].as_ref().unwrap(),
                        &want,
                        "lane {k} signature diverged from scalar feed"
                    );
                }
            }
            for &(fid, sid) in &ids {
                let len = fused.session_len(fid).unwrap();
                assert_eq!(len, scalar.session_len(sid).unwrap());
                assert_eq!(
                    fused.query(fid, 1, len - 1).unwrap(),
                    scalar.query(sid, 1, len - 1).unwrap(),
                    "post-feed interval query diverged"
                );
            }
            assert_eq!(fused.resident_bytes(), scalar.resident_bytes());
        });
    }

    #[test]
    fn feed_batch_isolates_errors_and_orders_duplicates() {
        let spec = SigSpec::new(2, 3).unwrap();
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(Arc::clone(&metrics), SessionConfig::default()).unwrap();
        let twin = mgr();
        let mut rng = Rng::new(31);
        let seed: Rows = rng.normal_vec(4 * 2, 0.3).into();
        let a = m.open(&spec, &seed, 4).unwrap();
        let b = m.open(&spec, &seed, 4).unwrap();
        let ta = twin.open(&spec, &seed, 4).unwrap();
        let chunk1: Rows = rng.normal_vec(3 * 2, 0.3).into();
        let chunk2: Rows = rng.normal_vec(2 * 2, 0.3).into();
        let good_b: Rows = rng.normal_vec(2 * 2, 0.3).into();
        // One batch: a fed twice (must apply in order), b with a malformed
        // buffer, plus an unknown session — failures stay individual.
        let results = m.feed_batch(vec![
            (a, chunk1.clone(), 3),
            (b, vec![0.0f32; 3].into(), 2), // wrong buffer length
            (a, chunk2.clone(), 2),
            (SessionId(9999), good_b.clone(), 2), // unknown
        ]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        assert!(results[3].is_err());
        // a saw chunk1 then chunk2, exactly like two scalar feeds.
        twin.feed(ta, &chunk1, 3).unwrap();
        let want = twin.feed(ta, &chunk2, 2).unwrap();
        assert_eq!(results[2].as_ref().unwrap(), &want);
        assert_eq!(m.session_len(a).unwrap(), 9);
        // b is untouched by its failed feed.
        assert_eq!(m.session_len(b).unwrap(), 4);
        // The failed lanes never corrupt accounting: b can still be fed.
        assert!(m.feed(b, &good_b, 2).is_ok());
        let snap = metrics.snapshot();
        assert_eq!(snap.session_updates, 3, "two batched feeds on a + one scalar on b");
    }

    #[test]
    fn feed_batch_closed_lane_errors_while_group_proceeds() {
        // The mid-feed eviction story: a session leaving the table between
        // submission and flush errors its own lane; the survivors' sweep
        // still runs and stays bitwise-scalar.
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let twin = mgr();
        let mut rng = Rng::new(32);
        let seed: Rows = rng.normal_vec(4 * 2, 0.3).into();
        let alive = m.open(&spec, &seed, 4).unwrap();
        let dead = m.open(&spec, &seed, 4).unwrap();
        let talive = twin.open(&spec, &seed, 4).unwrap();
        m.close(dead).unwrap();
        let chunk: Rows = rng.normal_vec(3 * 2, 0.3).into();
        let results =
            m.feed_batch(vec![(alive, chunk.clone(), 3), (dead, chunk.clone(), 3)]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        let want = twin.feed(talive, &chunk, 3).unwrap();
        assert_eq!(results[0].as_ref().unwrap(), &want);
    }

    #[test]
    fn feed_batch_counts_feed_lane_metrics() {
        let spec = SigSpec::new(2, 3).unwrap();
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(Arc::clone(&metrics), SessionConfig::default()).unwrap();
        let mut rng = Rng::new(33);
        let ids: Vec<SessionId> = (0..3)
            .map(|_| m.open(&spec, &rng.normal_vec(4 * 2, 0.3).into(), 4).unwrap())
            .collect();
        let feeds: Vec<(SessionId, Rows, usize)> =
            ids.iter().map(|&id| (id, rng.normal_vec(2 * 2, 0.3).into(), 2)).collect();
        for r in m.feed_batch(feeds) {
            r.unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.feed_lane_batches, 1, "three same-spec lanes = one fused sweep");
        assert_eq!(snap.dispatch_lane_fused, 1);
        assert_eq!(snap.session_updates, 3);
        // A single-lane batch is a scalar dispatch, not a lane sweep.
        let solo = m.feed_batch(vec![(ids[0], rng.normal_vec(2 * 2, 0.3).into(), 2)]);
        assert!(solo[0].is_ok());
        let snap = metrics.snapshot();
        assert_eq!(snap.feed_lane_batches, 1);
        assert_eq!(snap.dispatch_scalar, 1);
    }

    #[test]
    fn unknown_and_closed_sessions_error() {
        let spec = SigSpec::new(2, 2).unwrap();
        let m = mgr();
        assert!(m.feed(SessionId(99), &vec![0.0f32; 2].into(), 1).is_err());
        let id = m.open(&spec, &vec![0.0f32, 0.0, 1.0, 1.0].into(), 2).unwrap();
        assert_eq!(m.open_count(), 1);
        m.close(id).unwrap();
        assert_eq!(m.open_count(), 0);
        assert_eq!(m.resident_bytes(), 0);
        assert!(m.query(id, 0, 1).is_err());
        assert!(m.close(id).is_err());
    }

    #[test]
    fn concurrent_sessions_do_not_interfere() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = Arc::new(mgr());
        let mut handles = vec![];
        for t in 0..4u64 {
            let m = Arc::clone(&m);
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let pts = rng.normal_vec(6 * 2, 0.4);
                let id = m.open(&spec, &pts[..2 * 2].to_vec().into(), 2).unwrap();
                let sig = m.feed(id, &pts[2 * 2..].to_vec().into(), 4).unwrap();
                let expect = signature(&pts, 6, &spec);
                for (a, b) in sig.as_f32().unwrap().iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.open_count(), 4);
    }

    #[test]
    fn resident_bytes_tracks_path_storage() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(3);
        let id = m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 4));
        m.feed(id, &rng.normal_vec(6 * 2, 0.2).into(), 6).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 10));
        let id2 = m.open(&spec, &rng.normal_vec(3 * 2, 0.2).into(), 3).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 10) + session_bytes(&spec, 3));
        m.close(id).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 3));
        m.close(id2).unwrap();
        assert_eq!(m.resident_bytes(), 0);
    }

    #[test]
    fn budget_is_enforced_in_lru_order_and_evictees_error() {
        let spec = SigSpec::new(2, 3).unwrap();
        let per = session_bytes(&spec, 4);
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig { budget_bytes: Some(3 * per + per / 2), ..Default::default() },
        )
        .unwrap();
        let mut rng = Rng::new(4);
        let mut ids = vec![];
        for _ in 0..3 {
            ids.push(m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap());
            assert!(m.resident_bytes() <= 3 * per + per / 2);
        }
        assert_eq!(m.open_count(), 3);
        // Touch 0 so 1 becomes the LRU.
        m.query(ids[0], 0, 3).unwrap();
        // A fourth session pushes the total over budget: exactly one
        // eviction, and it must be the least recently used (ids[1]).
        let id3 = m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap();
        assert!(m.resident_bytes() <= 3 * per + per / 2);
        assert_eq!(m.open_count(), 3);
        assert!(m.query(ids[1], 0, 3).is_err(), "LRU session should be evicted");
        assert!(
            m.feed(ids[1], &vec![0.0f32; 2].into(), 1).is_err(),
            "evicted sessions error cleanly"
        );
        for &id in [ids[0], ids[2], id3].iter() {
            assert!(m.query(id, 0, 3).is_ok(), "recently used session evicted");
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.sessions_evicted, 1);
        assert_eq!(snap.open_sessions, 3);
        assert_eq!(snap.session_bytes as usize, m.resident_bytes());
    }

    #[test]
    fn budget_never_exceeded_property() {
        use crate::substrate::propcheck::property;
        property("session budget never exceeded", 8, |g| {
            let spec = SigSpec::new(2, 3).unwrap();
            let per = session_bytes(&spec, 4);
            let cap_sessions = g.usize_in(2, 5);
            let budget = cap_sessions * per + per / 4;
            g.label(format!("budget for ~{cap_sessions} sessions"));
            let m = SessionManager::with_config(
                Arc::new(Metrics::default()),
                SessionConfig { budget_bytes: Some(budget), ..Default::default() },
            )
            .unwrap();
            let mut open: Vec<SessionId> = vec![];
            let mut fed: Vec<bool> = vec![];
            for _ in 0..10 {
                // Feed each session at most once so no single session can
                // outgrow the budget (the just-touched session is exempt
                // from eviction by design).
                let unfed: Vec<usize> =
                    (0..open.len()).filter(|&k| !fed[k]).collect();
                if unfed.is_empty() || g.usize_in(0, 2) > 0 {
                    let pts = g.normal_vec(4 * 2, 0.2);
                    open.push(m.open(&spec, &pts.into(), 4).unwrap());
                    fed.push(false);
                } else {
                    // Feed a random still-known session (may have been
                    // evicted; errors are acceptable, overshoot is not).
                    let k = unfed[g.usize_in(0, unfed.len() - 1)];
                    fed[k] = true;
                    let pts = g.normal_vec(2 * 2, 0.2);
                    let _ = m.feed(open[k], &pts.into(), 2);
                }
                assert!(
                    m.resident_bytes() <= budget,
                    "resident {} exceeds budget {budget}",
                    m.resident_bytes()
                );
            }
        });
    }

    #[test]
    fn ttl_sweeper_expires_idle_sessions_only() {
        let spec = SigSpec::new(2, 2).unwrap();
        let metrics = Arc::new(Metrics::default());
        // TTL is 10x the keep-warm interval: only a full-second scheduler
        // stall between warms could spuriously expire the live session.
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig {
                ttl: Some(Duration::from_millis(1000)),
                sweep_interval: Duration::from_millis(50),
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(5);
        let idle = m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap();
        let live = m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap();
        // Keep `live` warm well inside the TTL while `idle` goes stale
        // (loop spans ~1.4s, past the 1s TTL plus a sweep interval).
        for _ in 0..14 {
            std::thread::sleep(Duration::from_millis(100));
            m.query(live, 0, 3).unwrap();
        }
        assert!(m.query(idle, 0, 3).is_err(), "idle session should have expired");
        assert!(m.query(live, 0, 3).is_ok(), "kept-warm session must survive");
        assert_eq!(m.open_count(), 1);
        assert!(metrics.snapshot().sessions_expired >= 1);
    }

    #[test]
    fn feeds_do_not_serialize_behind_the_table_lock() {
        // Regression for the global-map-lock bug: a long feed to one
        // session must not block a tiny feed to another. The old code held
        // the single table mutex across the whole `Path::update`, so B's
        // latency equalled A's; now B only waits on its own path lock.
        if crate::substrate::pool::default_threads() < 2 {
            eprintln!("skipping: single hardware thread (no true overlap to measure)");
            return;
        }
        let spec = SigSpec::new(4, 4).unwrap();
        let mut rng = Rng::new(6);
        let big: Rows = rng.normal_vec(8192 * 4, 0.1).into();
        let small: Rows = rng.normal_vec(4 * 4, 0.1).into();
        // Best of three attempts: scheduling noise from concurrently
        // running tests can delay the small feed; a table-wide lock fails
        // every attempt (B always waits out A's entire update).
        let mut last = (Duration::ZERO, Duration::ZERO);
        for _ in 0..3 {
            let m = Arc::new(mgr());
            let a = m.open(&spec, &rng.normal_vec(2 * 4, 0.1).into(), 2).unwrap();
            let b = m.open(&spec, &rng.normal_vec(2 * 4, 0.1).into(), 2).unwrap();
            let m2 = Arc::clone(&m);
            let big2 = big.clone();
            let t_a = std::thread::spawn(move || {
                let t0 = Instant::now();
                m2.feed(a, &big2, 8192).unwrap();
                t0.elapsed()
            });
            // Give A's feed time to get going, then time B's small feed.
            std::thread::sleep(Duration::from_millis(20));
            let t0 = Instant::now();
            m.feed(b, &small, 4).unwrap();
            let b_elapsed = t0.elapsed();
            let a_elapsed = t_a.join().unwrap();
            if b_elapsed < a_elapsed / 2 + Duration::from_millis(5) {
                return;
            }
            last = (b_elapsed, a_elapsed);
        }
        panic!(
            "small feed ({:?}) serialized behind big feed ({:?}) on every attempt",
            last.0, last.1
        );
    }

    #[test]
    fn distinct_session_feeds_scale_with_threads() {
        // N threads feeding N distinct sessions must beat the same total
        // work done serially; a table-wide lock would flatline this. On
        // fewer than 4 hardware threads the margin over `cargo test`'s
        // concurrent sibling tests is too thin to assert on — the
        // deterministic feeds_do_not_serialize test covers the lock
        // regression there.
        let hw = crate::substrate::pool::default_threads();
        if hw < 4 {
            eprintln!("skipping: needs >= 4 hardware threads for a stable margin");
            return;
        }
        let threads = 4;
        let spec = SigSpec::new(4, 4).unwrap();
        let feeds = 40usize;
        let feed_points = 256usize;
        let run = |par: bool| -> Duration {
            let m = SessionManager::new(Arc::new(Metrics::default()));
            let mut rng = Rng::new(7);
            let ids: Vec<SessionId> = (0..threads)
                .map(|_| m.open(&spec, &rng.normal_vec(2 * 4, 0.1).into(), 2).unwrap())
                .collect();
            let chunks: Vec<Rows> =
                (0..threads).map(|_| rng.normal_vec(feed_points * 4, 0.1).into()).collect();
            let t0 = Instant::now();
            if par {
                std::thread::scope(|scope| {
                    for (id, pts) in ids.iter().zip(&chunks) {
                        let m = &m;
                        scope.spawn(move || {
                            for _ in 0..feeds {
                                m.feed(*id, pts, feed_points).unwrap();
                            }
                        });
                    }
                });
            } else {
                for (id, pts) in ids.iter().zip(&chunks) {
                    for _ in 0..feeds {
                        m.feed(*id, pts, feed_points).unwrap();
                    }
                }
            }
            t0.elapsed()
        };
        // Best of three attempts: `cargo test` runs other tests
        // concurrently, so a single measurement can be squeezed by
        // unrelated load. A table-wide lock can never reach the threshold
        // regardless of retries; genuine parallelism reaches it easily.
        let mut best_ratio = f64::INFINITY;
        for _ in 0..3 {
            let serial = run(false);
            let parallel = run(true);
            let ratio = parallel.as_secs_f64() / serial.as_secs_f64();
            best_ratio = best_ratio.min(ratio);
            if best_ratio < 0.9 {
                return;
            }
        }
        panic!(
            "distinct-session feeds did not scale on {threads} threads: \
             best parallel/serial ratio {best_ratio:.2} (need < 0.9)"
        );
    }

    fn mgr_with(cfg: SessionConfig) -> SessionManager {
        SessionManager::with_config(Arc::new(Metrics::default()), cfg).unwrap()
    }

    fn tmp_state_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("signax-session-{}-{}", name, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn error_taxonomy_distinguishes_gone_reasons() {
        let spec = SigSpec::new(2, 3).unwrap();
        // Never opened.
        let m = mgr();
        let e = m.query(SessionId(777), 0, 1).unwrap_err().to_string();
        assert!(e.contains("never opened"), "got: {e}");
        // Closed (both a later query and a double close say so).
        let id = m.open(&spec, &vec![0.0f32, 0.0, 1.0, 1.0].into(), 2).unwrap();
        m.close(id).unwrap();
        let e = m.query(id, 0, 1).unwrap_err().to_string();
        assert!(e.contains("closed"), "got: {e}");
        let e = m.close(id).unwrap_err().to_string();
        assert!(e.contains("closed"), "got: {e}");
        // Evicted with no spill store: destroyed, and the error says so.
        let per = session_bytes(&spec, 4);
        let m = mgr_with(SessionConfig {
            budget_bytes: Some(per + per / 2),
            ..Default::default()
        });
        let mut rng = Rng::new(41);
        let victim = m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap();
        let _keeper = m.open(&spec, &rng.normal_vec(4 * 2, 0.2).into(), 4).unwrap();
        let e = m.query(victim, 0, 3).unwrap_err().to_string();
        assert!(e.contains("evicted"), "got: {e}");
        assert!(!e.contains("never opened") && !e.contains("is closed"), "got: {e}");
    }

    #[test]
    fn spill_and_reload_is_bitwise() {
        // The heart of the tentpole: with a spill store, eviction moves a
        // session cold and the next touch reloads it bit-for-bit — every
        // signature, query, and the byte accounting match an unbounded
        // control manager.
        let spec = SigSpec::new(2, 3).unwrap();
        let per = session_bytes(&spec, 4);
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig {
                budget_bytes: Some(per + per / 2),
                spill: SpillConfig::Memory,
                ..Default::default()
            },
        )
        .unwrap();
        let control = mgr();
        let mut rng = Rng::new(42);
        let pts_a: Rows = rng.normal_vec(4 * 2, 0.2).into();
        let pts_b: Rows = rng.normal_vec(4 * 2, 0.2).into();
        let a = m.open(&spec, &pts_a, 4).unwrap();
        let ca = control.open(&spec, &pts_a, 4).unwrap();
        // Opening b pushes over budget: a (the only candidate) spills.
        let b = m.open(&spec, &pts_b, 4).unwrap();
        assert_eq!(metrics.snapshot().sessions_spilled, 1);
        assert_eq!(m.open_count(), 2, "spilled sessions stay open");
        assert!(m.spilled_bytes() > 0);
        assert!(m.resident_bytes() <= per + per / 2);
        // Cold metadata answers without a reload.
        assert_eq!(m.session_len(a).unwrap(), 4);
        assert_eq!(m.session_spec(a).unwrap(), spec);
        assert_eq!(metrics.snapshot().sessions_reloaded, 0);
        // Touching a reloads it transparently, bitwise.
        assert_eq!(m.query(a, 1, 3).unwrap(), control.query(ca, 1, 3).unwrap());
        assert_eq!(metrics.snapshot().sessions_reloaded, 1);
        assert_eq!(m.signature(a).unwrap(), control.signature(ca).unwrap());
        // Reload re-enforced the budget, so b went cold in a's place;
        // feeding b reloads *and extends* bitwise (feed-vs-eviction race
        // resolves by reload, not by an error).
        let chunk: Rows = rng.normal_vec(3 * 2, 0.2).into();
        let cb = control.open(&spec, &pts_b, 4).unwrap();
        let got = m.feed(b, &chunk, 3).unwrap();
        let want = control.feed(cb, &chunk, 3).unwrap();
        assert_eq!(got, want, "feed after spill diverged from never-spilled control");
        assert_eq!(m.query(b, 2, 6).unwrap(), control.query(cb, 2, 6).unwrap());
    }

    #[test]
    fn feed_batch_reloads_spilled_lanes_bitwise() {
        // Lane-fused feeds hit the same reload path: a group where some
        // sessions are cold still matches scalar feeds bit-for-bit.
        let spec = SigSpec::new(2, 3).unwrap();
        let per = session_bytes(&spec, 4);
        let m = mgr_with(SessionConfig {
            budget_bytes: Some(2 * per + per / 2),
            spill: SpillConfig::Memory,
            ..Default::default()
        });
        let control = mgr();
        let mut rng = Rng::new(43);
        let mut ids = vec![];
        for _ in 0..3 {
            let pts: Rows = rng.normal_vec(4 * 2, 0.2).into();
            let id = m.open(&spec, &pts, 4).unwrap();
            let cid = control.open(&spec, &pts, 4).unwrap();
            ids.push((id, cid));
        }
        // Budget fits two: the LRU session (the first) is now cold.
        assert!(m.spilled_bytes() > 0, "expected at least one spill");
        let feeds: Vec<(SessionId, Rows, usize)> = ids
            .iter()
            .map(|&(id, _)| (id, rng.normal_vec(2 * 2, 0.2).into(), 2))
            .collect();
        let got = m.feed_batch(feeds.clone());
        for (k, ((_, cid), (_, pts, count))) in ids.iter().zip(&feeds).enumerate() {
            let want = control.feed(*cid, pts, *count).unwrap();
            assert_eq!(
                got[k].as_ref().unwrap(),
                &want,
                "lane {k} diverged after spill/reload"
            );
        }
    }

    #[test]
    fn ttl_spills_instead_of_destroying_with_a_store() {
        let spec = SigSpec::new(2, 2).unwrap();
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig {
                ttl: Some(Duration::from_millis(150)),
                sweep_interval: Duration::from_millis(40),
                spill: SpillConfig::Memory,
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(44);
        let pts: Rows = rng.normal_vec(4 * 2, 0.2).into();
        let control = mgr();
        let id = m.open(&spec, &pts, 4).unwrap();
        let cid = control.open(&spec, &pts, 4).unwrap();
        // Wait out the TTL plus a couple of sweeps.
        let deadline = Instant::now() + Duration::from_secs(5);
        while metrics.snapshot().sessions_spilled == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(25));
        }
        assert!(metrics.snapshot().sessions_spilled >= 1, "idle session never spilled");
        assert_eq!(m.open_count(), 1, "TTL with a store must not destroy the session");
        assert_eq!(metrics.snapshot().sessions_expired, 0);
        // And it comes back bitwise.
        assert_eq!(m.query(id, 0, 3).unwrap(), control.query(cid, 0, 3).unwrap());
    }

    #[test]
    fn warm_restart_recovers_sessions_bitwise() {
        // Kill-and-restart: everything a client could observe — interval
        // queries, whole-stream signatures, lengths, further feeds, and
        // the closed-session taxonomy — survives a process boundary via
        // the feed-delta log, bitwise vs an unrestarted control.
        let dir = tmp_state_dir("warmrestart");
        let cfg = SessionConfig { spill: SpillConfig::Disk(dir.clone()), ..Default::default() };
        let control = mgr();
        let mut rng = Rng::new(45);
        let specs =
            [SigSpec::new(2, 3).unwrap(), SigSpec::new(3, 2).unwrap(), SigSpec::new(1, 4).unwrap()];
        let mut ids = vec![];
        let closed_id;
        {
            let m = mgr_with(cfg.clone());
            for spec in &specs {
                let d = spec.d();
                let seed: Rows = rng.normal_vec(3 * d, 0.3).into();
                let id = m.open(spec, &seed, 3).unwrap();
                let cid = control.open(spec, &seed, 3).unwrap();
                for _ in 0..2 {
                    let chunk: Rows = rng.normal_vec(2 * d, 0.3).into();
                    let got = m.feed(id, &chunk, 2).unwrap();
                    let want = control.feed(cid, &chunk, 2).unwrap();
                    assert_eq!(got, want);
                }
                ids.push((id, cid, spec.clone()));
            }
            // One session closed before the "crash" must stay closed.
            let spec = &specs[0];
            closed_id = m.open(spec, &rng.normal_vec(2 * spec.d(), 0.3).into(), 2).unwrap();
            m.close(closed_id).unwrap();
            // Drop = orderly shutdown; the WAL flushes.
        }
        let m2 = mgr_with(cfg);
        assert_eq!(m2.open_count(), ids.len(), "every open session recovered");
        for (id, cid, _) in &ids {
            assert_eq!(m2.session_len(*id).unwrap(), control.session_len(*cid).unwrap());
            let len = control.session_len(*cid).unwrap();
            assert_eq!(
                m2.query(*id, 1, len - 1).unwrap(),
                control.query(*cid, 1, len - 1).unwrap(),
                "recovered interval query diverged"
            );
            assert_eq!(m2.signature(*id).unwrap(), control.signature(*cid).unwrap());
        }
        // Feeds continue bitwise after the restart.
        let (id, cid, spec) = &ids[0];
        let chunk: Rows = rng.normal_vec(2 * spec.d(), 0.3).into();
        assert_eq!(
            m2.feed(*id, &chunk, 2).unwrap(),
            control.feed(*cid, &chunk, 2).unwrap(),
            "post-restart feed diverged"
        );
        // The closed session stays closed, with the right reason.
        let e = m2.query(closed_id, 0, 1).unwrap_err().to_string();
        assert!(e.contains("closed"), "got: {e}");
        // New ids never collide with recovered ones.
        let fresh = m2.open(spec, &rng.normal_vec(2 * spec.d(), 0.3).into(), 2).unwrap();
        assert!(ids.iter().all(|(id, _, _)| *id != fresh) && fresh != closed_id);
        drop(m2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn restart_twice_survives_spills_and_wal_replay() {
        // Spilled-at-shutdown sessions recover too (the log supersedes
        // stale blobs), and a second restart replays the extended log.
        let dir = tmp_state_dir("restart2");
        let spec = SigSpec::new(2, 3).unwrap();
        let per = session_bytes(&spec, 4);
        let cfg = SessionConfig {
            budget_bytes: Some(per + per / 2),
            spill: SpillConfig::Disk(dir.clone()),
            ..Default::default()
        };
        let control = mgr();
        let mut rng = Rng::new(46);
        let pts_a: Rows = rng.normal_vec(4 * 2, 0.2).into();
        let pts_b: Rows = rng.normal_vec(4 * 2, 0.2).into();
        let (a, b, ca, cb);
        {
            let m = mgr_with(cfg.clone());
            a = m.open(&spec, &pts_a, 4).unwrap();
            b = m.open(&spec, &pts_b, 4).unwrap(); // spills a
            ca = control.open(&spec, &pts_a, 4).unwrap();
            cb = control.open(&spec, &pts_b, 4).unwrap();
            assert!(m.spilled_bytes() > 0);
        }
        {
            let m = mgr_with(cfg.clone());
            assert_eq!(m.open_count(), 2);
            assert_eq!(m.query(a, 1, 3).unwrap(), control.query(ca, 1, 3).unwrap());
            let chunk: Rows = rng.normal_vec(2 * 2, 0.2).into();
            assert_eq!(
                m.feed(b, &chunk, 2).unwrap(),
                control.feed(cb, &chunk, 2).unwrap()
            );
            m.flush_wal();
        }
        {
            let m = mgr_with(cfg);
            assert_eq!(
                m.signature(b).unwrap(),
                control.signature(cb).unwrap(),
                "second restart lost the interleaved feed"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Lift an f32 test vector to f64 exactly (every f32 is representable,
    /// so the widened stream is a faithful native-f64 oracle input).
    fn widen(v: &[f32]) -> Vec<f64> {
        v.iter().map(|&x| f64::from(x)).collect()
    }

    #[test]
    fn f64_sessions_serve_native_width_bitwise() {
        // The tentpole contract on the stateful surface: an f64 session's
        // every answer is bitwise identical to driving the f64 kernels
        // directly — no f32 hop anywhere between the wire and the path.
        let spec = SigSpec::with_dtype(2, 3, Precision::F64).unwrap();
        let m = mgr();
        let mut rng = Rng::new(51);
        let all = widen(&rng.normal_vec(10 * 2, 0.4));
        let id = m.open(&spec, &all[..4 * 2].to_vec().into(), 4).unwrap();
        let mut oracle = Path::<f64>::new(&spec, &all[..4 * 2], 4).unwrap();
        let sig = m.feed(id, &all[4 * 2..].to_vec().into(), 6).unwrap();
        oracle.update(&all[4 * 2..], 6).unwrap();
        assert_eq!(sig.precision(), Precision::F64);
        assert_eq!(sig, oracle.signature(), "f64 feed diverged from direct f64 kernels");
        assert_eq!(m.query(id, 2, 7).unwrap(), oracle.query(2, 7).unwrap());
        let plan =
            crate::logsignature::LogSigPlan::new(&spec, crate::logsignature::LogSigBasis::Words)
                .unwrap();
        assert_eq!(
            m.logsig_query(id, 2, 7, &plan).unwrap(),
            oracle.logsig_query(2, 7, &plan).unwrap()
        );
    }

    #[test]
    fn mixed_precision_feed_batch_never_coalesces_across_dtype() {
        let spec32 = SigSpec::new(2, 3).unwrap();
        let spec64 = SigSpec::with_dtype(2, 3, Precision::F64).unwrap();
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(Arc::clone(&metrics), SessionConfig::default()).unwrap();
        let control = mgr();
        let mut rng = Rng::new(52);
        let mut lanes = vec![];
        for _ in 0..2 {
            let pts = rng.normal_vec(4 * 2, 0.3);
            let id = m.open(&spec32, &pts.clone().into(), 4).unwrap();
            let cid = control.open(&spec32, &pts.into(), 4).unwrap();
            lanes.push((id, cid, Precision::F32));
        }
        for _ in 0..2 {
            let pts = widen(&rng.normal_vec(4 * 2, 0.3));
            let id = m.open(&spec64, &pts.clone().into(), 4).unwrap();
            let cid = control.open(&spec64, &pts.into(), 4).unwrap();
            lanes.push((id, cid, Precision::F64));
        }
        let feeds: Vec<(SessionId, Rows, usize)> = lanes
            .iter()
            .map(|&(id, _, prec)| {
                let pts = rng.normal_vec(2 * 2, 0.3);
                let rows: Rows = match prec {
                    Precision::F32 => pts.into(),
                    Precision::F64 => widen(&pts).into(),
                };
                (id, rows, 2)
            })
            .collect();
        let got = m.feed_batch(feeds.clone());
        for (k, ((_, cid, _), (_, rows, count))) in lanes.iter().zip(&feeds).enumerate() {
            let want = control.feed(*cid, rows, *count).unwrap();
            assert_eq!(got[k].as_ref().unwrap(), &want, "lane {k} diverged from scalar feed");
        }
        // Two dtype-homogeneous sweeps — never one mixed sweep.
        assert_eq!(metrics.snapshot().feed_lane_batches, 2);
    }

    #[test]
    fn cross_precision_rows_rejected() {
        let spec32 = SigSpec::new(2, 2).unwrap();
        let spec64 = SigSpec::with_dtype(2, 2, Precision::F64).unwrap();
        let m = mgr();
        let f32_rows: Rows = vec![0.0f32, 0.0, 1.0, 1.0].into();
        let f64_rows: Rows = vec![0.0f64, 0.0, 1.0, 1.0].into();
        assert!(m.open(&spec32, &f64_rows, 2).is_err(), "f64 rows under an f32 spec");
        assert!(m.open(&spec64, &f32_rows, 2).is_err(), "f32 rows under an f64 spec");
        let id = m.open(&spec32, &f32_rows, 2).unwrap();
        assert!(m.feed(id, &f64_rows, 2).is_err(), "scalar feed must not upcast");
        let batch = m.feed_batch(vec![(id, f64_rows, 2)]);
        assert!(batch[0].is_err(), "batched feed must not upcast");
        assert_eq!(m.session_len(id).unwrap(), 2, "rejected feeds leave no trace");
    }

    #[test]
    fn warm_restart_recovers_f64_sessions_bitwise() {
        // The WAL frames f64 rows at native width, so a restarted manager
        // rebuilds the session against the f64 kernels with the exact
        // points — bitwise equal to a never-restarted direct f64 path.
        let dir = tmp_state_dir("warmrestart64");
        let cfg = SessionConfig { spill: SpillConfig::Disk(dir.clone()), ..Default::default() };
        let spec = SigSpec::with_dtype(2, 3, Precision::F64).unwrap();
        let mut rng = Rng::new(53);
        let seed = widen(&rng.normal_vec(3 * 2, 0.3));
        let chunk = widen(&rng.normal_vec(2 * 2, 0.3));
        let mut oracle = Path::<f64>::new(&spec, &seed, 3).unwrap();
        oracle.update(&chunk, 2).unwrap();
        let id;
        {
            let m = mgr_with(cfg.clone());
            id = m.open(&spec, &seed.into(), 3).unwrap();
            m.feed(id, &chunk.clone().into(), 2).unwrap();
            // Drop = orderly shutdown; the WAL flushes.
        }
        let m2 = mgr_with(cfg);
        assert_eq!(m2.session_spec(id).unwrap().dtype(), Precision::F64);
        assert_eq!(
            m2.signature(id).unwrap(),
            oracle.signature(),
            "recovered f64 signature diverged from direct f64 kernels"
        );
        assert_eq!(m2.query(id, 1, 4).unwrap(), oracle.query(1, 4).unwrap());
        // Feeds continue at native width after the restart.
        let chunk2 = widen(&rng.normal_vec(2 * 2, 0.3));
        oracle.update(&chunk2, 2).unwrap();
        assert_eq!(m2.feed(id, &chunk2.into(), 2).unwrap(), oracle.signature());
        drop(m2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn id_striping_matches_placement() {
        let spec = SigSpec::new(2, 2).unwrap();
        let n = 3u64;
        // Shard 1 of 3 (0-based): first_id = 2, stride = 3.
        let m = mgr_with(SessionConfig { first_id: 2, id_stride: n, ..Default::default() });
        let placement = crate::state::Placement::new(n as usize);
        for _ in 0..4 {
            let id = m.open(&spec, &vec![0.0f32, 0.0, 1.0, 1.0].into(), 2).unwrap();
            assert_eq!((id.0 - 2) % n, 0, "id {} off the shard's stride lattice", id.0);
            assert_eq!(placement.locate(id.0), 1, "locate must find the issuing shard");
        }
    }

    #[test]
    fn window_sessions_survive_spill_and_reload_bitwise() {
        // A rolling-window session's durable surface includes its pending
        // slide rows — their source points may already be truncated away —
        // so spill-and-reload must hand back exactly the rows an
        // unbudgeted control would: same first slide index, same bits.
        let spec = SigSpec::new(2, 3).unwrap();
        let window = WindowSpec { len: 4, stride: 2, logsig: None };
        let per = session_bytes(&spec, 8);
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig {
                budget_bytes: Some(per),
                spill: SpillConfig::Memory,
                ..Default::default()
            },
        )
        .unwrap();
        let control = mgr();
        let mut rng = Rng::new(61);
        let seed: Rows = rng.normal_vec(8 * 2, 0.3).into();
        let (a, sig) = m.open_window(&spec, &seed, 8, window).unwrap();
        let (ca, csig) = control.open_window(&spec, &seed, 8, window).unwrap();
        assert_eq!(sig, csig, "open_window seed signature diverged");
        // A second (plain) session pushes over budget; the windowed
        // session is the LRU candidate and spills, pending rows and all.
        let _b = m.open(&spec, &rng.normal_vec(8 * 2, 0.3).into(), 8).unwrap();
        assert!(metrics.snapshot().sessions_spilled >= 1, "windowed session never spilled");
        // Feeding the cold session reloads it transparently; the window
        // advances over the new points exactly as the control's does.
        let chunk: Rows = rng.normal_vec(5 * 2, 0.3).into();
        assert_eq!(
            m.feed(a, &chunk, 5).unwrap(),
            control.feed(ca, &chunk, 5).unwrap(),
            "feed after spill diverged"
        );
        assert!(metrics.snapshot().sessions_reloaded >= 1);
        let (first, rows) = m.poll_window(a).unwrap();
        let (cfirst, crows) = control.poll_window(ca).unwrap();
        assert!(!rows.is_empty(), "seed plus chunk must have emitted slides");
        assert_eq!(first, cfirst, "reloaded window lost or replayed slides");
        assert_eq!(rows, crows, "reloaded pending rows diverged from control");
        // Both cursors agree that nothing further is pending.
        assert_eq!(m.poll_window(a).unwrap(), control.poll_window(ca).unwrap());
    }

    #[test]
    fn window_warm_restart_resumes_bitwise() {
        // Kill-and-restart mid-window: the OpenWindow record seeds the
        // replay, Feed records re-advance the window, and the Poll record
        // re-drains what was already delivered — so the restarted manager
        // hands back exactly the undelivered suffix, bitwise vs an
        // uninterrupted control, in both precisions.
        let dir = tmp_state_dir("windowrestart");
        let cfg = SessionConfig { spill: SpillConfig::Disk(dir.clone()), ..Default::default() };
        let control = mgr();
        let window = WindowSpec {
            len: 5,
            stride: 3,
            logsig: Some(crate::logsignature::LogSigBasis::Words),
        };
        let spec32 = SigSpec::new(2, 3).unwrap();
        let spec64 = SigSpec::with_dtype(2, 3, Precision::F64).unwrap();
        let mut rng = Rng::new(62);
        let seed = rng.normal_vec(6 * 2, 0.3);
        let chunk = rng.normal_vec(4 * 2, 0.3);
        let (id32, id64, c32, c64);
        {
            let m = mgr_with(cfg.clone());
            id32 = m.open_window(&spec32, &seed.clone().into(), 6, window).unwrap().0;
            c32 = control.open_window(&spec32, &seed.clone().into(), 6, window).unwrap().0;
            id64 = m.open_window(&spec64, &widen(&seed).into(), 6, window).unwrap().0;
            c64 = control.open_window(&spec64, &widen(&seed).into(), 6, window).unwrap().0;
            // Partially drain the f32 session before the "crash": the
            // slide delivered here must stay delivered across the
            // restart. The f64 session is never polled, covering the
            // replay path with no Poll record.
            assert_eq!(m.poll_window(id32).unwrap(), control.poll_window(c32).unwrap());
            m.feed(id32, &chunk.clone().into(), 4).unwrap();
            control.feed(c32, &chunk.clone().into(), 4).unwrap();
            m.feed(id64, &widen(&chunk).into(), 4).unwrap();
            control.feed(c64, &widen(&chunk).into(), 4).unwrap();
            m.flush_wal();
            // Process "dies" with undelivered slides buffered.
        }
        let m2 = mgr_with(cfg);
        let (first, rows) = m2.poll_window(id32).unwrap();
        let (cfirst, crows) = control.poll_window(c32).unwrap();
        assert!(first >= 1, "pre-crash poll forgotten: slide 0 re-delivered");
        assert_eq!(first, cfirst, "f32 window replay shifted the slide cursor");
        assert_eq!(rows, crows, "f32 window replay diverged from control");
        let (first64, rows64) = m2.poll_window(id64).unwrap();
        let (cfirst64, crows64) = control.poll_window(c64).unwrap();
        assert_eq!(first64, cfirst64);
        assert_eq!(rows64, crows64, "f64 window replay diverged from control");
        assert!(!rows64.is_empty(), "unpolled f64 session must re-deliver from slide 0");
        // The stream keeps rolling after the restart.
        let chunk2 = rng.normal_vec(3 * 2, 0.3);
        assert_eq!(
            m2.feed(id32, &chunk2.clone().into(), 3).unwrap(),
            control.feed(c32, &chunk2.into(), 3).unwrap(),
            "post-restart feed diverged"
        );
        assert_eq!(m2.poll_window(id32).unwrap(), control.poll_window(c32).unwrap());
        drop(m2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
