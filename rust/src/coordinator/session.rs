//! Streaming sessions: "keeping the signature up-to-date" (§5.5, eq. 7).
//!
//! A session owns a [`crate::path::Path`]; feeding new points extends the
//! precomputed expanding/inverted signatures incrementally (fused ops
//! only), and interval queries stay O(1) at any moment. This is the
//! serving-side wrapper around `Path.update` / `signature(initial=...)`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;
use crate::logsignature::LogSigPlan;
use crate::path::Path;
use crate::ta::SigSpec;

/// Opaque session handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionId(pub u64);

/// Concurrent session table.
pub struct SessionManager {
    next_id: AtomicU64,
    sessions: Mutex<HashMap<SessionId, Mutex<Path>>>,
    metrics: Arc<Metrics>,
}

impl SessionManager {
    pub fn new(metrics: Arc<Metrics>) -> SessionManager {
        SessionManager { next_id: AtomicU64::new(1), sessions: Mutex::new(HashMap::new()), metrics }
    }

    /// Open a session seeded with an initial path (>= 2 points).
    pub fn open(&self, spec: &SigSpec, points: &[f32], stream: usize) -> anyhow::Result<SessionId> {
        let path = Path::new(spec, points, stream)?;
        let id = SessionId(self.next_id.fetch_add(1, Ordering::Relaxed));
        self.sessions.lock().unwrap().insert(id, Mutex::new(path));
        self.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Feed new points; returns the signature over the whole stream so far.
    pub fn feed(&self, id: SessionId, points: &[f32], count: usize) -> anyhow::Result<Vec<f32>> {
        let sessions = self.sessions.lock().unwrap();
        let path = sessions.get(&id).ok_or_else(|| anyhow::anyhow!("unknown session {id:?}"))?;
        let mut path = path.lock().unwrap();
        path.update(points, count)?;
        self.metrics.session_updates.fetch_add(1, Ordering::Relaxed);
        Ok(path.signature())
    }

    /// O(1) interval query against a session's stream.
    pub fn query(&self, id: SessionId, i: usize, j: usize) -> anyhow::Result<Vec<f32>> {
        let sessions = self.sessions.lock().unwrap();
        let path = sessions.get(&id).ok_or_else(|| anyhow::anyhow!("unknown session {id:?}"))?;
        let path = path.lock().unwrap();
        path.query(i, j)
    }

    /// Logsignature interval query.
    pub fn logsig_query(
        &self,
        id: SessionId,
        i: usize,
        j: usize,
        plan: &LogSigPlan,
    ) -> anyhow::Result<Vec<f32>> {
        let sessions = self.sessions.lock().unwrap();
        let path = sessions.get(&id).ok_or_else(|| anyhow::anyhow!("unknown session {id:?}"))?;
        let path = path.lock().unwrap();
        path.logsig_query(i, j, plan)
    }

    /// Number of points a session currently holds.
    pub fn session_len(&self, id: SessionId) -> anyhow::Result<usize> {
        let sessions = self.sessions.lock().unwrap();
        let path = sessions.get(&id).ok_or_else(|| anyhow::anyhow!("unknown session {id:?}"))?;
        let path = path.lock().unwrap();
        Ok(path.len())
    }

    /// Close and drop a session.
    pub fn close(&self, id: SessionId) -> anyhow::Result<()> {
        self.sessions
            .lock()
            .unwrap()
            .remove(&id)
            .map(|_| ())
            .ok_or_else(|| anyhow::anyhow!("unknown session {id:?}"))
    }

    pub fn open_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signature::signature;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    fn mgr() -> SessionManager {
        SessionManager::new(Arc::new(Metrics::default()))
    }

    #[test]
    fn feed_matches_whole_path_signature() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(1);
        let all = rng.normal_vec(12 * 2, 0.4);
        let id = m.open(&spec, &all[..4 * 2], 4).unwrap();
        let sig1 = m.feed(id, &all[4 * 2..8 * 2], 4).unwrap();
        assert_close(&sig1, &signature(&all[..8 * 2], 8, &spec), 2e-3, 1e-4);
        let sig2 = m.feed(id, &all[8 * 2..], 4).unwrap();
        assert_close(&sig2, &signature(&all, 12, &spec), 2e-3, 1e-4);
        assert_eq!(m.session_len(id).unwrap(), 12);
    }

    #[test]
    fn queries_span_fed_chunks() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(2);
        let all = rng.normal_vec(10 * 2, 0.4);
        let id = m.open(&spec, &all[..5 * 2], 5).unwrap();
        m.feed(id, &all[5 * 2..], 5).unwrap();
        // Interval crossing the update boundary.
        let q = m.query(id, 3, 8).unwrap();
        assert_close(&q, &signature(&all[3 * 2..9 * 2], 6, &spec), 5e-3, 5e-4);
    }

    #[test]
    fn unknown_and_closed_sessions_error() {
        let spec = SigSpec::new(2, 2).unwrap();
        let m = mgr();
        assert!(m.feed(SessionId(99), &[0.0; 2], 1).is_err());
        let id = m.open(&spec, &[0.0, 0.0, 1.0, 1.0], 2).unwrap();
        assert_eq!(m.open_count(), 1);
        m.close(id).unwrap();
        assert_eq!(m.open_count(), 0);
        assert!(m.query(id, 0, 1).is_err());
        assert!(m.close(id).is_err());
    }

    #[test]
    fn concurrent_sessions_do_not_interfere() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = Arc::new(mgr());
        let mut handles = vec![];
        for t in 0..4u64 {
            let m = Arc::clone(&m);
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let pts = rng.normal_vec(6 * 2, 0.4);
                let id = m.open(&spec, &pts[..2 * 2], 2).unwrap();
                let sig = m.feed(id, &pts[2 * 2..], 4).unwrap();
                let expect = signature(&pts, 6, &spec);
                for (a, b) in sig.iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.open_count(), 4);
    }
}
