//! Streaming sessions: "keeping the signature up-to-date" (§5.5, eq. 7).
//!
//! A session owns a [`crate::path::Path`]; feeding new points extends the
//! precomputed expanding/inverted signatures incrementally (fused ops
//! only), and interval queries stay O(1) at any moment. This is the
//! serving-side state behind the coordinator's streaming requests
//! (`OpenStream` / `Feed` / `QueryInterval` / `LogSigQueryInterval` /
//! `CloseStream`).
//!
//! Scalability and memory bounds:
//!
//! - The table is **sharded**: session ids map onto independent
//!   `Mutex<HashMap>` shards, and the values are `Arc<Mutex<Path>>`, so a
//!   shard lock is only ever held for a map lookup — never across a `Path`
//!   operation. Feeds to distinct sessions run fully in parallel.
//! - `Path` storage is O(L) per session (the trade the paper makes for
//!   O(1) queries), so a serving process must bound it: an optional
//!   **byte budget** ([`SessionConfig::budget_bytes`], measured with
//!   [`Path::storage_bytes`]) is enforced by evicting the least recently
//!   used idle sessions, and an optional **idle TTL**
//!   ([`SessionConfig::ttl`]) is enforced by a background sweeper thread.
//!   Evicted sessions simply error on later use, like closed ones.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::logsignature::LogSigPlan;
use crate::path::Path;
use crate::ta::SigSpec;

/// Opaque session handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionId(pub u64);

/// Tuning knobs for the session table (see [`SessionManager`]).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Number of independent map shards. More shards reduce contention on
    /// open/close/lookup under many concurrent clients.
    pub shards: usize,
    /// Budget for resident precomputed storage across all sessions, in
    /// bytes ([`Path::storage_bytes`]); `None` = unbounded. When an open
    /// or feed pushes the total over budget, least-recently-used *other*
    /// sessions are evicted until the total fits again. The session just
    /// touched is never evicted by its own enforcement, and sessions with
    /// an operation in flight are skipped — so a single session larger
    /// than the whole budget is allowed to remain.
    pub budget_bytes: Option<usize>,
    /// Evict sessions idle for longer than this; `None` = no TTL. Enforced
    /// by a background sweeper thread owned by the manager.
    pub ttl: Option<Duration>,
    /// How often the sweeper checks for expired sessions.
    pub sweep_interval: Duration,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            shards: 16,
            budget_bytes: None,
            ttl: None,
            sweep_interval: Duration::from_millis(250),
        }
    }
}

/// One live session. The `Path` mutex is the only lock held during actual
/// signature work; the bookkeeping fields are atomics so eviction scans
/// never block serving threads.
struct Session {
    path: Mutex<Path>,
    /// Last accounted [`Path::storage_bytes`] (updated under the path
    /// lock, so the resident total stays consistent with eviction).
    bytes: AtomicUsize,
    /// Manager-wide monotonic clock value at last touch (LRU order).
    touch: AtomicU64,
    /// Milliseconds since manager start at last touch (TTL clock).
    last_used_ms: AtomicU64,
    /// Set (under the path lock) when the session is evicted or closed;
    /// an in-flight feed that raced the eviction sees it and bails
    /// instead of corrupting the resident-bytes accounting.
    evicted: AtomicBool,
}

struct Inner {
    cfg: SessionConfig,
    shards: Vec<Mutex<HashMap<u64, Arc<Session>>>>,
    metrics: Arc<Metrics>,
    epoch: Instant,
    clock: AtomicU64,
    /// Total resident `Path::storage_bytes` across live sessions.
    resident: AtomicUsize,
    shutdown: Mutex<bool>,
    wake: Condvar,
}

impl Inner {
    fn shard(&self, id: u64) -> &Mutex<HashMap<u64, Arc<Session>>> {
        &self.shards[(id as usize) % self.shards.len()]
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn touch(&self, sess: &Session) {
        sess.touch.store(self.clock.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        sess.last_used_ms.store(self.now_ms(), Ordering::Relaxed);
    }

    fn get(&self, id: SessionId) -> anyhow::Result<Arc<Session>> {
        self.shard(id.0)
            .lock()
            .unwrap()
            .get(&id.0)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown session {id:?} (never opened, closed, or evicted)"))
    }

    fn remove(&self, id: u64) -> Option<Arc<Session>> {
        self.shard(id).lock().unwrap().remove(&id)
    }

    /// Finish removing a session that is already out of the map: mark it
    /// evicted and release its bytes from the resident total. Taking the
    /// path lock serialises against any in-flight feed, whose accounting
    /// also runs under that lock — so a session's bytes are counted in
    /// `resident` exactly while it is live.
    fn retire(&self, sess: &Session) {
        let _path = sess.path.lock().unwrap();
        if !sess.evicted.swap(true, Ordering::Relaxed) {
            self.resident.fetch_sub(sess.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
            self.metrics.open_sessions.fetch_sub(1, Ordering::Relaxed);
        }
    }

    fn publish_gauges(&self) {
        self.metrics
            .session_bytes
            .store(self.resident.load(Ordering::Relaxed) as u64, Ordering::Relaxed);
    }

    /// Enforce the byte budget after the `exclude` sessions were touched,
    /// evicting idle sessions in LRU order until the resident total fits
    /// (`exclude` is one id for a scalar open/feed, the whole group for a
    /// lane-fused feed batch — none of the sessions just served may be
    /// evicted by their own enforcement).
    ///
    /// One scan per pass: candidates are snapshotted and sorted by touch
    /// once, then evicted down the list — O(N log N) per enforcement, not
    /// O(N) per eviction. Touches that land after the snapshot make the
    /// order approximate, which is acceptable for LRU. A victim whose
    /// `remove` is lost to a racing close/evict is simply skipped; the
    /// outer loop re-scans only when this pass evicted something yet the
    /// table is still over budget (so it terminates: each pass shrinks
    /// the table or ends the loop).
    fn enforce_budget(&self, exclude: &[u64]) {
        if let Some(budget) = self.cfg.budget_bytes {
            while self.resident.load(Ordering::Relaxed) > budget {
                let mut cands: Vec<(u64, u64)> = vec![];
                for shard in &self.shards {
                    let guard = shard.lock().unwrap();
                    for (&id, sess) in guard.iter() {
                        if !exclude.contains(&id) {
                            cands.push((sess.touch.load(Ordering::Relaxed), id));
                        }
                    }
                }
                cands.sort_unstable();
                let mut evicted_any = false;
                for &(_, id) in &cands {
                    if self.resident.load(Ordering::Relaxed) <= budget {
                        break;
                    }
                    // Eviction targets *idle* sessions: skip any whose path
                    // mutex is held right now (a concurrent client is
                    // mid-operation on it — it is not LRU, its touch just
                    // hasn't landed yet from this thread's perspective).
                    let busy = {
                        let guard = self.shard(id).lock().unwrap();
                        match guard.get(&id) {
                            Some(sess) => sess.path.try_lock().is_err(),
                            None => continue, // raced away: not a candidate
                        }
                    };
                    if busy {
                        continue;
                    }
                    if let Some(sess) = self.remove(id) {
                        self.retire(&sess);
                        self.metrics.sessions_evicted.fetch_add(1, Ordering::Relaxed);
                        evicted_any = true;
                    }
                }
                if !evicted_any {
                    break; // only the just-touched session remains (or raced away)
                }
            }
        }
        self.publish_gauges();
    }

    /// One TTL pass: expire sessions idle for longer than `cfg.ttl`.
    fn sweep(&self) {
        let Some(ttl) = self.cfg.ttl else { return };
        // Clamp: a sub-millisecond TTL must not truncate to 0, which would
        // make every session (idle time >= 0) expire on each pass.
        let ttl_ms = (ttl.as_millis() as u64).max(1);
        let now = self.now_ms();
        let mut expired: Vec<Arc<Session>> = vec![];
        for shard in &self.shards {
            let mut guard = shard.lock().unwrap();
            let ids: Vec<u64> = guard
                .iter()
                .filter(|(_, s)| now.saturating_sub(s.last_used_ms.load(Ordering::Relaxed)) >= ttl_ms)
                .map(|(&id, _)| id)
                .collect();
            for id in ids {
                if let Some(s) = guard.remove(&id) {
                    expired.push(s);
                }
            }
        }
        if expired.is_empty() {
            return;
        }
        for sess in &expired {
            self.retire(sess);
            self.metrics.sessions_expired.fetch_add(1, Ordering::Relaxed);
        }
        self.publish_gauges();
    }
}

/// Concurrent, memory-bounded session table (see the module docs).
pub struct SessionManager {
    next_id: AtomicU64,
    inner: Arc<Inner>,
    sweeper: Option<std::thread::JoinHandle<()>>,
}

impl SessionManager {
    /// Unbounded manager with default sharding (no budget, no TTL).
    pub fn new(metrics: Arc<Metrics>) -> SessionManager {
        SessionManager::with_config(metrics, SessionConfig::default())
    }

    pub fn with_config(metrics: Arc<Metrics>, cfg: SessionConfig) -> SessionManager {
        let shards = cfg.shards.max(1);
        let spawn_sweeper = cfg.ttl.is_some();
        let inner = Arc::new(Inner {
            cfg,
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            metrics,
            epoch: Instant::now(),
            clock: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            shutdown: Mutex::new(false),
            wake: Condvar::new(),
        });
        let sweeper = if spawn_sweeper {
            let inner = Arc::clone(&inner);
            Some(
                std::thread::Builder::new()
                    .name("signax-session-sweeper".into())
                    .spawn(move || loop {
                        let guard = inner.shutdown.lock().unwrap();
                        if *guard {
                            return;
                        }
                        let (guard, _) =
                            inner.wake.wait_timeout(guard, inner.cfg.sweep_interval).unwrap();
                        if *guard {
                            return;
                        }
                        drop(guard);
                        inner.sweep();
                    })
                    .expect("spawn session sweeper"),
            )
        } else {
            None
        };
        SessionManager { next_id: AtomicU64::new(1), inner, sweeper }
    }

    /// Open a session seeded with an initial path (>= 2 points).
    pub fn open(&self, spec: &SigSpec, points: &[f32], stream: usize) -> anyhow::Result<SessionId> {
        self.open_with_signature(spec, points, stream).map(|(id, _)| id)
    }

    /// Open a session and also return the signature of the seed path.
    /// The signature is computed *before* the session becomes visible (and
    /// thus evictable), so a racing eviction under budget pressure cannot
    /// turn a successful open into an error.
    pub fn open_with_signature(
        &self,
        spec: &SigSpec,
        points: &[f32],
        stream: usize,
    ) -> anyhow::Result<(SessionId, Vec<f32>)> {
        let path = Path::new(spec, points, stream)?;
        let bytes = path.storage_bytes();
        let sig = path.signature();
        let id = SessionId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let sess = Arc::new(Session {
            path: Mutex::new(path),
            bytes: AtomicUsize::new(bytes),
            touch: AtomicU64::new(0),
            last_used_ms: AtomicU64::new(0),
            evicted: AtomicBool::new(false),
        });
        self.inner.touch(&sess);
        self.inner.resident.fetch_add(bytes, Ordering::Relaxed);
        // Gauges before the insert: once the session is in the map a racing
        // eviction may retire it (fetch_sub) immediately, so incrementing
        // afterwards could transiently underflow the gauge.
        self.inner.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.open_sessions.fetch_add(1, Ordering::Relaxed);
        self.inner.shard(id.0).lock().unwrap().insert(id.0, sess);
        self.inner.enforce_budget(&[id.0]);
        Ok((id, sig))
    }

    /// Feed new points; returns the signature over the whole stream so far.
    pub fn feed(&self, id: SessionId, points: &[f32], count: usize) -> anyhow::Result<Vec<f32>> {
        let sess = self.inner.get(id)?;
        // Touch at start as well as completion: a long-running update must
        // not look idle to LRU/TTL eviction while it is in flight.
        self.inner.touch(&sess);
        let sig = {
            let mut path = sess.path.lock().unwrap();
            anyhow::ensure!(!sess.evicted.load(Ordering::Relaxed), "session {id:?} was evicted");
            path.update(points, count)?;
            // `update` only appends, so storage can only have grown.
            let new_bytes = path.storage_bytes();
            let old_bytes = sess.bytes.swap(new_bytes, Ordering::Relaxed);
            self.inner.resident.fetch_add(new_bytes - old_bytes, Ordering::Relaxed);
            path.signature()
        };
        self.inner.touch(&sess);
        self.inner.metrics.session_updates.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed);
        self.inner.enforce_budget(&[id.0]);
        Ok(sig)
    }

    /// Feed several sessions in one call, lane-fusing same-spec groups —
    /// the stateful analogue of the router's signature microbatch, backed
    /// by [`Path::update_batch`]. Returns one result per feed, in order;
    /// each is the whole-stream signature so far, **bitwise identical**
    /// to what a scalar [`SessionManager::feed`] of the same points would
    /// have returned (lanes replay the scalar op order). Failures are
    /// per-feed: an unknown/evicted session or malformed buffer errors
    /// its own entry while the rest of the group proceeds.
    ///
    /// A session appearing more than once is served its feeds in order
    /// (occurrence k runs in wave k), so coalescing cannot reorder one
    /// stream's points. Path locks are taken in ascending session-id
    /// order, so two overlapping batch feeds cannot deadlock.
    pub fn feed_batch(
        &self,
        feeds: Vec<(SessionId, Vec<f32>, usize)>,
    ) -> Vec<anyhow::Result<Vec<f32>>> {
        let n = feeds.len();
        let mut results: Vec<Option<anyhow::Result<Vec<f32>>>> = (0..n).map(|_| None).collect();
        // Wave-partition duplicates: occurrence k of a session id lands in
        // wave k, and waves run sequentially.
        let mut waves: Vec<Vec<usize>> = vec![];
        for idx in 0..n {
            let sid = feeds[idx].0;
            match waves.iter_mut().find(|w| w.iter().all(|&j| feeds[j].0 != sid)) {
                Some(w) => w.push(idx),
                None => waves.push(vec![idx]),
            }
        }
        for wave in &waves {
            self.feed_wave(&feeds, wave, &mut results);
        }
        let touched: Vec<u64> = feeds.iter().map(|f| f.0 .0).collect();
        self.inner.enforce_budget(&touched);
        results.into_iter().map(|r| r.expect("every feed resolved")).collect()
    }

    /// One wave of [`SessionManager::feed_batch`]: at most one feed per
    /// session.
    fn feed_wave(
        &self,
        feeds: &[(SessionId, Vec<f32>, usize)],
        wave: &[usize],
        results: &mut [Option<anyhow::Result<Vec<f32>>>],
    ) {
        // Resolve sessions; unknown ids error individually.
        let mut resolved: Vec<(usize, Arc<Session>)> = vec![];
        for &idx in wave {
            match self.inner.get(feeds[idx].0) {
                Ok(sess) => {
                    // Touch at start as well as completion, like a scalar
                    // feed: in-flight work must not look idle to LRU/TTL.
                    self.inner.touch(&sess);
                    resolved.push((idx, sess));
                }
                Err(e) => results[idx] = Some(Err(e)),
            }
        }
        // Lock paths in ascending session-id order: concurrent batch
        // feeds over overlapping session sets then acquire in the same
        // global order and cannot deadlock.
        resolved.sort_by_key(|(idx, _)| feeds[*idx].0 .0);
        let mut locked: Vec<(usize, std::sync::MutexGuard<'_, Path>)> = vec![];
        for (idx, sess) in &resolved {
            let guard = sess.path.lock().unwrap();
            if sess.evicted.load(Ordering::Relaxed) {
                results[*idx] =
                    Some(Err(anyhow::anyhow!("session {:?} was evicted", feeds[*idx].0)));
                continue;
            }
            // Per-lane validation up front, so one malformed feed errors
            // alone instead of failing its whole lane group.
            let (_, points, count) = &feeds[*idx];
            let d = guard.spec().d();
            if *count < 1 {
                results[*idx] = Some(Err(anyhow::anyhow!("no points to add")));
                continue;
            }
            if points.len() != count * d {
                results[*idx] = Some(Err(anyhow::anyhow!(
                    "feed buffer has {} values, expected count({count}) * channels({d})",
                    points.len()
                )));
                continue;
            }
            locked.push((*idx, guard));
        }
        // Group same-spec lanes into contiguous runs (the feed lane keys
        // submissions by spec, so this is normally one run; a mixed batch
        // still lane-fuses per spec).
        locked.sort_by_key(|(_, g)| (g.spec().d(), g.spec().depth()));
        let mut start = 0usize;
        while start < locked.len() {
            let key = {
                let s = locked[start].1.spec();
                (s.d(), s.depth())
            };
            let mut end = start + 1;
            while end < locked.len() {
                let s = locked[end].1.spec();
                if (s.d(), s.depth()) != key {
                    break;
                }
                end += 1;
            }
            let run = &mut locked[start..end];
            let idxs: Vec<usize> = run.iter().map(|(idx, _)| *idx).collect();
            let outcome = {
                let mut paths: Vec<&mut Path> = run.iter_mut().map(|(_, g)| &mut **g).collect();
                let slices: Vec<&[f32]> = idxs.iter().map(|&i| feeds[i].1.as_slice()).collect();
                let counts: Vec<usize> = idxs.iter().map(|&i| feeds[i].2).collect();
                Path::update_batch(&mut paths, &slices, &counts)
            };
            match outcome {
                Ok(()) => {
                    if idxs.len() >= 2 {
                        self.inner.metrics.feed_lane_batches.fetch_add(1, Ordering::Relaxed);
                        self.inner.metrics.dispatch_lane_fused.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.inner.metrics.dispatch_scalar.fetch_add(1, Ordering::Relaxed);
                    }
                    for (idx, guard) in run.iter() {
                        // Accounting under this path's lock, exactly like
                        // a scalar feed: `update` only appends, so storage
                        // can only have grown.
                        let (_, sess) = resolved
                            .iter()
                            .find(|(ri, _)| ri == idx)
                            .expect("locked lane was resolved");
                        let new_bytes = guard.storage_bytes();
                        let old_bytes = sess.bytes.swap(new_bytes, Ordering::Relaxed);
                        self.inner.resident.fetch_add(new_bytes - old_bytes, Ordering::Relaxed);
                        self.inner.metrics.session_updates.fetch_add(1, Ordering::Relaxed);
                        results[*idx] = Some(Ok(guard.signature()));
                    }
                }
                Err(e) => {
                    for &idx in &idxs {
                        results[idx] = Some(Err(anyhow::anyhow!("lane-fused feed failed: {e}")));
                    }
                }
            }
            start = end;
        }
        drop(locked);
        // Completion touches (LRU order reflects the work just done).
        for (_, sess) in &resolved {
            self.inner.touch(sess);
        }
    }

    /// O(1) interval query against a session's stream.
    pub fn query(&self, id: SessionId, i: usize, j: usize) -> anyhow::Result<Vec<f32>> {
        let sess = self.inner.get(id)?;
        let out = sess.path.lock().unwrap().query(i, j)?;
        self.inner.touch(&sess);
        Ok(out)
    }

    /// Logsignature interval query.
    pub fn logsig_query(
        &self,
        id: SessionId,
        i: usize,
        j: usize,
        plan: &LogSigPlan,
    ) -> anyhow::Result<Vec<f32>> {
        let sess = self.inner.get(id)?;
        let out = sess.path.lock().unwrap().logsig_query(i, j, plan)?;
        self.inner.touch(&sess);
        Ok(out)
    }

    /// Logsignature interval query resolving the session only once:
    /// `plan_for` receives the session's spec and returns the (typically
    /// cached) plan — this is the coordinator's hot path, which keys its
    /// plan cache by the session's `(d, depth)`.
    pub fn logsig_query_with<F>(
        &self,
        id: SessionId,
        i: usize,
        j: usize,
        plan_for: F,
    ) -> anyhow::Result<Vec<f32>>
    where
        F: FnOnce(&SigSpec) -> anyhow::Result<Arc<LogSigPlan>>,
    {
        let sess = self.inner.get(id)?;
        // Only the O(1) interval query runs under the path lock; plan
        // resolution (which may take the coordinator's global plan-cache
        // mutex, or build a plan) and the log projection run outside it,
        // so concurrent queries/feeds never serialize on either lock.
        let (sig, spec) = {
            let path = sess.path.lock().unwrap();
            (path.query(i, j)?, path.spec().clone())
        };
        self.inner.touch(&sess);
        let plan = plan_for(&spec)?;
        crate::logsignature::logsignature_from_sig(&sig, &spec, plan.as_ref())
    }

    /// The signature of a session's whole stream so far.
    pub fn signature(&self, id: SessionId) -> anyhow::Result<Vec<f32>> {
        let sess = self.inner.get(id)?;
        let out = sess.path.lock().unwrap().signature();
        self.inner.touch(&sess);
        Ok(out)
    }

    /// Number of points a session currently holds.
    pub fn session_len(&self, id: SessionId) -> anyhow::Result<usize> {
        let sess = self.inner.get(id)?;
        let len = sess.path.lock().unwrap().len();
        Ok(len)
    }

    /// The `SigSpec` a session was opened with.
    pub fn session_spec(&self, id: SessionId) -> anyhow::Result<SigSpec> {
        let sess = self.inner.get(id)?;
        let spec = sess.path.lock().unwrap().spec().clone();
        Ok(spec)
    }

    /// Close and drop a session.
    pub fn close(&self, id: SessionId) -> anyhow::Result<()> {
        let sess = self
            .inner
            .remove(id.0)
            .ok_or_else(|| anyhow::anyhow!("unknown session {id:?}"))?;
        self.inner.retire(&sess);
        self.inner.publish_gauges();
        Ok(())
    }

    pub fn open_count(&self) -> usize {
        self.inner.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Bytes of precomputed storage currently resident across sessions.
    pub fn resident_bytes(&self) -> usize {
        self.inner.resident.load(Ordering::Relaxed)
    }
}

impl Drop for SessionManager {
    fn drop(&mut self) {
        *self.inner.shutdown.lock().unwrap() = true;
        self.inner.wake.notify_all();
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signature::signature;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    fn mgr() -> SessionManager {
        SessionManager::new(Arc::new(Metrics::default()))
    }

    /// Storage bytes of a fresh session of `stream` points (for sizing
    /// budgets deterministically in tests) — measured on a throwaway
    /// `Path` so the tests stay agnostic to its storage layout.
    fn session_bytes(spec: &SigSpec, stream: usize) -> usize {
        Path::new(spec, &vec![0.0f32; stream * spec.d()], stream).unwrap().storage_bytes()
    }

    #[test]
    fn feed_matches_whole_path_signature() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(1);
        let all = rng.normal_vec(12 * 2, 0.4);
        let id = m.open(&spec, &all[..4 * 2], 4).unwrap();
        let sig1 = m.feed(id, &all[4 * 2..8 * 2], 4).unwrap();
        assert_close(&sig1, &signature(&all[..8 * 2], 8, &spec), 2e-3, 1e-4);
        let sig2 = m.feed(id, &all[8 * 2..], 4).unwrap();
        assert_close(&sig2, &signature(&all, 12, &spec), 2e-3, 1e-4);
        assert_eq!(m.session_len(id).unwrap(), 12);
        assert_eq!(m.session_spec(id).unwrap(), spec);
    }

    #[test]
    fn queries_span_fed_chunks() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(2);
        let all = rng.normal_vec(10 * 2, 0.4);
        let id = m.open(&spec, &all[..5 * 2], 5).unwrap();
        m.feed(id, &all[5 * 2..], 5).unwrap();
        // Interval crossing the update boundary.
        let q = m.query(id, 3, 8).unwrap();
        assert_close(&q, &signature(&all[3 * 2..9 * 2], 6, &spec), 5e-3, 5e-4);
        // Whole-stream signature accessor agrees with recomputation.
        let whole = m.signature(id).unwrap();
        assert_close(&whole, &signature(&all, 10, &spec), 2e-3, 1e-4);
        // Logsig interval query (direct-plan and resolve-once variants).
        let plan =
            crate::logsignature::LogSigPlan::new(&spec, crate::logsignature::LogSigBasis::Words)
                .unwrap();
        let lq = m.logsig_query(id, 3, 8, &plan).unwrap();
        assert_eq!(lq.len(), crate::words::witt_dimension(2, 3));
        let lq2 = m
            .logsig_query_with(id, 3, 8, |spec| {
                Ok(Arc::new(crate::logsignature::LogSigPlan::new(
                    spec,
                    crate::logsignature::LogSigBasis::Words,
                )?))
            })
            .unwrap();
        assert_eq!(lq, lq2);
    }

    #[test]
    fn feed_batch_matches_scalar_feeds_bitwise() {
        use crate::substrate::propcheck::property;
        // Serving contract: coalescing same-spec feeds into one lane-fused
        // sweep must not change any session's bits — returned signatures,
        // later queries, and the resident-byte accounting all match a
        // manager fed scalar, feed for feed (ragged counts included).
        property("feed_batch == scalar feeds bitwise", 8, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let lanes = g.usize_in(2, 5);
            g.label(format!("d={d} n={n} lanes={lanes}"));
            let spec = SigSpec::new(d, n).unwrap();
            let fused = mgr();
            let scalar = mgr();
            let mut ids = vec![];
            for _ in 0..lanes {
                let seed_len = g.usize_in(2, 6);
                let pts = g.normal_vec(seed_len * d, 0.3);
                let fid = fused.open(&spec, &pts, seed_len).unwrap();
                let sid = scalar.open(&spec, &pts, seed_len).unwrap();
                ids.push((fid, sid));
            }
            for _ in 0..3 {
                let feeds: Vec<(SessionId, Vec<f32>, usize)> = ids
                    .iter()
                    .map(|&(fid, _)| {
                        let count = g.usize_in(1, 6);
                        (fid, g.normal_vec(count * d, 0.3), count)
                    })
                    .collect();
                let got = fused.feed_batch(feeds.clone());
                for (k, ((_, sid), (_, pts, count))) in ids.iter().zip(&feeds).enumerate() {
                    let want = scalar.feed(*sid, pts, *count).unwrap();
                    assert_eq!(
                        got[k].as_ref().unwrap(),
                        &want,
                        "lane {k} signature diverged from scalar feed"
                    );
                }
            }
            for &(fid, sid) in &ids {
                let len = fused.session_len(fid).unwrap();
                assert_eq!(len, scalar.session_len(sid).unwrap());
                assert_eq!(
                    fused.query(fid, 1, len - 1).unwrap(),
                    scalar.query(sid, 1, len - 1).unwrap(),
                    "post-feed interval query diverged"
                );
            }
            assert_eq!(fused.resident_bytes(), scalar.resident_bytes());
        });
    }

    #[test]
    fn feed_batch_isolates_errors_and_orders_duplicates() {
        let spec = SigSpec::new(2, 3).unwrap();
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(Arc::clone(&metrics), SessionConfig::default());
        let twin = mgr();
        let mut rng = Rng::new(31);
        let seed = rng.normal_vec(4 * 2, 0.3);
        let a = m.open(&spec, &seed, 4).unwrap();
        let b = m.open(&spec, &seed, 4).unwrap();
        let ta = twin.open(&spec, &seed, 4).unwrap();
        let chunk1 = rng.normal_vec(3 * 2, 0.3);
        let chunk2 = rng.normal_vec(2 * 2, 0.3);
        let good_b = rng.normal_vec(2 * 2, 0.3);
        // One batch: a fed twice (must apply in order), b with a malformed
        // buffer, plus an unknown session — failures stay individual.
        let results = m.feed_batch(vec![
            (a, chunk1.clone(), 3),
            (b, vec![0.0; 3], 2), // wrong buffer length
            (a, chunk2.clone(), 2),
            (SessionId(9999), good_b.clone(), 2), // unknown
        ]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        assert!(results[3].is_err());
        // a saw chunk1 then chunk2, exactly like two scalar feeds.
        twin.feed(ta, &chunk1, 3).unwrap();
        let want = twin.feed(ta, &chunk2, 2).unwrap();
        assert_eq!(results[2].as_ref().unwrap(), &want);
        assert_eq!(m.session_len(a).unwrap(), 9);
        // b is untouched by its failed feed.
        assert_eq!(m.session_len(b).unwrap(), 4);
        // The failed lanes never corrupt accounting: b can still be fed.
        assert!(m.feed(b, &good_b, 2).is_ok());
        let snap = metrics.snapshot();
        assert_eq!(snap.session_updates, 3, "two batched feeds on a + one scalar on b");
    }

    #[test]
    fn feed_batch_closed_lane_errors_while_group_proceeds() {
        // The mid-feed eviction story: a session leaving the table between
        // submission and flush errors its own lane; the survivors' sweep
        // still runs and stays bitwise-scalar.
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let twin = mgr();
        let mut rng = Rng::new(32);
        let seed = rng.normal_vec(4 * 2, 0.3);
        let alive = m.open(&spec, &seed, 4).unwrap();
        let dead = m.open(&spec, &seed, 4).unwrap();
        let talive = twin.open(&spec, &seed, 4).unwrap();
        m.close(dead).unwrap();
        let chunk = rng.normal_vec(3 * 2, 0.3);
        let results =
            m.feed_batch(vec![(alive, chunk.clone(), 3), (dead, chunk.clone(), 3)]);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        let want = twin.feed(talive, &chunk, 3).unwrap();
        assert_eq!(results[0].as_ref().unwrap(), &want);
    }

    #[test]
    fn feed_batch_counts_feed_lane_metrics() {
        let spec = SigSpec::new(2, 3).unwrap();
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(Arc::clone(&metrics), SessionConfig::default());
        let mut rng = Rng::new(33);
        let ids: Vec<SessionId> = (0..3)
            .map(|_| m.open(&spec, &rng.normal_vec(4 * 2, 0.3), 4).unwrap())
            .collect();
        let feeds: Vec<(SessionId, Vec<f32>, usize)> =
            ids.iter().map(|&id| (id, rng.normal_vec(2 * 2, 0.3), 2)).collect();
        for r in m.feed_batch(feeds) {
            r.unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.feed_lane_batches, 1, "three same-spec lanes = one fused sweep");
        assert_eq!(snap.dispatch_lane_fused, 1);
        assert_eq!(snap.session_updates, 3);
        // A single-lane batch is a scalar dispatch, not a lane sweep.
        let solo = m.feed_batch(vec![(ids[0], rng.normal_vec(2 * 2, 0.3), 2)]);
        assert!(solo[0].is_ok());
        let snap = metrics.snapshot();
        assert_eq!(snap.feed_lane_batches, 1);
        assert_eq!(snap.dispatch_scalar, 1);
    }

    #[test]
    fn unknown_and_closed_sessions_error() {
        let spec = SigSpec::new(2, 2).unwrap();
        let m = mgr();
        assert!(m.feed(SessionId(99), &[0.0; 2], 1).is_err());
        let id = m.open(&spec, &[0.0, 0.0, 1.0, 1.0], 2).unwrap();
        assert_eq!(m.open_count(), 1);
        m.close(id).unwrap();
        assert_eq!(m.open_count(), 0);
        assert_eq!(m.resident_bytes(), 0);
        assert!(m.query(id, 0, 1).is_err());
        assert!(m.close(id).is_err());
    }

    #[test]
    fn concurrent_sessions_do_not_interfere() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = Arc::new(mgr());
        let mut handles = vec![];
        for t in 0..4u64 {
            let m = Arc::clone(&m);
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let pts = rng.normal_vec(6 * 2, 0.4);
                let id = m.open(&spec, &pts[..2 * 2], 2).unwrap();
                let sig = m.feed(id, &pts[2 * 2..], 4).unwrap();
                let expect = signature(&pts, 6, &spec);
                for (a, b) in sig.iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.open_count(), 4);
    }

    #[test]
    fn resident_bytes_tracks_path_storage() {
        let spec = SigSpec::new(2, 3).unwrap();
        let m = mgr();
        let mut rng = Rng::new(3);
        let id = m.open(&spec, &rng.normal_vec(4 * 2, 0.2), 4).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 4));
        m.feed(id, &rng.normal_vec(6 * 2, 0.2), 6).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 10));
        let id2 = m.open(&spec, &rng.normal_vec(3 * 2, 0.2), 3).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 10) + session_bytes(&spec, 3));
        m.close(id).unwrap();
        assert_eq!(m.resident_bytes(), session_bytes(&spec, 3));
        m.close(id2).unwrap();
        assert_eq!(m.resident_bytes(), 0);
    }

    #[test]
    fn budget_is_enforced_in_lru_order_and_evictees_error() {
        let spec = SigSpec::new(2, 3).unwrap();
        let per = session_bytes(&spec, 4);
        let metrics = Arc::new(Metrics::default());
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig { budget_bytes: Some(3 * per + per / 2), ..Default::default() },
        );
        let mut rng = Rng::new(4);
        let mut ids = vec![];
        for _ in 0..3 {
            ids.push(m.open(&spec, &rng.normal_vec(4 * 2, 0.2), 4).unwrap());
            assert!(m.resident_bytes() <= 3 * per + per / 2);
        }
        assert_eq!(m.open_count(), 3);
        // Touch 0 so 1 becomes the LRU.
        m.query(ids[0], 0, 3).unwrap();
        // A fourth session pushes the total over budget: exactly one
        // eviction, and it must be the least recently used (ids[1]).
        let id3 = m.open(&spec, &rng.normal_vec(4 * 2, 0.2), 4).unwrap();
        assert!(m.resident_bytes() <= 3 * per + per / 2);
        assert_eq!(m.open_count(), 3);
        assert!(m.query(ids[1], 0, 3).is_err(), "LRU session should be evicted");
        assert!(m.feed(ids[1], &[0.0; 2], 1).is_err(), "evicted sessions error cleanly");
        for &id in [ids[0], ids[2], id3].iter() {
            assert!(m.query(id, 0, 3).is_ok(), "recently used session evicted");
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.sessions_evicted, 1);
        assert_eq!(snap.open_sessions, 3);
        assert_eq!(snap.session_bytes as usize, m.resident_bytes());
    }

    #[test]
    fn budget_never_exceeded_property() {
        use crate::substrate::propcheck::property;
        property("session budget never exceeded", 8, |g| {
            let spec = SigSpec::new(2, 3).unwrap();
            let per = session_bytes(&spec, 4);
            let cap_sessions = g.usize_in(2, 5);
            let budget = cap_sessions * per + per / 4;
            g.label(format!("budget for ~{cap_sessions} sessions"));
            let m = SessionManager::with_config(
                Arc::new(Metrics::default()),
                SessionConfig { budget_bytes: Some(budget), ..Default::default() },
            );
            let mut open: Vec<SessionId> = vec![];
            let mut fed: Vec<bool> = vec![];
            for _ in 0..10 {
                // Feed each session at most once so no single session can
                // outgrow the budget (the just-touched session is exempt
                // from eviction by design).
                let unfed: Vec<usize> =
                    (0..open.len()).filter(|&k| !fed[k]).collect();
                if unfed.is_empty() || g.usize_in(0, 2) > 0 {
                    let pts = g.normal_vec(4 * 2, 0.2);
                    open.push(m.open(&spec, &pts, 4).unwrap());
                    fed.push(false);
                } else {
                    // Feed a random still-known session (may have been
                    // evicted; errors are acceptable, overshoot is not).
                    let k = unfed[g.usize_in(0, unfed.len() - 1)];
                    fed[k] = true;
                    let pts = g.normal_vec(2 * 2, 0.2);
                    let _ = m.feed(open[k], &pts, 2);
                }
                assert!(
                    m.resident_bytes() <= budget,
                    "resident {} exceeds budget {budget}",
                    m.resident_bytes()
                );
            }
        });
    }

    #[test]
    fn ttl_sweeper_expires_idle_sessions_only() {
        let spec = SigSpec::new(2, 2).unwrap();
        let metrics = Arc::new(Metrics::default());
        // TTL is 10x the keep-warm interval: only a full-second scheduler
        // stall between warms could spuriously expire the live session.
        let m = SessionManager::with_config(
            Arc::clone(&metrics),
            SessionConfig {
                ttl: Some(Duration::from_millis(1000)),
                sweep_interval: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let mut rng = Rng::new(5);
        let idle = m.open(&spec, &rng.normal_vec(4 * 2, 0.2), 4).unwrap();
        let live = m.open(&spec, &rng.normal_vec(4 * 2, 0.2), 4).unwrap();
        // Keep `live` warm well inside the TTL while `idle` goes stale
        // (loop spans ~1.4s, past the 1s TTL plus a sweep interval).
        for _ in 0..14 {
            std::thread::sleep(Duration::from_millis(100));
            m.query(live, 0, 3).unwrap();
        }
        assert!(m.query(idle, 0, 3).is_err(), "idle session should have expired");
        assert!(m.query(live, 0, 3).is_ok(), "kept-warm session must survive");
        assert_eq!(m.open_count(), 1);
        assert!(metrics.snapshot().sessions_expired >= 1);
    }

    #[test]
    fn feeds_do_not_serialize_behind_the_table_lock() {
        // Regression for the global-map-lock bug: a long feed to one
        // session must not block a tiny feed to another. The old code held
        // the single table mutex across the whole `Path::update`, so B's
        // latency equalled A's; now B only waits on its own path lock.
        if crate::substrate::pool::default_threads() < 2 {
            eprintln!("skipping: single hardware thread (no true overlap to measure)");
            return;
        }
        let spec = SigSpec::new(4, 4).unwrap();
        let mut rng = Rng::new(6);
        let big = rng.normal_vec(8192 * 4, 0.1);
        let small = rng.normal_vec(4 * 4, 0.1);
        // Best of three attempts: scheduling noise from concurrently
        // running tests can delay the small feed; a table-wide lock fails
        // every attempt (B always waits out A's entire update).
        let mut last = (Duration::ZERO, Duration::ZERO);
        for _ in 0..3 {
            let m = Arc::new(mgr());
            let a = m.open(&spec, &rng.normal_vec(2 * 4, 0.1), 2).unwrap();
            let b = m.open(&spec, &rng.normal_vec(2 * 4, 0.1), 2).unwrap();
            let m2 = Arc::clone(&m);
            let big2 = big.clone();
            let t_a = std::thread::spawn(move || {
                let t0 = Instant::now();
                m2.feed(a, &big2, 8192).unwrap();
                t0.elapsed()
            });
            // Give A's feed time to get going, then time B's small feed.
            std::thread::sleep(Duration::from_millis(20));
            let t0 = Instant::now();
            m.feed(b, &small, 4).unwrap();
            let b_elapsed = t0.elapsed();
            let a_elapsed = t_a.join().unwrap();
            if b_elapsed < a_elapsed / 2 + Duration::from_millis(5) {
                return;
            }
            last = (b_elapsed, a_elapsed);
        }
        panic!(
            "small feed ({:?}) serialized behind big feed ({:?}) on every attempt",
            last.0, last.1
        );
    }

    #[test]
    fn distinct_session_feeds_scale_with_threads() {
        // N threads feeding N distinct sessions must beat the same total
        // work done serially; a table-wide lock would flatline this. On
        // fewer than 4 hardware threads the margin over `cargo test`'s
        // concurrent sibling tests is too thin to assert on — the
        // deterministic feeds_do_not_serialize test covers the lock
        // regression there.
        let hw = crate::substrate::pool::default_threads();
        if hw < 4 {
            eprintln!("skipping: needs >= 4 hardware threads for a stable margin");
            return;
        }
        let threads = 4;
        let spec = SigSpec::new(4, 4).unwrap();
        let feeds = 40usize;
        let feed_points = 256usize;
        let run = |par: bool| -> Duration {
            let m = SessionManager::new(Arc::new(Metrics::default()));
            let mut rng = Rng::new(7);
            let ids: Vec<SessionId> = (0..threads)
                .map(|_| m.open(&spec, &rng.normal_vec(2 * 4, 0.1), 2).unwrap())
                .collect();
            let chunks: Vec<Vec<f32>> =
                (0..threads).map(|_| rng.normal_vec(feed_points * 4, 0.1)).collect();
            let t0 = Instant::now();
            if par {
                std::thread::scope(|scope| {
                    for (id, pts) in ids.iter().zip(&chunks) {
                        let m = &m;
                        scope.spawn(move || {
                            for _ in 0..feeds {
                                m.feed(*id, pts, feed_points).unwrap();
                            }
                        });
                    }
                });
            } else {
                for (id, pts) in ids.iter().zip(&chunks) {
                    for _ in 0..feeds {
                        m.feed(*id, pts, feed_points).unwrap();
                    }
                }
            }
            t0.elapsed()
        };
        // Best of three attempts: `cargo test` runs other tests
        // concurrently, so a single measurement can be squeezed by
        // unrelated load. A table-wide lock can never reach the threshold
        // regardless of retries; genuine parallelism reaches it easily.
        let mut best_ratio = f64::INFINITY;
        for _ in 0..3 {
            let serial = run(false);
            let parallel = run(true);
            let ratio = parallel.as_secs_f64() / serial.as_secs_f64();
            best_ratio = best_ratio.min(ratio);
            if best_ratio < 0.9 {
                return;
            }
        }
        panic!(
            "distinct-session feeds did not scale on {threads} threads: \
             best parallel/serial ratio {best_ratio:.2} (need < 0.9)"
        );
    }
}
