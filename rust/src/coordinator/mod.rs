//! The L3 coordinator: a request router + dynamic batcher serving
//! signature/logsignature computations over two backends — the native Rust
//! engine and the AOT-compiled XLA artifacts — plus stateful streaming
//! sessions implementing "keeping the signature up-to-date" (§5.5).
//!
//! Shape of the system (vLLM-router-like):
//!
//! ```text
//!  client ──submit──▶ Router ──(streaming request?)──▶ Session table ──▶ Path (native)
//!                       │        (sharded, memory-bounded, LRU+TTL eviction)
//!                       ├──(shape matches an artifact?)──▶ Batcher ──▶ XLA Engine
//!                       │                                    (pad to artifact batch)
//!                       └──(no artifact)──▶ native microbatcher ──▶ lane-fused sweep
//!                                            (same-spec signatures, ta::batch)
//! ```
//!
//! Batching exists because XLA executables are compiled for fixed shapes:
//! requests with the same `(kind, L, d, N)` are gathered until the artifact
//! batch fills or a linger deadline passes, padded with zero rows, executed
//! once, and scattered back to callers. Property tests assert padding never
//! leaks between requests.
//!
//! Streaming requests (`OpenStream` / `Feed` / `QueryInterval` /
//! `LogSigQueryInterval` / `CloseStream`) flow through the same
//! [`Coordinator::call`] front door — so latency and error metrics cover
//! them — and are served by the [`SessionManager`], a sharded table of
//! `Arc<Mutex<Path>>` sessions whose resident precomputed storage is
//! bounded by [`SessionConfig::budget_bytes`] (LRU eviction) and
//! [`SessionConfig::ttl`] (idle expiry).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod session;

pub use batcher::{BatchBackend, BatchShape, Batcher};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{Backend, Coordinator, CoordinatorConfig, Request, Response};
pub use session::{SessionConfig, SessionId, SessionManager};
