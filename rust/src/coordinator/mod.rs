//! The L3 coordinator: a request router + dynamic batcher serving
//! signature/logsignature computations over two backends — the native Rust
//! engine and the AOT-compiled XLA artifacts — plus stateful streaming
//! sessions implementing "keeping the signature up-to-date" (§5.5).
//!
//! Shape of the system (vLLM-router-like):
//!
//! ```text
//!  client ──submit──▶ Router ──(streaming request?)──▶ Session table ──▶ Path (native)
//!                       │         │  (sharded, memory-bounded, LRU+TTL eviction)
//!                       │         └─(Feed, ≥2 sessions on one spec)──▶ Feed lane
//!                       │              (ExecPlanner-gated)     (Path::update_batch sweep)
//!                       ├──(shape matches an artifact?)──▶ Batcher ──▶ XLA Engine
//!                       │                                    (pad to artifact batch)
//!                       └──(no artifact)──▶ ExecPlanner ──▶ native microbatcher
//!                             (adaptive per-shape capacity)   (lane-fused sweep, ta::batch;
//!                                          │                   Sig AND LogSig kinds — logsig
//!                                          │                   rows add a log + Words-basis
//!                                          │                   projection epilogue)
//!                                          └──(rare shape / capacity 1)──▶ direct scalar
//! ```
//!
//! **Adaptive dispatch**: every native request's shape is recorded into
//! the [`crate::exec::ExecPlanner`]'s observed shape-mix histogram, and
//! the planner — not the call sites — decides the execution strategy and
//! the microbatch capacity per shape ([`DispatchConfig`]). Shapes with
//! batch peers in recent traffic linger and lane-fuse; rare shapes (and
//! lone streaming feeders) serve directly with zero added latency.
//! `Signature` and `LogSignature` requests both ride this path (logsig
//! shapes key the mix under their own kind, so the two surfaces adapt on
//! their own traffic). The old `native_batch` knob survives as a
//! compatibility alias ([`CoordinatorConfig::with_native_batch`]),
//! including its documented `0` escape hatch: microbatching and the feed
//! lane fully off for every native request kind.
//!
//! **One batcher implementation**: the pending-queue / condvar /
//! deadline-recompute flusher machinery lives once, in
//! [`flusher::GroupBatcher`] — the XLA/native row [`Batcher`] and the
//! stateful [`FeedLane`] are thin instantiations, so concurrency fixes
//! (stale-linger recompute, missed wakeups) land exactly once.
//!
//! Batching exists because XLA executables are compiled for fixed shapes:
//! requests with the same `(kind, L, d, N)` are gathered until the artifact
//! batch fills or a linger deadline passes, padded with zero rows, executed
//! once, and scattered back to callers. Property tests assert padding never
//! leaks between requests.
//!
//! Streaming requests (`OpenStream` / `OpenWindow` / `Feed` /
//! `PollWindow` / `QueryInterval` / `LogSigQueryInterval` /
//! `CloseStream`) flow through the same
//! [`Coordinator::call`] front door — so latency and error metrics cover
//! them — and are served by the [`SessionManager`], a sharded table of
//! `Arc<Mutex<Path>>` sessions whose resident precomputed storage is
//! bounded by [`SessionConfig::budget_bytes`] (LRU eviction) and
//! [`SessionConfig::ttl`] (idle expiry). With a spill store configured
//! ([`SessionConfig::spill`], [`crate::state`]), eviction and expiry
//! *spill* sessions instead of destroying them — the next touch reloads
//! the path bitwise — and `SpillConfig::Disk` adds a write-behind feed
//! log so a restarted `serve-stream --state-dir` recovers every live
//! session.
//!
//! [`ShardedCoordinator`] stacks N logical coordinators behind one front
//! door: session ids stripe across shards ([`SessionConfig::first_id`] /
//! [`SessionConfig::id_stride`]) so [`crate::state::Placement`] locates a
//! session's shard by pure arithmetic on the id, and same-spec opens
//! co-locate in feed-lane-width groups so feed batching still engages
//! per shard.

pub mod batcher;
pub mod feedlane;
pub mod flusher;
pub mod metrics;
pub mod router;
pub(crate) mod rows;
pub mod session;
pub mod sharded;

pub use batcher::{BatchBackend, BatchShape, Batcher};
pub use feedlane::FeedLane;
pub use flusher::{GroupBatcher, GroupExecutor};
pub use metrics::{LatencyBuckets, Metrics, MetricsSnapshot, RequestKind};
pub use router::{Backend, Coordinator, CoordinatorConfig, DispatchConfig, Request, Response};
pub use session::{SessionConfig, SessionId, SessionManager};
pub use sharded::ShardedCoordinator;
