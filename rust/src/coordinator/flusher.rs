//! The **unified dynamic-batcher core**: one pending-queue / condvar /
//! deadline-recompute flusher implementation, generic over (key, item,
//! execute).
//!
//! Three gathering surfaces share this exact machinery — the XLA/native
//! row batcher ([`super::batcher::Batcher`], stateless `Signature` and
//! `LogSignature` microbatches) and the stateful feed lane
//! ([`super::feedlane::FeedLane`]) are thin instantiations. Before this
//! module, `feedlane.rs` deliberately mirrored `batcher.rs` line for line,
//! which meant every concurrency fix (the stale-linger deadline recompute,
//! the missed-wakeup handling) had to land twice; now they live in exactly
//! one place and are pinned by regression tests at this level.
//!
//! Semantics, shared by every instantiation:
//!
//! - Items submitted under one key coalesce into a pending group whose
//!   **capacity is fixed by the first submitter** (the adaptive planner
//!   may quote later submitters a different capacity; they must still
//!   join this group rather than fork a parallel queue).
//! - A group that reaches its capacity executes **inline on the
//!   submitting thread** (tail latency stays off the flusher).
//! - Otherwise the flusher thread fires the group once its linger
//!   deadline passes. After executing due groups the flusher re-acquires
//!   the lock and **recomputes the earliest deadline**: a submit that
//!   landed mid-execution dropped its condvar notify on the floor (nobody
//!   was waiting), so sleeping on a deadline captured before execution
//!   would let that group idle a stale full linger — flushing at up to 2x
//!   linger.
//! - Dropping the batcher shuts the flusher down and force-flushes every
//!   pending group, so no submitter is left waiting on a dead queue.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Executes one flushed group of pending items. The executor owns result
/// delivery (items typically carry their response channel), so the
/// generic core never needs to know what an item produces.
pub trait GroupExecutor: Send + Sync + 'static {
    /// Queue identity. Submissions with equal keys coalesce.
    type Key: Copy + Eq + Hash + Send + Sync + 'static;
    /// One pending unit of work.
    type Item: Send + 'static;

    /// Run one group. `capacity` is the first submitter's quoted capacity
    /// (the group's execution width); `items` holds between 1 and
    /// `capacity` entries in submission order.
    fn execute(&self, key: Self::Key, capacity: usize, items: Vec<Self::Item>);
}

struct Pending<I> {
    /// Fixed by the first submitter of this group (see module docs).
    capacity: usize,
    items: Vec<I>,
    deadline: Instant,
}

struct Shared<K, I> {
    queues: Mutex<HashMap<K, Pending<I>>>,
    wake: Condvar,
    shutdown: Mutex<bool>,
}

/// The generic dynamic batcher (see the module docs for semantics).
pub struct GroupBatcher<E: GroupExecutor> {
    shared: Arc<Shared<E::Key, E::Item>>,
    executor: Arc<E>,
    linger: Duration,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl<E: GroupExecutor> GroupBatcher<E> {
    /// `thread_name` labels the flusher thread (one per instantiation, so
    /// stack traces attribute lingering batches to the right surface).
    pub fn new(thread_name: &str, executor: Arc<E>, linger: Duration) -> GroupBatcher<E> {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            wake: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let flusher = {
            let shared = Arc::clone(&shared);
            let executor = Arc::clone(&executor);
            std::thread::Builder::new()
                .name(thread_name.into())
                .spawn(move || flusher_loop(shared, executor, linger))
                .expect("spawn batcher flusher")
        };
        GroupBatcher { shared, executor, linger, flusher: Some(flusher) }
    }

    /// Submit one item under `key` with the capacity quoted for it. If the
    /// group fills, it executes on the calling thread before returning;
    /// otherwise the flusher fires it at the linger deadline.
    pub fn submit(&self, key: E::Key, capacity: usize, item: E::Item) -> anyhow::Result<()> {
        anyhow::ensure!(capacity >= 1, "batch capacity must be at least 1");
        let full = {
            let mut queues = self.shared.queues.lock().unwrap();
            let pending = queues.entry(key).or_insert_with(|| Pending {
                capacity,
                items: Vec::with_capacity(capacity),
                deadline: Instant::now() + self.linger,
            });
            pending.items.push(item);
            if pending.items.len() >= pending.capacity {
                queues.remove(&key)
            } else {
                self.shared.wake.notify_one();
                None
            }
        };
        if let Some(pending) = full {
            self.executor.execute(key, pending.capacity, pending.items);
        }
        Ok(())
    }

    /// Force-flush everything (used on shutdown and by tests).
    pub fn flush(&self) {
        let drained: Vec<(E::Key, Pending<E::Item>)> = {
            let mut queues = self.shared.queues.lock().unwrap();
            queues.drain().collect()
        };
        for (key, pending) in drained {
            self.executor.execute(key, pending.capacity, pending.items);
        }
    }
}

impl<E: GroupExecutor> Drop for GroupBatcher<E> {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.wake.notify_all();
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        self.flush();
    }
}

fn flusher_loop<E: GroupExecutor>(
    shared: Arc<Shared<E::Key, E::Item>>,
    executor: Arc<E>,
    linger: Duration,
) {
    loop {
        if *shared.shutdown.lock().unwrap() {
            return;
        }
        let mut due: Vec<(E::Key, Pending<E::Item>)> = vec![];
        {
            let mut queues = shared.queues.lock().unwrap();
            let now = Instant::now();
            let due_keys: Vec<E::Key> = queues
                .iter()
                .filter(|(_, p)| p.deadline <= now)
                .map(|(k, _)| *k)
                .collect();
            for k in due_keys {
                if let Some(p) = queues.remove(&k) {
                    due.push((k, p));
                }
            }
        }
        for (key, pending) in due {
            executor.execute(key, pending.capacity, pending.items);
        }
        // Re-acquire the lock and recompute the earliest deadline *after*
        // executing: a submit that landed mid-execution had its notify
        // dropped on the floor (nobody was waiting), so sleeping on a
        // deadline captured before execution would let that batch idle a
        // stale full linger — flushing at up to 2x linger.
        let guard = shared.queues.lock().unwrap();
        let now = Instant::now();
        if guard.values().any(|p| p.deadline <= now) {
            continue; // something became due while executing: drain first
        }
        // Sleep until the earliest deadline (or linger, when idle).
        let wait = guard
            .values()
            .map(|p| p.deadline)
            .min()
            .map(|dl| dl.saturating_duration_since(now))
            .unwrap_or(linger)
            .max(Duration::from_micros(100));
        let _unused = shared.wake.wait_timeout(guard, wait).unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::mpsc;

    /// Test executor: records (key, capacity, group size) per execution
    /// and acks every item's channel; optionally sleeps once to catch the
    /// flusher mid-execution.
    struct Recorder {
        executions: Mutex<Vec<(u32, usize, usize)>>,
        slow_once: AtomicBool,
        total_items: AtomicUsize,
    }

    impl Recorder {
        fn new() -> Arc<Recorder> {
            Arc::new(Recorder {
                executions: Mutex::new(vec![]),
                slow_once: AtomicBool::new(false),
                total_items: AtomicUsize::new(0),
            })
        }
    }

    impl GroupExecutor for Recorder {
        type Key = u32;
        type Item = (usize, mpsc::Sender<usize>);

        fn execute(&self, key: u32, capacity: usize, items: Vec<Self::Item>) {
            if self.slow_once.swap(false, Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(450));
            }
            self.executions.lock().unwrap().push((key, capacity, items.len()));
            self.total_items.fetch_add(items.len(), Ordering::SeqCst);
            for (v, tx) in items {
                let _ = tx.send(v);
            }
        }
    }

    #[test]
    fn full_group_executes_inline_and_keys_isolate() {
        let rec = Recorder::new();
        // Linger long enough that only fullness can flush.
        let b = GroupBatcher::new("test-flusher", Arc::clone(&rec), Duration::from_secs(60));
        let (tx, rx) = mpsc::channel();
        b.submit(7, 2, (1, tx.clone())).unwrap();
        // A different key must not fill key 7's group.
        b.submit(8, 2, (9, tx.clone())).unwrap();
        assert!(rec.executions.lock().unwrap().is_empty());
        b.submit(7, 2, (2, tx)).unwrap();
        // Key 7 filled: executed inline, capacity 2, both items, in order.
        assert_eq!(*rec.executions.lock().unwrap(), vec![(7, 2, 2)]);
        let got: Vec<usize> = (0..2).map(|_| rx.try_recv().unwrap()).collect();
        assert_eq!(got, vec![1, 2]);
        // Drop force-flushes the lone key-8 item.
        drop(b);
        assert_eq!(rec.total_items.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn capacity_is_fixed_by_the_first_submitter() {
        let rec = Recorder::new();
        let b = GroupBatcher::new("test-flusher", Arc::clone(&rec), Duration::from_secs(60));
        let (tx, _rx) = mpsc::channel();
        b.submit(1, 2, (0, tx.clone())).unwrap();
        // The second submitter quotes a wider capacity; the group still
        // executes at the first quote once two items are pending.
        b.submit(1, 8, (1, tx)).unwrap();
        assert_eq!(*rec.executions.lock().unwrap(), vec![(1, 2, 2)]);
    }

    #[test]
    fn linger_flushes_partial_groups() {
        let rec = Recorder::new();
        let b = GroupBatcher::new("test-flusher", Arc::clone(&rec), Duration::from_millis(10));
        let (tx, rx) = mpsc::channel();
        b.submit(3, 8, (5, tx)).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), 5);
        assert_eq!(*rec.executions.lock().unwrap(), vec![(3, 8, 1)]);
        drop(b);
        assert_eq!(rec.total_items.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_capacity_rejected() {
        let rec = Recorder::new();
        let b = GroupBatcher::new("test-flusher", rec, Duration::from_millis(10));
        let (tx, _rx) = mpsc::channel();
        assert!(b.submit(0, 0, (0, tx)).is_err());
    }

    #[test]
    fn submit_during_execution_is_not_delayed_by_a_stale_deadline() {
        // The unified regression for the missed-wakeup bug, pinned at the
        // generic level so every instantiation inherits the fix: a submit
        // landing while the flusher is mid-`execute` loses its notify, and
        // a flusher that slept on a deadline computed *before* execution
        // would flush the new group at up to 2x linger late. Timeline with
        // linger = 300ms and a 450ms first execution: A's group flushes at
        // ~300ms and executes until ~750ms; B lands at ~375ms (deadline
        // ~675ms). Fixed flusher: B flushes when the execution ends
        // (waited ~375ms). Stale-deadline flusher: B waits a further full
        // linger (waited ~675ms). The 550ms bound sits between the two.
        let rec = Recorder::new();
        let linger = Duration::from_millis(300);
        let b = GroupBatcher::new("test-flusher", Arc::clone(&rec), linger);
        let (tx, rx_a) = mpsc::channel();
        rec.slow_once.store(true, Ordering::SeqCst);
        b.submit(1, 8, (0, tx)).unwrap(); // never fills: only the linger flushes
        std::thread::sleep(Duration::from_millis(375));
        let (tx_b, rx_b) = mpsc::channel();
        let t0 = Instant::now();
        b.submit(1, 8, (1, tx_b)).unwrap();
        assert_eq!(rx_b.recv_timeout(Duration::from_secs(5)).unwrap(), 1);
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_millis(550),
            "group flushed only after {waited:?} (stale linger deadline)"
        );
        let _ = rx_a.recv_timeout(Duration::from_secs(5));
    }
}
