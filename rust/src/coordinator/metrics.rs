//! Coordinator metrics: lock-free counters and per-request-kind latency
//! histograms, snapshotted for reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// The request kinds latency is tracked for, one histogram each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    Signature,
    LogSignature,
    SignatureGrad,
    OpenStream,
    Feed,
    QueryInterval,
    LogSigQueryInterval,
    CloseStream,
    OpenWindow,
    PollWindow,
}

/// Number of [`RequestKind`] variants (histogram array length).
pub const REQUEST_KINDS: usize = 10;

impl RequestKind {
    /// Every kind, in display order.
    pub const ALL: [RequestKind; REQUEST_KINDS] = [
        RequestKind::Signature,
        RequestKind::LogSignature,
        RequestKind::SignatureGrad,
        RequestKind::OpenStream,
        RequestKind::Feed,
        RequestKind::QueryInterval,
        RequestKind::LogSigQueryInterval,
        RequestKind::CloseStream,
        RequestKind::OpenWindow,
        RequestKind::PollWindow,
    ];

    pub fn label(self) -> &'static str {
        match self {
            RequestKind::Signature => "sig",
            RequestKind::LogSignature => "logsig",
            RequestKind::SignatureGrad => "siggrad",
            RequestKind::OpenStream => "open",
            RequestKind::Feed => "feed",
            RequestKind::QueryInterval => "query",
            RequestKind::LogSigQueryInterval => "logsig_query",
            RequestKind::CloseStream => "close",
            RequestKind::OpenWindow => "open_window",
            RequestKind::PollWindow => "poll_window",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// Buckets per latency histogram; bucket `b` counts observations with
/// `floor(log2(ns)) == b`, so the range spans 1 ns to ~2.1 s (the last
/// bucket absorbs everything slower).
pub const LATENCY_BUCKETS: usize = 32;

/// Lock-free log2-bucket latency histogram. Recording is one relaxed
/// `fetch_add`, so it sits on the serving hot path without contending;
/// quantiles are read off a [`LatencyBuckets`] snapshot.
#[derive(Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl LatencyHistogram {
    fn bucket(ns: u64) -> usize {
        // floor(log2(ns)), with 0 ns in bucket 0 and the tail clamped.
        (63 - ns.max(1).leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
    }

    pub fn record(&self, dt: Duration) {
        let ns = dt.as_nanos().min(u128::from(u64::MAX)) as u64;
        self.buckets[LatencyHistogram::bucket(ns)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LatencyBuckets {
        LatencyBuckets {
            counts: std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed)),
        }
    }
}

/// A point-in-time copy of one histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyBuckets {
    pub counts: [u64; LATENCY_BUCKETS],
}

impl Default for LatencyBuckets {
    fn default() -> Self {
        LatencyBuckets { counts: [0; LATENCY_BUCKETS] }
    }
}

impl LatencyBuckets {
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The quantile `q` (in `[0, 1]`) as the **upper edge** of the bucket
    /// where the cumulative count crosses the rank — an at-most-2x
    /// overestimate, the right bias for an SLO gate. `ZERO` when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total); // lint: non-row cast
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Duration::from_nanos(1u64 << (b + 1).min(63));
            }
        }
        unreachable!("cumulative count reaches total")
    }
}

/// Shared counters. All methods are cheap and thread-safe.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub native_requests: AtomicU64,
    pub xla_requests: AtomicU64,
    /// Streaming (session) requests served through `Coordinator::call`.
    pub stream_requests: AtomicU64,
    /// Logsignature requests served (stateless `LogSignature` on either
    /// backend plus streaming `LogSigQueryInterval`) — the logsig surface
    /// now rides the same adaptive microbatcher as signatures, so its
    /// share of traffic is worth watching on its own.
    pub logsig_requests: AtomicU64,
    pub batches: AtomicU64,
    /// Total rows submitted to XLA including padding.
    pub padded_rows: AtomicU64,
    /// Rows that carried real requests.
    pub real_rows: AtomicU64,
    /// Failed *requests* (counted once per request, at the `call` layer).
    pub errors: AtomicU64,
    /// Failed *batch executions* (one per failed backend run; each such
    /// failure surfaces as one `errors` increment per affected request).
    pub batch_failures: AtomicU64,
    /// Total latency across requests, nanoseconds.
    pub latency_ns: AtomicU64,
    pub sessions_opened: AtomicU64,
    pub session_updates: AtomicU64,
    /// Gauge: sessions currently open.
    pub open_sessions: AtomicU64,
    /// Gauge: bytes of precomputed `Path` storage currently resident
    /// across all sessions.
    pub session_bytes: AtomicU64,
    /// Sessions evicted to enforce the memory budget (LRU order).
    pub sessions_evicted: AtomicU64,
    /// Sessions expired by the idle-TTL sweeper.
    pub sessions_expired: AtomicU64,
    /// Sessions spilled to the session store (budget/TTL pressure with a
    /// spill store configured — the state survives, cold).
    pub sessions_spilled: AtomicU64,
    /// Spilled sessions transparently reloaded on their next touch.
    pub sessions_reloaded: AtomicU64,
    /// Gauge: bytes of session state currently spilled to the store.
    pub spilled_bytes: AtomicU64,
    /// Records appended to the feed-delta log (write-behind; durable at
    /// the sweeper's next fsync-batched flush).
    pub wal_appends: AtomicU64,
    /// Units of native work executed with the scalar strategy (one serial
    /// sweep per path / per feed) — see [`crate::exec::ExecPlan`].
    pub dispatch_scalar: AtomicU64,
    /// Units executed with chunked Chen-identity stream parallelism.
    pub dispatch_stream_parallel: AtomicU64,
    /// Units executed lane-fused across a batch (microbatch flushes and
    /// feed-lane sweeps).
    pub dispatch_lane_fused: AtomicU64,
    /// Lane-fused *session feed* sweeps: flushed feed groups (>= 2
    /// sessions) advanced through one `Path::update_batch` call.
    pub feed_lane_batches: AtomicU64,
    /// Gauge: distinct request shapes currently in the planner's observed
    /// shape-mix window.
    pub shape_mix_shapes: AtomicU64,
    /// Rolling-window sessions: `PollWindow` requests served.
    pub window_polls: AtomicU64,
    /// Rolling-window sessions: slides delivered across all polls (each
    /// is one signature/logsignature row the server emitted via the
    /// O(1) sliding update instead of a client recompute).
    pub window_slides: AtomicU64,
    /// Lane-fused *window* sweeps: flushed feed groups whose windowed
    /// sessions (>= 2) advanced their rolling windows through one
    /// `RollingWindow::advance_batch` call instead of per-session loops.
    pub window_slide_batches: AtomicU64,
    /// Slides emitted by those batched sweeps (a subset of the slides
    /// later counted into `window_slides` when a poll delivers them).
    pub window_slides_batched: AtomicU64,
    /// Per-request-kind latency histograms, indexed by
    /// [`RequestKind::index`].
    pub latency: [LatencyHistogram; REQUEST_KINDS],
}

/// A point-in-time copy of the metrics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub native_requests: u64,
    pub xla_requests: u64,
    pub stream_requests: u64,
    pub logsig_requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub real_rows: u64,
    pub errors: u64,
    pub batch_failures: u64,
    pub mean_latency: Duration,
    pub sessions_opened: u64,
    pub session_updates: u64,
    pub open_sessions: u64,
    pub session_bytes: u64,
    pub sessions_evicted: u64,
    pub sessions_expired: u64,
    pub sessions_spilled: u64,
    pub sessions_reloaded: u64,
    pub spilled_bytes: u64,
    pub wal_appends: u64,
    pub dispatch_scalar: u64,
    pub dispatch_stream_parallel: u64,
    pub dispatch_lane_fused: u64,
    pub feed_lane_batches: u64,
    pub shape_mix_shapes: u64,
    pub window_polls: u64,
    pub window_slides: u64,
    pub window_slide_batches: u64,
    pub window_slides_batched: u64,
    pub latency: [LatencyBuckets; REQUEST_KINDS],
}

impl Metrics {
    /// Record one request's latency: into the global mean and into the
    /// kind's own histogram.
    pub fn record_latency(&self, kind: RequestKind, dt: Duration) {
        self.latency_ns
            .fetch_add(dt.as_nanos().min(u128::from(u64::MAX)) as u64, Ordering::Relaxed);
        self.latency[kind.index()].record(dt);
    }

    /// The histogram for one request kind (benches read p99 off this).
    pub fn latency_of(&self, kind: RequestKind) -> LatencyBuckets {
        self.latency[kind.index()].snapshot()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let latency = self.latency_ns.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests,
            native_requests: self.native_requests.load(Ordering::Relaxed),
            xla_requests: self.xla_requests.load(Ordering::Relaxed),
            stream_requests: self.stream_requests.load(Ordering::Relaxed),
            logsig_requests: self.logsig_requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            real_rows: self.real_rows.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batch_failures: self.batch_failures.load(Ordering::Relaxed),
            mean_latency: if requests == 0 {
                Duration::ZERO
            } else {
                Duration::from_nanos(latency / requests)
            },
            sessions_opened: self.sessions_opened.load(Ordering::Relaxed),
            session_updates: self.session_updates.load(Ordering::Relaxed),
            open_sessions: self.open_sessions.load(Ordering::Relaxed),
            session_bytes: self.session_bytes.load(Ordering::Relaxed),
            sessions_evicted: self.sessions_evicted.load(Ordering::Relaxed),
            sessions_expired: self.sessions_expired.load(Ordering::Relaxed),
            sessions_spilled: self.sessions_spilled.load(Ordering::Relaxed),
            sessions_reloaded: self.sessions_reloaded.load(Ordering::Relaxed),
            spilled_bytes: self.spilled_bytes.load(Ordering::Relaxed),
            wal_appends: self.wal_appends.load(Ordering::Relaxed),
            dispatch_scalar: self.dispatch_scalar.load(Ordering::Relaxed),
            dispatch_stream_parallel: self.dispatch_stream_parallel.load(Ordering::Relaxed),
            dispatch_lane_fused: self.dispatch_lane_fused.load(Ordering::Relaxed),
            feed_lane_batches: self.feed_lane_batches.load(Ordering::Relaxed),
            shape_mix_shapes: self.shape_mix_shapes.load(Ordering::Relaxed),
            window_polls: self.window_polls.load(Ordering::Relaxed),
            window_slides: self.window_slides.load(Ordering::Relaxed),
            window_slide_batches: self.window_slide_batches.load(Ordering::Relaxed),
            window_slides_batched: self.window_slides_batched.load(Ordering::Relaxed),
            latency: std::array::from_fn(|k| self.latency[k].snapshot()),
        }
    }

    /// Fraction of batch *slots* that were padding, across both batchers
    /// (0 when nothing ran). XLA pays real compute for padding slots; the
    /// native lane backend skips them, so for native microbatches this
    /// measures slot utilisation of the linger window, not wasted work.
    pub fn padding_ratio(&self) -> f64 {
        let padded = self.padded_rows.load(Ordering::Relaxed);
        let real = self.real_rows.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            1.0 - real as f64 / padded as f64 // lint: non-row cast
        }
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} (native={} xla={} stream={} logsig={}) batches={} rows={}/{} errors={} \
             batch_failures={} mean_latency={:?} sessions={} updates={} open={} \
             resident_bytes={} evicted={} expired={} spilled={} reloaded={} spilled_bytes={} \
             wal_appends={} window_polls={} window_slides={} window_slide_batches={} \
             window_slides_batched={}",
            self.requests,
            self.native_requests,
            self.xla_requests,
            self.stream_requests,
            self.logsig_requests,
            self.batches,
            self.real_rows,
            self.padded_rows,
            self.errors,
            self.batch_failures,
            self.mean_latency,
            self.sessions_opened,
            self.session_updates,
            self.open_sessions,
            self.session_bytes,
            self.sessions_evicted,
            self.sessions_expired,
            self.sessions_spilled,
            self.sessions_reloaded,
            self.spilled_bytes,
            self.wal_appends,
            self.window_polls,
            self.window_slides,
            self.window_slide_batches,
            self.window_slides_batched,
        )
    }

    /// Per-kind latency quantiles — one `kind=p50/p90/p99` clause per
    /// kind that served traffic (quantiles are log2-bucket upper edges).
    /// Empty when nothing was recorded, so callers can skip the line.
    pub fn render_latency(&self) -> String {
        let mut parts: Vec<String> = vec![];
        for kind in RequestKind::ALL {
            let h = &self.latency[kind.index()];
            if h.count() == 0 {
                continue;
            }
            parts.push(format!(
                "{}={:?}/{:?}/{:?}",
                kind.label(),
                h.quantile(0.50),
                h.quantile(0.90),
                h.quantile(0.99),
            ));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("latency[p50/p90/p99 {}]", parts.join(" "))
        }
    }

    /// The per-strategy dispatch summary — a separate line so callers
    /// compose it with [`MetricsSnapshot::render`] without duplication
    /// (the `serve` / `serve-stream` CLI subcommands print both).
    pub fn render_dispatch(&self) -> String {
        format!(
            "dispatch[scalar={} stream_parallel={} lane_fused={} feed_lane_batches={} \
             shape_mix={}]",
            self.dispatch_scalar,
            self.dispatch_stream_parallel,
            self.dispatch_lane_fused,
            self.feed_lane_batches,
            self.shape_mix_shapes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn snapshot_and_padding_ratio() {
        let m = Metrics::default();
        m.requests.store(4, Ordering::Relaxed);
        m.real_rows.store(6, Ordering::Relaxed);
        m.padded_rows.store(8, Ordering::Relaxed);
        m.record_latency(RequestKind::Signature, Duration::from_millis(8));
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.mean_latency, Duration::from_millis(2));
        assert!((m.padding_ratio() - 0.25).abs() < 1e-12);
        assert!(s.render().contains("requests=4"));
    }

    #[test]
    fn logsig_counter_roundtrips_and_renders() {
        let m = Metrics::default();
        m.logsig_requests.store(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.logsig_requests, 5);
        assert!(s.render().contains("logsig=5"));
    }

    #[test]
    fn session_gauges_roundtrip() {
        let m = Metrics::default();
        m.open_sessions.store(3, Ordering::Relaxed);
        m.session_bytes.store(4096, Ordering::Relaxed);
        m.sessions_evicted.store(2, Ordering::Relaxed);
        m.batch_failures.store(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.open_sessions, 3);
        assert_eq!(s.session_bytes, 4096);
        assert_eq!(s.sessions_evicted, 2);
        assert_eq!(s.sessions_expired, 0);
        assert_eq!(s.batch_failures, 1);
        assert!(s.render().contains("resident_bytes=4096"));
    }

    #[test]
    fn persistence_counters_roundtrip_and_render() {
        let m = Metrics::default();
        m.sessions_spilled.store(4, Ordering::Relaxed);
        m.sessions_reloaded.store(3, Ordering::Relaxed);
        m.spilled_bytes.store(2048, Ordering::Relaxed);
        m.wal_appends.store(17, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.sessions_spilled, 4);
        assert_eq!(s.sessions_reloaded, 3);
        assert_eq!(s.spilled_bytes, 2048);
        assert_eq!(s.wal_appends, 17);
        let line = s.render();
        assert!(line.contains("spilled=4"));
        assert!(line.contains("reloaded=3"));
        assert!(line.contains("spilled_bytes=2048"));
        assert!(line.contains("wal_appends=17"));
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().mean_latency, Duration::ZERO);
        assert_eq!(m.padding_ratio(), 0.0);
        // No traffic -> no latency line at all (callers skip printing it).
        assert_eq!(m.snapshot().render_latency(), "");
    }

    #[test]
    fn latency_buckets_are_log2() {
        assert_eq!(LatencyHistogram::bucket(0), 0);
        assert_eq!(LatencyHistogram::bucket(1), 0);
        assert_eq!(LatencyHistogram::bucket(2), 1);
        assert_eq!(LatencyHistogram::bucket(3), 1);
        assert_eq!(LatencyHistogram::bucket(1024), 10);
        assert_eq!(LatencyHistogram::bucket(1025), 10);
        // The tail clamps instead of indexing out of range.
        assert_eq!(LatencyHistogram::bucket(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn latency_quantiles_read_bucket_upper_edges() {
        let h = LatencyHistogram::default();
        // 90 fast observations (~1 us) and 10 slow ones (~1 ms).
        for _ in 0..90 {
            h.record(Duration::from_nanos(1000)); // bucket 9, edge 1024
        }
        for _ in 0..10 {
            h.record(Duration::from_nanos(1_000_000)); // bucket 19
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.quantile(0.50), Duration::from_nanos(1 << 10));
        assert_eq!(s.quantile(0.90), Duration::from_nanos(1 << 10));
        // p99 lands in the slow bucket: upper edge 2^20 ns.
        assert_eq!(s.quantile(0.99), Duration::from_nanos(1 << 20));
        assert_eq!(s.quantile(1.0), Duration::from_nanos(1 << 20));
    }

    #[test]
    fn per_kind_latency_renders_only_active_kinds() {
        let m = Metrics::default();
        m.record_latency(RequestKind::Feed, Duration::from_micros(3));
        m.record_latency(RequestKind::Feed, Duration::from_micros(5));
        m.record_latency(RequestKind::PollWindow, Duration::from_micros(1));
        let s = m.snapshot();
        assert_eq!(s.latency[RequestKind::Feed.index()].count(), 2);
        let line = s.render_latency();
        assert!(line.starts_with("latency[p50/p90/p99 "), "line: {line}");
        assert!(line.contains("feed="), "line: {line}");
        assert!(line.contains("poll_window="), "line: {line}");
        // Kinds that served nothing stay out of the line entirely.
        assert!(!line.contains("siggrad="), "line: {line}");
    }

    #[test]
    fn window_counters_roundtrip_and_render() {
        let m = Metrics::default();
        m.window_polls.store(6, Ordering::Relaxed);
        m.window_slides.store(42, Ordering::Relaxed);
        m.window_slide_batches.store(3, Ordering::Relaxed);
        m.window_slides_batched.store(17, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.window_polls, 6);
        assert_eq!(s.window_slides, 42);
        assert_eq!(s.window_slide_batches, 3);
        assert_eq!(s.window_slides_batched, 17);
        let line = s.render();
        assert!(line.contains("window_polls=6"));
        assert!(line.contains("window_slides=42"));
        assert!(line.contains("window_slide_batches=3"));
        assert!(line.contains("window_slides_batched=17"));
    }

    #[test]
    fn dispatch_counters_roundtrip_and_render() {
        let m = Metrics::default();
        m.dispatch_scalar.store(3, Ordering::Relaxed);
        m.dispatch_stream_parallel.store(2, Ordering::Relaxed);
        m.dispatch_lane_fused.store(5, Ordering::Relaxed);
        m.feed_lane_batches.store(4, Ordering::Relaxed);
        m.shape_mix_shapes.store(7, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.dispatch_scalar, 3);
        assert_eq!(s.dispatch_stream_parallel, 2);
        assert_eq!(s.dispatch_lane_fused, 5);
        assert_eq!(s.feed_lane_batches, 4);
        assert_eq!(s.shape_mix_shapes, 7);
        let line = s.render_dispatch();
        assert!(line.contains("lane_fused=5"));
        assert!(line.contains("feed_lane_batches=4"));
        assert!(line.contains("shape_mix=7"));
        // render() deliberately does NOT embed the dispatch line — the
        // CLI prints both, and embedding would duplicate it.
        assert!(!s.render().contains("dispatch["));
    }
}
