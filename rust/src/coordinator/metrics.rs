//! Coordinator metrics: lock-free counters, snapshotted for reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Shared counters. All methods are cheap and thread-safe.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub native_requests: AtomicU64,
    pub xla_requests: AtomicU64,
    /// Streaming (session) requests served through `Coordinator::call`.
    pub stream_requests: AtomicU64,
    /// Logsignature requests served (stateless `LogSignature` on either
    /// backend plus streaming `LogSigQueryInterval`) — the logsig surface
    /// now rides the same adaptive microbatcher as signatures, so its
    /// share of traffic is worth watching on its own.
    pub logsig_requests: AtomicU64,
    pub batches: AtomicU64,
    /// Total rows submitted to XLA including padding.
    pub padded_rows: AtomicU64,
    /// Rows that carried real requests.
    pub real_rows: AtomicU64,
    /// Failed *requests* (counted once per request, at the `call` layer).
    pub errors: AtomicU64,
    /// Failed *batch executions* (one per failed backend run; each such
    /// failure surfaces as one `errors` increment per affected request).
    pub batch_failures: AtomicU64,
    /// Total latency across requests, nanoseconds.
    pub latency_ns: AtomicU64,
    pub sessions_opened: AtomicU64,
    pub session_updates: AtomicU64,
    /// Gauge: sessions currently open.
    pub open_sessions: AtomicU64,
    /// Gauge: bytes of precomputed `Path` storage currently resident
    /// across all sessions.
    pub session_bytes: AtomicU64,
    /// Sessions evicted to enforce the memory budget (LRU order).
    pub sessions_evicted: AtomicU64,
    /// Sessions expired by the idle-TTL sweeper.
    pub sessions_expired: AtomicU64,
    /// Sessions spilled to the session store (budget/TTL pressure with a
    /// spill store configured — the state survives, cold).
    pub sessions_spilled: AtomicU64,
    /// Spilled sessions transparently reloaded on their next touch.
    pub sessions_reloaded: AtomicU64,
    /// Gauge: bytes of session state currently spilled to the store.
    pub spilled_bytes: AtomicU64,
    /// Records appended to the feed-delta log (write-behind; durable at
    /// the sweeper's next fsync-batched flush).
    pub wal_appends: AtomicU64,
    /// Units of native work executed with the scalar strategy (one serial
    /// sweep per path / per feed) — see [`crate::exec::ExecPlan`].
    pub dispatch_scalar: AtomicU64,
    /// Units executed with chunked Chen-identity stream parallelism.
    pub dispatch_stream_parallel: AtomicU64,
    /// Units executed lane-fused across a batch (microbatch flushes and
    /// feed-lane sweeps).
    pub dispatch_lane_fused: AtomicU64,
    /// Lane-fused *session feed* sweeps: flushed feed groups (>= 2
    /// sessions) advanced through one `Path::update_batch` call.
    pub feed_lane_batches: AtomicU64,
    /// Gauge: distinct request shapes currently in the planner's observed
    /// shape-mix window.
    pub shape_mix_shapes: AtomicU64,
}

/// A point-in-time copy of the metrics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub native_requests: u64,
    pub xla_requests: u64,
    pub stream_requests: u64,
    pub logsig_requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub real_rows: u64,
    pub errors: u64,
    pub batch_failures: u64,
    pub mean_latency: Duration,
    pub sessions_opened: u64,
    pub session_updates: u64,
    pub open_sessions: u64,
    pub session_bytes: u64,
    pub sessions_evicted: u64,
    pub sessions_expired: u64,
    pub sessions_spilled: u64,
    pub sessions_reloaded: u64,
    pub spilled_bytes: u64,
    pub wal_appends: u64,
    pub dispatch_scalar: u64,
    pub dispatch_stream_parallel: u64,
    pub dispatch_lane_fused: u64,
    pub feed_lane_batches: u64,
    pub shape_mix_shapes: u64,
}

impl Metrics {
    pub fn record_latency(&self, dt: Duration) {
        self.latency_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let latency = self.latency_ns.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests,
            native_requests: self.native_requests.load(Ordering::Relaxed),
            xla_requests: self.xla_requests.load(Ordering::Relaxed),
            stream_requests: self.stream_requests.load(Ordering::Relaxed),
            logsig_requests: self.logsig_requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            real_rows: self.real_rows.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batch_failures: self.batch_failures.load(Ordering::Relaxed),
            mean_latency: if requests == 0 {
                Duration::ZERO
            } else {
                Duration::from_nanos(latency / requests)
            },
            sessions_opened: self.sessions_opened.load(Ordering::Relaxed),
            session_updates: self.session_updates.load(Ordering::Relaxed),
            open_sessions: self.open_sessions.load(Ordering::Relaxed),
            session_bytes: self.session_bytes.load(Ordering::Relaxed),
            sessions_evicted: self.sessions_evicted.load(Ordering::Relaxed),
            sessions_expired: self.sessions_expired.load(Ordering::Relaxed),
            sessions_spilled: self.sessions_spilled.load(Ordering::Relaxed),
            sessions_reloaded: self.sessions_reloaded.load(Ordering::Relaxed),
            spilled_bytes: self.spilled_bytes.load(Ordering::Relaxed),
            wal_appends: self.wal_appends.load(Ordering::Relaxed),
            dispatch_scalar: self.dispatch_scalar.load(Ordering::Relaxed),
            dispatch_stream_parallel: self.dispatch_stream_parallel.load(Ordering::Relaxed),
            dispatch_lane_fused: self.dispatch_lane_fused.load(Ordering::Relaxed),
            feed_lane_batches: self.feed_lane_batches.load(Ordering::Relaxed),
            shape_mix_shapes: self.shape_mix_shapes.load(Ordering::Relaxed),
        }
    }

    /// Fraction of batch *slots* that were padding, across both batchers
    /// (0 when nothing ran). XLA pays real compute for padding slots; the
    /// native lane backend skips them, so for native microbatches this
    /// measures slot utilisation of the linger window, not wasted work.
    pub fn padding_ratio(&self) -> f64 {
        let padded = self.padded_rows.load(Ordering::Relaxed);
        let real = self.real_rows.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            1.0 - real as f64 / padded as f64 // lint: non-row cast
        }
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} (native={} xla={} stream={} logsig={}) batches={} rows={}/{} errors={} \
             batch_failures={} mean_latency={:?} sessions={} updates={} open={} \
             resident_bytes={} evicted={} expired={} spilled={} reloaded={} spilled_bytes={} \
             wal_appends={}",
            self.requests,
            self.native_requests,
            self.xla_requests,
            self.stream_requests,
            self.logsig_requests,
            self.batches,
            self.real_rows,
            self.padded_rows,
            self.errors,
            self.batch_failures,
            self.mean_latency,
            self.sessions_opened,
            self.session_updates,
            self.open_sessions,
            self.session_bytes,
            self.sessions_evicted,
            self.sessions_expired,
            self.sessions_spilled,
            self.sessions_reloaded,
            self.spilled_bytes,
            self.wal_appends,
        )
    }

    /// The per-strategy dispatch summary — a separate line so callers
    /// compose it with [`MetricsSnapshot::render`] without duplication
    /// (the `serve` / `serve-stream` CLI subcommands print both).
    pub fn render_dispatch(&self) -> String {
        format!(
            "dispatch[scalar={} stream_parallel={} lane_fused={} feed_lane_batches={} \
             shape_mix={}]",
            self.dispatch_scalar,
            self.dispatch_stream_parallel,
            self.dispatch_lane_fused,
            self.feed_lane_batches,
            self.shape_mix_shapes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn snapshot_and_padding_ratio() {
        let m = Metrics::default();
        m.requests.store(4, Ordering::Relaxed);
        m.real_rows.store(6, Ordering::Relaxed);
        m.padded_rows.store(8, Ordering::Relaxed);
        m.record_latency(Duration::from_millis(8));
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.mean_latency, Duration::from_millis(2));
        assert!((m.padding_ratio() - 0.25).abs() < 1e-12);
        assert!(s.render().contains("requests=4"));
    }

    #[test]
    fn logsig_counter_roundtrips_and_renders() {
        let m = Metrics::default();
        m.logsig_requests.store(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.logsig_requests, 5);
        assert!(s.render().contains("logsig=5"));
    }

    #[test]
    fn session_gauges_roundtrip() {
        let m = Metrics::default();
        m.open_sessions.store(3, Ordering::Relaxed);
        m.session_bytes.store(4096, Ordering::Relaxed);
        m.sessions_evicted.store(2, Ordering::Relaxed);
        m.batch_failures.store(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.open_sessions, 3);
        assert_eq!(s.session_bytes, 4096);
        assert_eq!(s.sessions_evicted, 2);
        assert_eq!(s.sessions_expired, 0);
        assert_eq!(s.batch_failures, 1);
        assert!(s.render().contains("resident_bytes=4096"));
    }

    #[test]
    fn persistence_counters_roundtrip_and_render() {
        let m = Metrics::default();
        m.sessions_spilled.store(4, Ordering::Relaxed);
        m.sessions_reloaded.store(3, Ordering::Relaxed);
        m.spilled_bytes.store(2048, Ordering::Relaxed);
        m.wal_appends.store(17, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.sessions_spilled, 4);
        assert_eq!(s.sessions_reloaded, 3);
        assert_eq!(s.spilled_bytes, 2048);
        assert_eq!(s.wal_appends, 17);
        let line = s.render();
        assert!(line.contains("spilled=4"));
        assert!(line.contains("reloaded=3"));
        assert!(line.contains("spilled_bytes=2048"));
        assert!(line.contains("wal_appends=17"));
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().mean_latency, Duration::ZERO);
        assert_eq!(m.padding_ratio(), 0.0);
    }

    #[test]
    fn dispatch_counters_roundtrip_and_render() {
        let m = Metrics::default();
        m.dispatch_scalar.store(3, Ordering::Relaxed);
        m.dispatch_stream_parallel.store(2, Ordering::Relaxed);
        m.dispatch_lane_fused.store(5, Ordering::Relaxed);
        m.feed_lane_batches.store(4, Ordering::Relaxed);
        m.shape_mix_shapes.store(7, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.dispatch_scalar, 3);
        assert_eq!(s.dispatch_stream_parallel, 2);
        assert_eq!(s.dispatch_lane_fused, 5);
        assert_eq!(s.feed_lane_batches, 4);
        assert_eq!(s.shape_mix_shapes, 7);
        let line = s.render_dispatch();
        assert!(line.contains("lane_fused=5"));
        assert!(line.contains("feed_lane_batches=4"));
        assert!(line.contains("shape_mix=7"));
        // render() deliberately does NOT embed the dispatch line — the
        // CLI prints both, and embedding would duplicate it.
        assert!(!s.render().contains("dispatch["));
    }
}
