//! # signax
//!
//! A Rust + JAX + Pallas reproduction of *"Signatory: differentiable
//! computations of the signature and logsignature transforms, on both CPU
//! and GPU"* (Kidger & Lyons, ICLR 2021).
//!
//! The crate is organised in three layers, with two cross-cutting
//! layers (execution planning, durable state):
//!
//! - **Native engine** ([`ta`], [`signature`], [`logsignature`], [`words`],
//!   [`path`], [`parallel`]): the full algorithmic content of the paper —
//!   truncated tensor algebra, the fused multiply-exponentiate (§4.1),
//!   handwritten backward passes exploiting signature reversibility
//!   (App. C), the Lyndon/Words logsignature bases (§4.3, App. A.2), and
//!   the `Path` precomputation class with O(1) interval queries (§4.2).
//!   Beyond the paper, the backward pass is parallel over the *stream* as
//!   well as the batch: a chunked Chen-identity factorisation
//!   (`Sig = L_c ⊠ M_c ⊠ R_c`) derives per-chunk cotangents with two
//!   ⊠-VJPs so the reversible reverse sweeps run concurrently — see
//!   [`signature::backward`]. Batched work additionally runs on the
//!   **batch-lane engine** ([`ta::batch`]): blocks of same-spec signatures
//!   advance through lane-interleaved fused sweeps that vectorise *across*
//!   the batch — the winning strategy for the serving regime of many short
//!   streams, and bitwise identical per lane to per-path dispatch
//!   ([`signature::signature_batch`],
//!   [`signature::signature_batch_vjp`], `deepsig::train_step`,
//!   [`path::Path::update_batch`]). The whole tensor-algebra core is
//!   generic along two axes: a **precision axis** — every kernel is
//!   parameterised over the sealed element trait [`ta::Elem`] (f32/f64;
//!   bare `&[f32]` call sites infer `E = f32` unchanged) — and a
//!   **dimension axis** — the fused forward and VJP each ship a
//!   `const D`-monomorphised body for `d ≤ 8` (a benchmark-arbitrated
//!   crossover, recorded by `benches/batch_lanes.rs`) and a runtime-`d`
//!   twin ([`ta::fused::fused_mexp_vjp_dyn`]) replaying the same
//!   floating-point op order beyond, so no entry point has a dimension
//!   ceiling.
//! - **Execution planning** ([`exec`]): one adaptive dispatch layer owning
//!   the choice between those strategies. Every execution site — the
//!   batched signature *and logsignature* forward/backward entry points
//!   ([`signature::signature_batch_with`],
//!   [`logsignature::logsignature_batch_with`] and their VJPs, which
//!   execute the same plans through shared planned executors plus a
//!   per-lane log/projection epilogue), `deepsig::train_step`, and the
//!   coordinator's router — describes its work as an [`exec::WorkShape`]
//!   and executes whatever [`exec::ExecPlan`] the [`exec::ExecPlanner`]
//!   returns (`Scalar`, `StreamParallel`, or `LaneFused`); no call site
//!   re-derives lane/thread heuristics. Shapes carry their element
//!   precision (`WorkShape::dtype`), the adaptive shape-mix keys on it
//!   ([`exec::ShapeKey`]), and the lane-fused backward is planned at
//!   *every* `d` — the runtime-`d` VJP removed the old `d ≤ 8` planning
//!   ceiling. Lane width is itself a runtime choice: [`exec::lane_width`]
//!   picks the widest tier in [`exec::LANE_WIDTHS`] (`{16, 32, 64}`)
//!   whose per-lane signature footprint `(d, depth, dtype)` fits the
//!   workspace budget, so small shapes fuse wide while f64 steps down a
//!   tier where f32 still fits. The serving layer additionally
//!   feeds the planner an observed shape-mix histogram, so microbatch
//!   formation adapts to recent traffic: hot shapes linger and lane-fuse,
//!   rare shapes serve directly. Plans are scheduling only — `Scalar` and
//!   `LaneFused` are bitwise identical, `StreamParallel` agrees to f32
//!   rounding — which is also what makes the planned XLA/GPU lowering a
//!   one-layer change: the lane layout is already the batched-kernel
//!   layout, so a future backend executes the same plans (logsignature
//!   plans included — they lower through the same path, the epilogue
//!   staying a per-lane postscript).
//! - **Accelerator runtime** ([`runtime`]): loads AOT-compiled HLO-text
//!   artifacts (produced by `python/compile/aot.py` from JAX + Pallas) and
//!   executes them on a PJRT client. This is the reproduction's analogue of
//!   Signatory's GPU backend.
//! - **Coordinator** ([`coordinator`]): a request router + dynamic batcher
//!   serving signature computations over both backends, plus a stateful
//!   streaming surface implementing "keeping the signature up-to-date"
//!   (§5.5): `OpenStream` / `Feed` / `QueryInterval` /
//!   `LogSigQueryInterval` / `CloseStream` requests flow through the same
//!   `Coordinator::call` front door (so metrics cover them) into a
//!   sharded, memory-bounded session table — per-session `Path` state
//!   with O(1) interval queries, an LRU-evicted byte budget, and an
//!   idle-TTL sweeper. Native signature *and logsignature* traffic is
//!   microbatched under the planner's adaptive per-shape capacity
//!   (`coordinator::DispatchConfig`), and same-spec session feeds from
//!   distinct sessions coalesce through the **feed lane** into single
//!   `Path::update_batch` sweeps — bitwise identical per session to
//!   scalar feeding. All three gathering surfaces instantiate one
//!   unified batcher generic (`coordinator::flusher::GroupBatcher`), so
//!   the pending-queue/condvar concurrency machinery exists exactly once.
//!   Rows travel **natively typed** end to end: requests and responses
//!   carry [`ta::Rows`] (`F32(Vec<f32>)` / `F64(Vec<f64>)`), the router
//!   inspects the precision tag exactly once at the wire boundary
//!   (`coordinator::rows::with_elem!`) and runs one [`ta::Elem`]-generic
//!   serving pipeline below it — f64 rows reach the f64 kernels at full
//!   width with no up/downcast anywhere in the plane, and f32 serving is
//!   bitwise what it was when the wire was `Vec<f32>`. Precision is part
//!   of the microbatch and feed-lane queue identities, so f32 and f64
//!   rows of one logical shape never share a flush — the logsignature
//!   surface included, whose f64 arm runs the generic epilogue at
//!   `E = f64`. **Rolling windows** make the paper's sliding-signature
//!   trick (§5.5) a server-maintained workload: `OpenWindow` attaches a
//!   [`path::WindowSpec`] (`len`/`stride`, signature or logsignature
//!   output) and every feed advances the window family incrementally —
//!   one O(1) stored-inverse Chen combination per emitted slide, bitwise
//!   identical to per-query answers over the same intervals — while
//!   `PollWindow` drains the buffered slides (pageable via `max_slides`
//!   + the response's `window_remaining` continuation). Slide
//!   advancement is lane-fused like feeding: when a feed-lane flush
//!   holds two or more same-spec windowed sessions, their slides advance
//!   through one [`path::RollingWindow::advance_batch`] sweep over the
//!   lane-interleaved Chen kernels ([`ta::batch`]), planner-gated
//!   ([`exec::ExecPlanner::plan_window_sweep`]) and bitwise identical
//!   per session to the scalar loop. Window sessions retain only
//!   the live horizon: a retention watermark ([`path::Path::base`])
//!   truncates dead `points`/`sigs`/`inv_sigs` prefixes geometrically, so
//!   per-session memory is O(window), not O(history), however long the
//!   stream runs. Per-request-kind log2-bucket latency histograms
//!   ([`coordinator::Metrics`]) expose the p50/p90/p99 the soak bench
//!   (`benches/session_soak.rs`) gates its SLO on.
//! - **Durable state** ([`state`]): the persistence layer under the
//!   session table. A versioned binary codec (v3: the retention
//!   watermark plus rolling-window state — emission cursor and
//!   undelivered slide rows — ride in the session record; v2 framed rows
//!   at native width; v1/v2 blobs and WALs still replay) serializes
//!   `Path` state bitwise in both precisions
//!   ([`path::Path::serialize_into`] / [`path::Path::deserialize`]); a [`state::SessionStore`] lets LRU
//!   eviction and TTL expiry *spill* sessions (memory or disk) instead of
//!   destroying them, with transparent bitwise reload on the next touch;
//!   an append-only feed-delta log ([`state::FeedLog`], fsync-batched by
//!   the session sweeper) gives `signax serve-stream --state-dir`
//!   warm-restart recovery; and [`state::Placement`] hash-shards session
//!   ids across N logical coordinators
//!   ([`coordinator::ShardedCoordinator`]) while keeping same-spec
//!   sessions co-located in feed-lane-width groups.
//!
//! Baselines reproducing the systems the paper benchmarks against live in
//! [`baselines`]; the benchmark harness regenerating every table and figure
//! of the paper lives in [`bench`].
//!
//! ## Quickstart
//!
//! ```no_run
//! use signax::prelude::*;
//!
//! let spec = SigSpec::new(2, 4).unwrap();           // 2 channels, depth 4
//! // A path: 10 points in R^2, flattened row-major (stream, channel).
//! let path: Vec<f32> = (0..20).map(|i| (i as f32 * 0.1).sin()).collect();
//! let sig = signax::signature::signature(&path, 10, &spec);
//! assert_eq!(sig.len(), spec.sig_len());
//! ```

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod deepsig;
pub mod exec;
pub mod logsignature;
pub mod parallel;
pub mod path;
pub mod runtime;
pub mod signature;
pub mod state;
pub mod substrate;
pub mod ta;
pub mod words;

/// Commonly used items, re-exported for convenience.
pub mod prelude {
    pub use crate::logsignature::{LogSigBasis, LogSigPlan};
    pub use crate::path::Path;
    pub use crate::signature::{signature, signature_stream, SigConfig};
    pub use crate::ta::SigSpec;
    pub use crate::words::witt_dimension;
}
