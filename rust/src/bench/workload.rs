//! Seeded heavy-tail workload generation shared by the serving
//! benchmarks (`session_soak`, `session_streaming`).
//!
//! The perf trajectory compares `BENCH_*.json` records across commits,
//! so benchmark traffic must be reproducible bit-for-bit: everything
//! here is a pure function of `(parameters, seed)` through
//! [`crate::substrate::rng::Rng`], and the unit tests pin determinism.
//!
//! Real serving traffic is heavy-tailed twice over — a few hot sessions
//! take most of the feeds (Zipf over sessions), and most feeds carry a
//! handful of points while a minority are bursts (Zipf over chunk
//! sizes). [`Workload`] composes both into one event stream.

use crate::substrate::rng::Rng;

/// Zipf(s) sampler over ranks `0..n` (rank 0 hottest): `P(k) ∝ (k+1)^-s`.
/// Sampling is inverse-CDF over a precomputed table — O(log n) per draw.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf over an empty universe");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        // Rounding guard: `uniform() < 1.0` must always find a rank.
        *cdf.last_mut().unwrap() = 1.0;
        Zipf { cdf }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Draw a rank in `0..n` (0 = hottest).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cdf.partition_point(|&c| c <= u)
    }
}

/// Heavy-tailed feed sizes in `[floor, cap]`: mass concentrates at the
/// floor, with a Zipf-weighted tail of bursts up to `cap`.
pub struct ChunkSizes {
    floor: usize,
    tail: Zipf,
}

impl ChunkSizes {
    /// `skew` is the Zipf exponent over the `cap - floor + 1` sizes;
    /// larger means burstier (more mass at `floor`).
    pub fn new(floor: usize, cap: usize, skew: f64) -> ChunkSizes {
        assert!(floor >= 1 && cap >= floor, "need 1 <= floor <= cap");
        ChunkSizes { floor, tail: Zipf::new(cap - floor + 1, skew) }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        self.floor + self.tail.sample(rng)
    }
}

/// One traffic event: feed `points` rows into session `session`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Session rank in `0..n_sessions` (0 = hottest).
    pub session: usize,
    /// Rows in this feed (ragged, heavy-tailed).
    pub points: usize,
}

/// A seeded stream of [`Event`]s: Zipf-hot sessions fed ragged chunks.
pub struct Workload {
    sessions: Zipf,
    chunks: ChunkSizes,
    rng: Rng,
}

impl Workload {
    /// `skew` shapes session popularity (1.1 is a typical serving tail);
    /// chunk sizes run `[1, chunk_cap]` with their own fixed skew.
    pub fn new(n_sessions: usize, skew: f64, chunk_cap: usize, seed: u64) -> Workload {
        Workload {
            sessions: Zipf::new(n_sessions, skew),
            chunks: ChunkSizes::new(1, chunk_cap, 1.2),
            rng: Rng::new(seed),
        }
    }

    pub fn next_event(&mut self) -> Event {
        Event {
            session: self.sessions.sample(&mut self.rng),
            points: self.chunks.sample(&mut self.rng),
        }
    }

    /// The workload's own generator, for deriving point data in lockstep
    /// with the event stream (keeps the whole trace one seed).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_deterministic_and_skewed() {
        let z = Zipf::new(1000, 1.1);
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let draws_a: Vec<usize> = (0..2000).map(|_| z.sample(&mut a)).collect();
        let draws_b: Vec<usize> = (0..2000).map(|_| z.sample(&mut b)).collect();
        assert_eq!(draws_a, draws_b, "same seed must replay the same ranks");
        assert!(draws_a.iter().all(|&k| k < 1000));
        // Skew sanity: the hottest rank beats a cold one by a wide margin.
        let hot = draws_a.iter().filter(|&&k| k == 0).count();
        let cold = draws_a.iter().filter(|&&k| k == 900).count();
        assert!(hot >= 20 && hot > 4 * cold, "hot={hot} cold={cold}");
    }

    #[test]
    fn chunk_sizes_stay_in_bounds() {
        let c = ChunkSizes::new(4, 64, 1.2);
        let mut rng = Rng::new(11);
        let mut seen_floor = false;
        for _ in 0..5000 {
            let s = c.sample(&mut rng);
            assert!((4..=64).contains(&s), "chunk {s} out of [4, 64]");
            seen_floor |= s == 4;
        }
        assert!(seen_floor, "heavy tail should mass at the floor");
    }

    #[test]
    fn workload_trace_is_reproducible() {
        // The BENCH trajectory contract: one seed, one trace — events
        // AND the point data drawn from the workload's rng.
        let mut a = Workload::new(500, 1.1, 32, 0x50AC);
        let mut b = Workload::new(500, 1.1, 32, 0x50AC);
        for _ in 0..1000 {
            let ea = a.next_event();
            assert_eq!(ea, b.next_event());
            assert_eq!(
                a.rng().normal_vec(ea.points, 0.3),
                b.rng().normal_vec(ea.points, 0.3)
            );
        }
        let mut c = Workload::new(500, 1.1, 32, 0x50AD);
        let diverged = (0..100).any(|_| a.next_event() != c.next_event());
        assert!(diverged, "different seeds must give different traces");
    }
}
